//! Regenerates every paper table/figure (deliverable (d)). Each experiment
//! prints its paper-shaped rows and writes results/<id>.json.
//!
//! Scale via EAC_MOE_BENCH_SCALE (default 0.25 — the single-core CI
//! setting; use 1.0 for the full data volumes).
//!
//! ```bash
//! cargo bench --bench bench_tables                 # all
//! cargo bench --bench bench_tables -- table2 fig7  # subset
//! ```

fn main() {
    let scale: f64 = eac_moe::util::env::bench_scale().unwrap_or(0.25);
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let ids: Vec<&str> = if args.is_empty() {
        vec![
            "fig2", "fig10", "table1", "fig4", "fig6", "table2", "fig7", "table3",
            "table4", "table5", "table6", "table7", "table8", "table9", "fig8", "fig9",
        ]
    } else {
        args.iter().map(|s| s.as_str()).collect()
    };
    println!("== bench_tables (scale {scale}) ==");
    for id in ids {
        println!("\n################ {id} ################");
        if let Err(e) = eac_moe::report::experiments::run(id, scale) {
            eprintln!("experiment {id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}
