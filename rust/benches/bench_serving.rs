//! Serving-engine benchmarks: batcher overhead, engine throughput scaling
//! with batch policy and worker count, and PESF's serve-time effect
//! (the L3 §Perf targets).

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::prune::pesf::PesfConfig;
use eac_moe::serve::{BatchPolicy, Batcher, Engine, EngineConfig, PrunePolicy, Request};
use eac_moe::util::timing::bench;
use std::time::Duration;

fn model() -> Model {
    let cfg = ModelConfig {
        name: "bench".into(),
        n_layers: 4,
        d_model: 128,
        d_ff: 64,
        n_experts: 64,
        top_k: 6,
        n_shared: 2,
        n_heads: 4,
        vocab: 512,
        max_seq: 512,
    };
    Model::new(Weights::init(&cfg, 3))
}

fn reqs(n: u64, len: usize) -> Vec<Request> {
    let mut mix = eac_moe::data::corpus::WikiMixture::new(55);
    (0..n).map(|i| Request::new(i, mix.sequence(len).to_vec())).collect()
}

fn main() {
    println!("== bench_serving ==");

    // Batcher overhead: push+drain 1k requests, no model work.
    bench("batcher push+drain 1000 reqs", || {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(1),
            ..Default::default()
        });
        for i in 0..1000 {
            assert!(b.push(Request::new(i, vec![1, 2, 3])).is_ok());
        }
        b.close();
        let mut n = 0;
        while let Some(batch) = b.next_batch() {
            n += batch.len();
        }
        assert_eq!(n, 1000);
    });

    // Engine throughput: deepseek-mini shape, 8 requests x 128 tokens.
    let m = model();
    for (name, prune) in [
        ("engine 8x128 dense", PrunePolicy::None),
        ("engine 8x128 PESF(0.3)", PrunePolicy::Pesf(PesfConfig { alpha: 0.3, ..Default::default() })),
        ("engine 8x128 PESF(0.7)", PrunePolicy::Pesf(PesfConfig { alpha: 0.7, ..Default::default() })),
    ] {
        let weights = m.weights.clone();
        let r = bench(name, || {
            let engine = Engine::new(
                Model::new(weights.clone()),
                EngineConfig { workers: 1, prune, ..Default::default() },
            );
            let (resps, _) = engine.serve(reqs(8, 128));
            assert_eq!(resps.len(), 8);
        });
        let toks = 8.0 * 128.0;
        println!("    -> {:.0} tok/s", toks / (r.mean_ns / 1e9));
    }

    // Decode batching: same requests + 24 decode tokens each, served with
    // a decode batch of 1 vs 4 (the cross-batch expert-GEMM gather).
    for max_batch in [1usize, 4] {
        let weights = m.weights.clone();
        let r = bench(&format!("engine 8x64 +24 decode, max_batch={max_batch}"), || {
            let engine = Engine::new(
                Model::new(weights.clone()),
                EngineConfig {
                    batch: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_micros(100),
                        ..Default::default()
                    },
                    workers: 1,
                    prune: PrunePolicy::None,
                    ..Default::default()
                },
            );
            let rs: Vec<Request> =
                reqs(8, 64).into_iter().map(|r| r.with_decode(24)).collect();
            let (resps, metrics) = engine.serve(rs);
            assert_eq!(resps.len(), 8);
            assert_eq!(metrics.generated_tokens, 8 * 24);
        });
        let gen_toks = 8.0 * 24.0;
        println!("    -> {:.0} decode tok/s", gen_toks / (r.mean_ns / 1e9));
    }

    // --- Open-loop Poisson burst: p50/p95/p99 TTFT and ITL under a
    // bimodal short/long prompt mix, chunked prefill vs the monolithic
    // baseline on the *same* arrival schedule. The SLO story in one
    // number: monolithically, a short prompt that lands behind a long one
    // waits out the entire long prefill before its first token; chunking
    // bounds that head-of-line blocking at one chunk, so short-request
    // p99 TTFT drops while the outputs stay token-identical (asserted —
    // chunking is a scheduling change, not a math change).
    {
        use eac_moe::serve::workload::{self, LenDist, WorkloadSpec};
        let spec = WorkloadSpec {
            n_requests: 24,
            rate_per_sec: 300.0,
            prompt_len: LenDist::Bimodal { short: 8, long: 192, p_short: 0.75 },
            decode_len: LenDist::Fixed(8),
            tenants: 1,
            vocab: 512,
            seed: 7,
            deadline_budget: None,
        };
        let arrivals = workload::generate(&spec);
        let short_ids: Vec<u64> = arrivals
            .iter()
            .filter(|t| t.req.tokens.len() == 8)
            .map(|t| t.req.id)
            .collect();
        println!(
            "poisson burst: {} reqs @ {:.0}/s ({} short x8, {} long x192), +8 decode each",
            spec.n_requests,
            spec.rate_per_sec,
            short_ids.len(),
            spec.n_requests - short_ids.len()
        );
        let pctl = |mut v: Vec<f64>, p: f64| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v.sort_by(|a, b| a.total_cmp(b));
            v[((p * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)]
        };
        let mut short_p99 = Vec::new(); // (name, ms)
        let mut outputs = Vec::new(); // sorted (id, next_token, generated) per run
        for (name, chunk) in [("monolithic", 0usize), ("chunk=32", 32)] {
            let engine = Engine::new(
                Model::new(m.weights.clone()),
                EngineConfig {
                    batch: BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_micros(100),
                        ..Default::default()
                    },
                    workers: 1,
                    prefill_chunk: chunk,
                    ..Default::default()
                },
            );
            let (resps, metrics) = engine.serve_timed(arrivals.clone());
            assert_eq!(resps.len(), spec.n_requests);
            assert!(
                resps.iter().all(|r| !r.finish_reason.is_rejection()),
                "burst workload must serve every request"
            );
            let mut out: Vec<(u64, u32, Vec<u32>)> =
                resps.iter().map(|r| (r.id, r.next_token, r.generated.clone())).collect();
            out.sort_by_key(|(id, _, _)| *id);
            outputs.push(out);
            let short_ttft_ms: Vec<f64> = resps
                .iter()
                .filter(|r| short_ids.contains(&r.id))
                .map(|r| r.ttft_secs * 1e3)
                .collect();
            let sp99 = pctl(short_ttft_ms, 0.99);
            short_p99.push((name, sp99));
            println!(
                "    {name:>10}: ttft p50={:.1}ms p95={:.1}ms p99={:.1}ms | itl p50={:.1}ms p95={:.1}ms p99={:.1}ms | short-req ttft p99={sp99:.1}ms",
                metrics.ttft.percentile_ms(0.5),
                metrics.ttft.percentile_ms(0.95),
                metrics.ttft.percentile_ms(0.99),
                metrics.itl.percentile_ms(0.5),
                metrics.itl.percentile_ms(0.95),
                metrics.itl.percentile_ms(0.99),
            );
        }
        assert_eq!(
            outputs[0], outputs[1],
            "chunked prefill changed tokens — it must be scheduling-only"
        );
        let (mono, chunked) = (short_p99[0].1, short_p99[1].1);
        println!(
            "    -> short-request p99 TTFT: chunked {chunked:.1}ms vs monolithic {mono:.1}ms ({:.2}x){}",
            chunked / mono.max(1e-9),
            if chunked < mono { "" } else { "  [WARN: chunking did not help on this host]" }
        );
    }
}
