//! Serving-engine benchmarks: batcher overhead, engine throughput scaling
//! with batch policy and worker count, and PESF's serve-time effect
//! (the L3 §Perf targets).

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::prune::pesf::PesfConfig;
use eac_moe::serve::{BatchPolicy, Batcher, Engine, EngineConfig, PrunePolicy, Request};
use eac_moe::util::timing::bench;
use std::time::Duration;

fn model() -> Model {
    let cfg = ModelConfig {
        name: "bench".into(),
        n_layers: 4,
        d_model: 128,
        d_ff: 64,
        n_experts: 64,
        top_k: 6,
        n_shared: 2,
        n_heads: 4,
        vocab: 512,
        max_seq: 512,
    };
    Model::new(Weights::init(&cfg, 3))
}

fn reqs(n: u64, len: usize) -> Vec<Request> {
    let mut mix = eac_moe::data::corpus::WikiMixture::new(55);
    (0..n).map(|i| Request::new(i, mix.sequence(len).to_vec())).collect()
}

fn main() {
    println!("== bench_serving ==");

    // Batcher overhead: push+drain 1k requests, no model work.
    bench("batcher push+drain 1000 reqs", || {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(1),
            ..Default::default()
        });
        for i in 0..1000 {
            assert!(b.push(Request::new(i, vec![1, 2, 3])).is_ok());
        }
        b.close();
        let mut n = 0;
        while let Some(batch) = b.next_batch() {
            n += batch.len();
        }
        assert_eq!(n, 1000);
    });

    // Engine throughput: deepseek-mini shape, 8 requests x 128 tokens.
    let m = model();
    for (name, prune) in [
        ("engine 8x128 dense", PrunePolicy::None),
        ("engine 8x128 PESF(0.3)", PrunePolicy::Pesf(PesfConfig { alpha: 0.3, ..Default::default() })),
        ("engine 8x128 PESF(0.7)", PrunePolicy::Pesf(PesfConfig { alpha: 0.7, ..Default::default() })),
    ] {
        let weights = m.weights.clone();
        let r = bench(name, || {
            let engine = Engine::new(
                Model::new(weights.clone()),
                EngineConfig { workers: 1, prune, ..Default::default() },
            );
            let (resps, _) = engine.serve(reqs(8, 128));
            assert_eq!(resps.len(), 8);
        });
        let toks = 8.0 * 128.0;
        println!("    -> {:.0} tok/s", toks / (r.mean_ns / 1e9));
    }

    // Decode batching: same requests + 24 decode tokens each, served with
    // a decode batch of 1 vs 4 (the cross-batch expert-GEMM gather).
    for max_batch in [1usize, 4] {
        let weights = m.weights.clone();
        let r = bench(&format!("engine 8x64 +24 decode, max_batch={max_batch}"), || {
            let engine = Engine::new(
                Model::new(weights.clone()),
                EngineConfig {
                    batch: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_micros(100),
                        ..Default::default()
                    },
                    workers: 1,
                    prune: PrunePolicy::None,
                    ..Default::default()
                },
            );
            let rs: Vec<Request> =
                reqs(8, 64).into_iter().map(|r| r.with_decode(24)).collect();
            let (resps, metrics) = engine.serve(rs);
            assert_eq!(resps.len(), 8);
            assert_eq!(metrics.generated_tokens, 8 * 24);
        });
        let gen_toks = 8.0 * 24.0;
        println!("    -> {:.0} decode tok/s", gen_toks / (r.mean_ns / 1e9));
    }
}
