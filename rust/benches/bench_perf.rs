//! Performance micro-benches for the hot paths (EXPERIMENTS.md §Perf):
//! native GEMM, fused packed dequant-matmul, GPTQ per-layer, model prefill,
//! PESF overhead. `harness = false` — uses the in-crate timing harness
//! (criterion is not in the offline registry).

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::quant::gptq::{gptq_quantize_mat, GptqConfig, Hessian};
use eac_moe::quant::pack::PackedMat;
use eac_moe::quant::quantizer::{GroupQuant, QuantConfig};
use eac_moe::tensor::{matmul, Mat, Pcg64};
use eac_moe::util::timing::bench;

fn main() {
    println!("== bench_perf (EAC_MOE_BENCH_MS={}ms/case) ==",
        std::env::var("EAC_MOE_BENCH_MS").unwrap_or_else(|_| "2000".into()));
    let mut rng = Pcg64::seeded(1);

    // --- GEMM: the prefill workhorse (tokens x d_model @ d_model x d_ff).
    for &(m, k, n) in &[(512usize, 128usize, 256usize), (128, 128, 512), (1, 128, 512)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let r = bench(&format!("matmul {m}x{k}x{n}"), || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);
    }

    // --- Fused packed dequant-matmul vs dequant-then-GEMM (2-bit).
    let w = Mat::randn(128, 512, 1.0, &mut rng);
    let gq = GroupQuant::quantize(&w, QuantConfig::new(2, 128));
    let packed = PackedMat::pack(&gq);
    for &m in &[1usize, 16, 512] {
        let x = Mat::randn(m, 128, 1.0, &mut rng);
        bench(&format!("packed2 fused dequant-matmul m={m}"), || {
            std::hint::black_box(packed.matmul_dequant(&x));
        });
        bench(&format!("dequant-then-matmul      m={m}"), || {
            let dq = gq.dequantize();
            std::hint::black_box(matmul(&x, &dq));
        });
    }

    // --- GPTQ one expert matrix (the Table-7 dominant cost).
    let x = Mat::randn(512, 128, 1.0, &mut rng);
    let mut h = Hessian::new(128);
    h.update(&x);
    let w = Mat::randn(128, 256, 1.0, &mut rng);
    bench("gptq 128x256 @3bit g128", || {
        std::hint::black_box(gptq_quantize_mat(&w, &h, GptqConfig::new(3, 128)));
    });

    // --- Model prefill (mixtral-mini shape) with and without PESF.
    let cfg = ModelConfig {
        name: "bench".into(),
        n_layers: 4,
        d_model: 128,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 512,
        max_seq: 512,
    };
    let model = Model::new(Weights::init(&cfg, 2));
    let tokens: Vec<u32> = (0..256u32).map(|i| (i * 7) % 512).collect();
    bench("prefill 256 tok (mixtral-mini shape)", || {
        std::hint::black_box(model.forward(&tokens));
    });
    bench("prefill 256 tok + PESF(0.5)", || {
        let hooks = eac_moe::model::hooks::Hooks {
            pesf_alpha: Some(0.5),
            ..Default::default()
        };
        std::hint::black_box(model.forward_with_hooks(&tokens, &hooks));
    });

    // --- Decode step (kv-cache path; quantization's bandwidth-bound case).
    let mut cache = eac_moe::model::KvCache::new(model.cfg());
    for &t in tokens.iter().take(64) {
        model.decode_step(t, &mut cache, &eac_moe::model::hooks::Hooks::none());
    }
    bench("decode step @ctx64", || {
        let mut c2 = eac_moe::model::KvCache::new(model.cfg());
        c2.len = cache.len;
        for li in 0..cfg.n_layers {
            c2.k[li] = cache.k[li].clone();
            c2.v[li] = cache.v[li].clone();
        }
        std::hint::black_box(model.decode_step(1, &mut c2, &eac_moe::model::hooks::Hooks::none()));
    });
}
