//! Performance micro-benches for the hot paths (EXPERIMENTS.md §Perf):
//! native GEMM, fused packed dequant-matmul, GPTQ per-layer, model prefill
//! (dense vs packed weights), PESF overhead. `harness = false` — uses the
//! in-crate timing harness (criterion is not in the offline registry).
//!
//! Emits `results/bench_perf.json` with the dense-vs-packed GEMM,
//! end-to-end prefill, serve-with-decode (seed double-compute vs prefill
//! KV export), batched-vs-sequential decode, small-batch decode
//! tokens/sec across worker-pool sizes (B ∈ {1,4} × threads ∈ {1,4} — the
//! persistent-pool win), and pruned-vs-unpruned decode under decode-time
//! PESF (`decode_pesf/*`: alpha ∈ {0, 0.3, 0.7} × B ∈ {1,4}, plus an
//! engine run reporting the decode-phase prune rate), forced-scalar vs
//! SIMD-dispatched decode with a bitwise-equality gate (`simd_gemm/b{1,4}`),
//! KV-cache bytes / decode tok/s / decode-path ppl at f32 vs int8
//! storage (`kv_cache/*`), and open-loop Poisson-burst serving tails —
//! TTFT/ITL p50/p95/p99 with monolithic vs chunked-interleaved prefill
//! (`serve_slo/*`), same shape as the bench_tables outputs. CI runs
//! this in smoke mode (`EAC_MOE_BENCH_MS=25`), uploads the JSON, and
//! appends the run's summary to the repo-root `BENCH_TRAJECTORY.json` so
//! the perf trajectory is tracked per PR.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::quant::gptq::{gptq_quantize_mat, GptqConfig, Hessian};
use eac_moe::quant::pack::PackedMat;
use eac_moe::quant::quantizer::{GroupQuant, QuantConfig};
use eac_moe::tensor::{matmul, Mat, Pcg64};
use eac_moe::util::json::Json;
use eac_moe::util::timing::bench;

fn main() {
    println!("== bench_perf (EAC_MOE_BENCH_MS={}ms/case) ==",
        eac_moe::util::env::bench_ms().unwrap_or(2000));
    let mut rng = Pcg64::seeded(1);
    let mut json = Json::obj();

    // --- GEMM: the prefill workhorse (tokens x d_model @ d_model x d_ff).
    for &(m, k, n) in &[(512usize, 128usize, 256usize), (128, 128, 512), (1, 128, 512)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let r = bench(&format!("matmul {m}x{k}x{n}"), || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);
    }

    // --- Dense GEMM vs fused packed dequant-GEMM at 2 and 4 bits.
    // The fused kernel unpacks K-tiles into a reused panel, so its cost
    // should sit within ~1.5-2x of dense at batch M, not the ~column-count
    // multiple the old per-call unpack paid.
    let (k, n) = (128usize, 512usize);
    let w = Mat::randn(k, n, 1.0, &mut rng);
    for &bits in &[2u32, 4] {
        let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 128));
        let packed = PackedMat::pack(&gq);
        let dq = gq.dequantize();
        for &m in &[1usize, 16, 512] {
            let x = Mat::randn(m, k, 1.0, &mut rng);
            let rp = bench(&format!("packed{bits} fused dequant-matmul m={m}"), || {
                std::hint::black_box(packed.matmul_dequant(&x));
            });
            let rd = bench(&format!("dense matmul (pre-dequantized) m={m}"), || {
                std::hint::black_box(matmul(&x, &dq));
            });
            let ru = bench(&format!("dequant-then-matmul          m={m}"), || {
                let dq = gq.dequantize();
                std::hint::black_box(matmul(&x, &dq));
            });
            println!("    -> packed/dense ratio: {:.2}x", rp.mean_ns / rd.mean_ns);
            let mut o = Json::obj();
            o.set("fused_ns", Json::Num(rp.mean_ns))
                .set("dense_ns", Json::Num(rd.mean_ns))
                .set("unpack_per_call_ns", Json::Num(ru.mean_ns))
                .set("fused_over_dense", Json::Num(rp.mean_ns / rd.mean_ns));
            json.set(&format!("gemm/{bits}bit/m{m}"), o);
        }
    }

    // --- GPTQ one expert matrix (the Table-7 dominant cost).
    let x = Mat::randn(512, 128, 1.0, &mut rng);
    let mut h = Hessian::new(128);
    h.update(&x);
    let w = Mat::randn(128, 256, 1.0, &mut rng);
    bench("gptq 128x256 @3bit g128", || {
        std::hint::black_box(gptq_quantize_mat(&w, &h, GptqConfig::new(3, 128)));
    });

    // --- Model prefill (mixtral-mini shape): dense, packed 4-bit, PESF.
    let cfg = ModelConfig {
        name: "bench".into(),
        n_layers: 4,
        d_model: 128,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 512,
        max_seq: 512,
    };
    let model = Model::new(Weights::init(&cfg, 2));
    let mut packed_weights = model.weights.clone();
    packed_weights.pack_experts_rtn(4, 128);
    let packed_model = Model::new(packed_weights);
    let tokens: Vec<u32> = (0..256u32).map(|i| (i * 7) % 512).collect();
    let rd = bench("prefill 256 tok dense (mixtral-mini shape)", || {
        std::hint::black_box(model.forward(&tokens));
    });
    let rp = bench("prefill 256 tok packed 4-bit experts", || {
        std::hint::black_box(packed_model.forward(&tokens));
    });
    println!(
        "    -> packed/dense prefill ratio: {:.2}x  (resident weights {:.2} MB vs {:.2} MB)",
        rp.mean_ns / rd.mean_ns,
        packed_model.weights.storage_bytes() as f64 / 1e6,
        model.weights.storage_bytes() as f64 / 1e6
    );
    let mut o = Json::obj();
    o.set("dense_ns", Json::Num(rd.mean_ns))
        .set("packed_ns", Json::Num(rp.mean_ns))
        .set("packed_over_dense", Json::Num(rp.mean_ns / rd.mean_ns))
        .set("dense_weight_bytes", Json::Num(model.weights.storage_bytes() as f64))
        .set("packed_weight_bytes", Json::Num(packed_model.weights.storage_bytes() as f64));
    json.set("prefill/256tok", o);
    bench("prefill 256 tok + PESF(0.5)", || {
        let hooks = eac_moe::model::hooks::Hooks {
            pesf_alpha: Some(0.5),
            ..Default::default()
        };
        std::hint::black_box(model.forward_with_hooks(&tokens, &hooks));
    });

    // --- Serve-with-decode: the seed engine forwarded every prompt twice
    // (prefill for logits, then a token-by-token decode_step replay just to
    // refill the KV cache). The KV-export path prefills once into the
    // cache. Same outputs, one prompt pass — the ratio is the PR's win.
    let (prompt_len, n_decode) = (192usize, 32usize);
    let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| (i * 11) % 512).collect();
    let seed_path = |model: &Model| {
        let logits = model.forward(&prompt);
        let mut cur = eac_moe::tensor::ops::topk_indices(logits.row(prompt_len - 1), 1)[0] as u32;
        let mut cache = eac_moe::model::KvCache::new(model.cfg());
        for &t in &prompt {
            model.decode_step(t, &mut cache, &eac_moe::model::hooks::Hooks::none());
        }
        let mut generated = Vec::with_capacity(n_decode);
        for _ in 0..n_decode {
            generated.push(cur);
            let l = model.decode_step(cur, &mut cache, &eac_moe::model::hooks::Hooks::none());
            cur = eac_moe::tensor::ops::topk_indices(&l, 1)[0] as u32;
        }
        generated
    };
    let kv_export_path = |model: &Model| {
        let mut cache = eac_moe::model::KvCache::new(model.cfg());
        let logits =
            model.prefill_into_cache(&prompt, &eac_moe::model::hooks::Hooks::none(), &mut cache);
        let mut cur = eac_moe::tensor::ops::topk_indices(logits.row(prompt_len - 1), 1)[0] as u32;
        let mut generated = Vec::with_capacity(n_decode);
        for _ in 0..n_decode {
            generated.push(cur);
            let l = model.decode_step(cur, &mut cache, &eac_moe::model::hooks::Hooks::none());
            cur = eac_moe::tensor::ops::topk_indices(&l, 1)[0] as u32;
        }
        generated
    };
    assert_eq!(seed_path(&model), kv_export_path(&model), "paths must agree token-for-token");
    let rs = bench(&format!("serve {prompt_len}+{n_decode} seed double-compute"), || {
        std::hint::black_box(seed_path(&model));
    });
    let rk = bench(&format!("serve {prompt_len}+{n_decode} prefill KV export"), || {
        std::hint::black_box(kv_export_path(&model));
    });
    println!("    -> KV export speedup over seed path: {:.2}x", rs.mean_ns / rk.mean_ns);
    let mut o = Json::obj();
    o.set("seed_double_compute_ns", Json::Num(rs.mean_ns))
        .set("kv_export_ns", Json::Num(rk.mean_ns))
        .set("seed_over_kv_export", Json::Num(rs.mean_ns / rk.mean_ns));
    json.set(&format!("serve_decode/{prompt_len}p{n_decode}d"), o);

    // --- Batched decode: B sequences advanced together (experts gathered
    // across the batch into one GEMM) vs B sequential decode_steps.
    let bsz = 4usize;
    let prefill_batch = || -> Vec<eac_moe::model::KvCache> {
        (0..bsz)
            .map(|b| {
                let p: Vec<u32> = (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect();
                let mut c = eac_moe::model::KvCache::new(model.cfg());
                model.prefill_into_cache(&p, &eac_moe::model::hooks::Hooks::none(), &mut c);
                c
            })
            .collect()
    };
    // Rewinding `len` (instead of cloning ~MBs of cache per iteration)
    // keeps the timed region pure decode: the step re-appends at the same
    // position and never reads past `len`, so stale rows are harmless.
    let mut caches = prefill_batch();
    let ctx_len = caches[0].len;
    let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
    let rb = bench(&format!("decode step batched B={bsz} @ctx64"), || {
        for c in caches.iter_mut() {
            c.len = ctx_len;
        }
        std::hint::black_box(model.decode_step_batch(
            &toks,
            &mut caches,
            &eac_moe::model::hooks::Hooks::none(),
        ));
    });
    let rq = bench(&format!("decode step sequential x{bsz} @ctx64"), || {
        for (b, c) in caches.iter_mut().enumerate() {
            c.len = ctx_len;
            std::hint::black_box(model.decode_step(
                toks[b],
                c,
                &eac_moe::model::hooks::Hooks::none(),
            ));
        }
    });
    println!("    -> batched/sequential decode ratio: {:.2}x", rb.mean_ns / rq.mean_ns);
    let mut o = Json::obj();
    o.set("batched_ns", Json::Num(rb.mean_ns))
        .set("sequential_ns", Json::Num(rq.mean_ns))
        .set("batched_over_sequential", Json::Num(rb.mean_ns / rq.mean_ns));
    json.set(&format!("decode_batch/b{bsz}"), o);

    // --- Small-batch decode vs pool size: the worker-pool win. Before the
    // persistent pool, decode GEMMs (B rows, a few routed tokens per
    // expert) always fell below the row-parallel threshold and ran on one
    // core; expert- and head-level tasks now spread them across the pool,
    // so B=1 decode tokens/sec should improve with threads=4 over
    // threads=1.
    {
        use eac_moe::tensor::pool::ThreadPool;
        use std::sync::Arc;
        for &threads in &[1usize, 4] {
            let pm = Model::with_pool(
                model.weights.clone(),
                Arc::new(ThreadPool::new(threads)),
            );
            for &bsz in &[1usize, 4] {
                let mut caches: Vec<eac_moe::model::KvCache> = (0..bsz)
                    .map(|b| {
                        let p: Vec<u32> =
                            (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect();
                        let mut c = eac_moe::model::KvCache::new(pm.cfg());
                        pm.prefill_into_cache(&p, &eac_moe::model::hooks::Hooks::none(), &mut c);
                        c
                    })
                    .collect();
                let ctx_len = caches[0].len;
                let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
                let r = bench(
                    &format!("decode step B={bsz} pool={threads} @ctx64"),
                    || {
                        for c in caches.iter_mut() {
                            c.len = ctx_len;
                        }
                        std::hint::black_box(pm.decode_step_batch(
                            &toks,
                            &mut caches,
                            &eac_moe::model::hooks::Hooks::none(),
                        ));
                    },
                );
                let tps = bsz as f64 / (r.mean_ns / 1e9);
                println!("    -> {tps:.0} decode tok/s");
                let mut o = Json::obj();
                o.set("step_ns", Json::Num(r.mean_ns))
                    .set("tokens_per_sec", Json::Num(tps));
                json.set(&format!("decode_pool/b{bsz}t{threads}"), o);
            }
        }
    }

    // --- Decode-time PESF: per-sequence masks carried through
    // decode_step_batch, so pruned experts are skipped where serving
    // spends its wall-clock. Pruned vs unpruned decode tokens/sec at
    // alpha ∈ {0, 0.3, 0.7} × B ∈ {1, 4} (`decode_pesf/*` — the ISSUE-4
    // acceptance surface: alpha=0.7 should beat unpruned on the same
    // batch shape, alpha=0 is asserted bit-identical to it).
    {
        use eac_moe::model::hooks::{Hooks, SeqExpertMask};
        use eac_moe::prune::pesf::{pesf_mask, PesfConfig};
        use std::sync::Arc;
        let (n_layers, n_experts, top_k) =
            (model.cfg().n_layers, model.cfg().n_experts, model.cfg().top_k);
        for &bsz in &[1usize, 4] {
            let prompts: Vec<Vec<u32>> = (0..bsz)
                .map(|b| (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect())
                .collect();
            let mut caches: Vec<eac_moe::model::KvCache> = prompts
                .iter()
                .map(|p| {
                    let mut c = eac_moe::model::KvCache::new(model.cfg());
                    model.prefill_into_cache(p, &Hooks::none(), &mut c);
                    c
                })
                .collect();
            let ctx_len = caches[0].len;
            let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
            let ru = bench(&format!("decode step B={bsz} unpruned @ctx64"), || {
                for c in caches.iter_mut() {
                    c.len = ctx_len;
                }
                std::hint::black_box(model.decode_step_batch(
                    &toks,
                    &mut caches,
                    &Hooks::none(),
                ));
            });
            let unpruned_tps = bsz as f64 / (ru.mean_ns / 1e9);
            // Each sequence's routing statistics, recorded once — the
            // record is alpha-independent; only the Eq. 6 thresholding
            // below depends on alpha.
            let records: Vec<_> = prompts
                .iter()
                .map(|p| {
                    let hooks = Hooks::recording(n_layers);
                    model.forward_with_hooks(p, &hooks);
                    hooks.take_selections().unwrap()
                })
                .collect();
            for &alpha in &[0.0f32, 0.3, 0.7] {
                let pc = PesfConfig { alpha, ..Default::default() };
                // Each sequence's mask from its own prompt statistics,
                // exactly as the engine derives it at prefill.
                let masks: Vec<Option<SeqExpertMask>> = records
                    .iter()
                    .map(|rec| {
                        let (m, _) = pesf_mask(rec, n_experts, top_k, pc);
                        Some(Arc::new(m))
                    })
                    .collect();
                let hooks = Hooks::with_seq_masks(masks);
                if alpha == 0.0 {
                    // All-false masks: the masked path must be bit-identical
                    // to the unpruned decode it is benchmarked against.
                    for c in caches.iter_mut() {
                        c.len = ctx_len;
                    }
                    let a = model.decode_step_batch(&toks, &mut caches, &hooks);
                    for c in caches.iter_mut() {
                        c.len = ctx_len;
                    }
                    let b = model.decode_step_batch(&toks, &mut caches, &Hooks::none());
                    assert_eq!(a.data, b.data, "alpha=0 masked decode differs from unpruned");
                }
                let r = bench(&format!("decode step B={bsz} PESF(a={alpha}) @ctx64"), || {
                    for c in caches.iter_mut() {
                        c.len = ctx_len;
                    }
                    std::hint::black_box(model.decode_step_batch(&toks, &mut caches, &hooks));
                });
                let tps = bsz as f64 / (r.mean_ns / 1e9);
                println!("    -> {tps:.0} pruned vs {unpruned_tps:.0} unpruned decode tok/s");
                let mut o = Json::obj();
                o.set("pruned_tokens_per_sec", Json::Num(tps))
                    .set("unpruned_tokens_per_sec", Json::Num(unpruned_tps))
                    .set("pruned_over_unpruned", Json::Num(tps / unpruned_tps));
                json.set(&format!("decode_pesf/b{bsz}/alpha{alpha}"), o);
            }
        }
        // The ServeMetrics surface: a short engine run at alpha=0.7 must
        // report a decode-phase prune rate > 0 alongside the speedup.
        {
            use eac_moe::serve::{Engine, EngineConfig, PrunePolicy, Request};
            let engine = Engine::new(
                Model::new(model.weights.clone()),
                EngineConfig {
                    workers: 1,
                    prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.7, ..Default::default() }),
                    ..Default::default()
                },
            );
            let reqs: Vec<Request> = (0..4u64)
                .map(|i| {
                    Request::new(i, (0..64u32).map(|t| (t * 7 + i as u32 * 13) % 512).collect())
                        .with_decode(16)
                })
                .collect();
            let (_, m) = engine.serve(reqs);
            println!(
                "    -> serve alpha=0.7: decode prune {:.1}%, {:.0} decode tok/s",
                m.mean_decode_prune_rate * 100.0,
                m.decode_tokens_per_sec()
            );
            let mut o = Json::obj();
            o.set("decode_prune_rate", Json::Num(m.mean_decode_prune_rate as f64))
                .set("prefill_prune_rate", Json::Num(m.mean_prune_rate as f64))
                .set("decode_tokens_per_sec", Json::Num(m.decode_tokens_per_sec()));
            json.set("decode_pesf/serve_alpha0.7", o);
        }
    }

    // --- Tiered ExpertStore: the packed model served with experts on
    // disk under budget fractions {1.0, 0.5, 0.25} of their total bytes
    // (`expert_store/*`). Decode tok/s + hit rate per budget; outputs are
    // asserted bit-identical to the resident model before timing, so the
    // entries measure pure residency-management cost.
    {
        use eac_moe::model::hooks::Hooks;
        let spill = std::env::temp_dir()
            .join(format!("eac_moe_bench_store_{}.bin", std::process::id()));
        packed_model.weights.save(&spill).expect("spill packed weights");
        let total = packed_model.expert_store_stats().total_bytes;
        let min_fit = packed_model.weights.max_expert_bytes();
        let bsz = 4usize;
        let prompts: Vec<Vec<u32>> = (0..bsz)
            .map(|b| (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect())
            .collect();
        let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
        let prefill_on = |m: &Model| -> Vec<eac_moe::model::KvCache> {
            prompts
                .iter()
                .map(|p| {
                    let mut c = eac_moe::model::KvCache::new(m.cfg());
                    m.prefill_into_cache(p, &Hooks::none(), &mut c);
                    c
                })
                .collect()
        };
        let mut ref_caches = prefill_on(&packed_model);
        let ref_logits = packed_model.decode_step_batch(&toks, &mut ref_caches, &Hooks::none());
        for &frac in &[1.0f64, 0.5, 0.25] {
            let budget = ((total as f64 * frac) as usize).max(min_fit);
            let tm = Model::open_tiered(&spill, "bench", budget).expect("open tiered");
            let mut caches = prefill_on(&tm);
            let ctx_len = caches[0].len;
            let a = tm.decode_step_batch(&toks, &mut caches, &Hooks::none());
            assert_eq!(
                a.data, ref_logits.data,
                "tiered decode differs from resident at budget fraction {frac}"
            );
            let r = bench(&format!("decode step B={bsz} tiered budget={frac}"), || {
                for c in caches.iter_mut() {
                    c.len = ctx_len;
                }
                std::hint::black_box(tm.decode_step_batch(&toks, &mut caches, &Hooks::none()));
            });
            let st = tm.expert_store_stats();
            let tps = bsz as f64 / (r.mean_ns / 1e9);
            println!(
                "    -> {tps:.0} decode tok/s at {:.0}% budget, hit rate {:.1}%, {} evictions",
                frac * 100.0,
                100.0 * st.hits as f64 / (st.hits + st.misses).max(1) as f64,
                st.evictions
            );
            let mut o = Json::obj();
            o.set("tokens_per_sec", Json::Num(tps))
                .set("budget_bytes", Json::Num(budget as f64))
                .set("total_bytes", Json::Num(total as f64))
                .set("hit_rate", Json::Num(st.hits as f64 / (st.hits + st.misses).max(1) as f64))
                .set("evictions", Json::Num(st.evictions as f64))
                .set("load_stall_secs", Json::Num(st.load_stall_secs))
                .set("peak_resident_bytes", Json::Num(st.peak_resident_bytes as f64));
            json.set(&format!("expert_store/budget{frac}"), o);
        }
        let _ = std::fs::remove_file(&spill);
    }

    // --- SIMD kernel dispatch (`simd_gemm/*`): forced-scalar vs
    // auto-dispatched decode on the same model and caches. Outputs are
    // asserted bitwise-equal first — the kernels share one operation DAG,
    // so the speedup is free of numerical drift — then both levels are
    // timed. On a host without AVX2/NEON both entries run scalar and the
    // ratio sits at ~1.0.
    {
        use eac_moe::model::hooks::Hooks;
        use eac_moe::tensor::simd;
        let auto_kernel = simd::active();
        for &bsz in &[1usize, 4] {
            let mut caches: Vec<eac_moe::model::KvCache> = (0..bsz)
                .map(|b| {
                    let p: Vec<u32> =
                        (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect();
                    let mut c = eac_moe::model::KvCache::new(model.cfg());
                    model.prefill_into_cache(&p, &Hooks::none(), &mut c);
                    c
                })
                .collect();
            let ctx_len = caches[0].len;
            let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
            simd::force(Some(simd::Kernel::Scalar));
            for c in caches.iter_mut() {
                c.len = ctx_len;
            }
            let a = model.decode_step_batch(&toks, &mut caches, &Hooks::none());
            simd::force(None);
            for c in caches.iter_mut() {
                c.len = ctx_len;
            }
            let b = model.decode_step_batch(&toks, &mut caches, &Hooks::none());
            assert_eq!(
                a.data, b.data,
                "scalar and {} decode logits must be bitwise equal",
                auto_kernel.name()
            );
            simd::force(Some(simd::Kernel::Scalar));
            let rs = bench(&format!("decode step B={bsz} forced-scalar @ctx64"), || {
                for c in caches.iter_mut() {
                    c.len = ctx_len;
                }
                std::hint::black_box(model.decode_step_batch(
                    &toks,
                    &mut caches,
                    &Hooks::none(),
                ));
            });
            simd::force(None);
            let rv = bench(
                &format!("decode step B={bsz} simd ({}) @ctx64", auto_kernel.name()),
                || {
                    for c in caches.iter_mut() {
                        c.len = ctx_len;
                    }
                    std::hint::black_box(model.decode_step_batch(
                        &toks,
                        &mut caches,
                        &Hooks::none(),
                    ));
                },
            );
            let scalar_tps = bsz as f64 / (rs.mean_ns / 1e9);
            let simd_tps = bsz as f64 / (rv.mean_ns / 1e9);
            println!(
                "    -> {simd_tps:.0} tok/s ({}) vs {scalar_tps:.0} tok/s scalar: {:.2}x",
                auto_kernel.name(),
                simd_tps / scalar_tps
            );
            let mut o = Json::obj();
            o.set("scalar_tps", Json::Num(scalar_tps))
                .set("simd_tps", Json::Num(simd_tps))
                .set("simd_over_scalar", Json::Num(simd_tps / scalar_tps))
                .set("kernel", Json::Str(auto_kernel.name().into()));
            json.set(&format!("simd_gemm/b{bsz}"), o);
        }
        simd::force(None);
    }

    // --- KV cache (`kv_cache/*`): chunked growth + int8 storage. Reports
    // actual cache bytes after a 64-token prefill against the eager
    // n_layers x max_seq x d_model worst case the seed allocated up
    // front, decode tok/s at both precisions, and the decode-path
    // perplexity delta int8 quantization costs (f32 KV is bit-identical
    // to the cacheless forward, so its ppl is the reference).
    {
        use eac_moe::model::hooks::Hooks;
        use eac_moe::model::{KvCache, KvPrecision};
        let cfgr = model.cfg();
        let eager_bytes = cfgr.n_layers * cfgr.max_seq * cfgr.d_model * 2 * 4;
        let prompt: Vec<u32> = (0..64u32).map(|i| (i * 7) % 512).collect();
        for (name, prec, bits) in
            [("f32", KvPrecision::F32, 32u32), ("int8", KvPrecision::Int8, 8)]
        {
            let mut c = KvCache::with_precision(cfgr, prec);
            model.prefill_into_cache(&prompt, &Hooks::none(), &mut c);
            let cache_bytes = c.bytes();
            let ctx_len = c.len;
            let r = bench(&format!("decode step kv-{name} @ctx64"), || {
                c.len = ctx_len;
                std::hint::black_box(model.decode_step(1, &mut c, &Hooks::none()));
            });
            let tps = 1.0 / (r.mean_ns / 1e9);
            println!(
                "    -> kv-{name}: {:.2} MB cached (eager worst case {:.2} MB), {tps:.0} tok/s",
                cache_bytes as f64 / 1e6,
                eager_bytes as f64 / 1e6
            );
            let mut o = Json::obj();
            o.set("cache_bytes", Json::Num(cache_bytes as f64))
                .set("eager_bytes", Json::Num(eager_bytes as f64))
                .set("tokens_per_sec", Json::Num(tps));
            json.set(&format!("kv_cache/{bits}bit"), o);
        }
        let stream: Vec<u32> = (0..96u32).map(|i| (i * 13 + 5) % 512).collect();
        let decode_ppl = |prec: KvPrecision| -> f64 {
            let mut c = KvCache::with_precision(cfgr, prec);
            let mut logp = vec![0f32; cfgr.vocab];
            let mut nll = 0.0f64;
            for w in stream.windows(2) {
                let l = model.decode_step(w[0], &mut c, &Hooks::none());
                eac_moe::tensor::ops::log_softmax_into(&l, &mut logp);
                nll -= logp[w[1] as usize] as f64;
            }
            (nll / (stream.len() - 1) as f64).exp()
        };
        let ppl32 = decode_ppl(KvPrecision::F32);
        let ppl8 = decode_ppl(KvPrecision::Int8);
        println!(
            "    -> decode ppl: f32 {ppl32:.4} vs int8 {ppl8:.4} ({:+.3}% rel)",
            100.0 * (ppl8 - ppl32) / ppl32
        );
        let mut o = Json::obj();
        o.set("ppl_kv32", Json::Num(ppl32))
            .set("ppl_kv8", Json::Num(ppl8))
            .set("ppl_rel_delta", Json::Num((ppl8 - ppl32) / ppl32));
        json.set("kv_cache/decode_ppl", o);
    }

    // --- Expert merging (`merge/*`): decode throughput and routed-expert
    // footprint at merge thresholds {1.0, 0.9, 0.7} on synthesized
    // near-duplicate expert pairs. Threshold 1.0 is the bit-identity
    // anchor (asserted against the unmerged model before timing); lower
    // thresholds halve the routed expert count and report the byte and
    // tok/s effect of serving cluster bases + low-rank deltas.
    {
        use eac_moe::model::hooks::Hooks;
        use eac_moe::prune::{
            merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig,
        };
        let mut base_w = model.weights.clone();
        synthesize_mergeable_pairs(&mut base_w, 0.05, 3);
        let base = Model::new(base_w.clone());
        let bsz = 4usize;
        let prompts: Vec<Vec<u32>> = (0..bsz)
            .map(|b| (0..64u32).map(|i| (i * 7 + b as u32 * 13) % 512).collect())
            .collect();
        let toks: Vec<u32> = (0..bsz as u32).map(|b| b * 31 % 512).collect();
        let prefill_on = |m: &Model| -> Vec<eac_moe::model::KvCache> {
            prompts
                .iter()
                .map(|p| {
                    let mut c = eac_moe::model::KvCache::new(m.cfg());
                    m.prefill_into_cache(p, &Hooks::none(), &mut c);
                    c
                })
                .collect()
        };
        let mut ref_caches = prefill_on(&base);
        let ref_logits = base.decode_step_batch(&toks, &mut ref_caches, &Hooks::none());
        for &threshold in &[1.0f32, 0.9, 0.7] {
            let mut w = base_w.clone();
            let rep = merge_experts(
                &mut w,
                &uniform_frequencies(cfg.n_layers, cfg.n_experts),
                &MergeConfig::at_threshold(threshold),
            );
            let routed_bytes = w.routed_expert_bytes();
            let mm = Model::new(w);
            let mut caches = prefill_on(&mm);
            let ctx_len = caches[0].len;
            if threshold >= 1.0 {
                let a = mm.decode_step_batch(&toks, &mut caches, &Hooks::none());
                assert_eq!(
                    a.data, ref_logits.data,
                    "threshold=1.0 merged decode differs from unmerged"
                );
            }
            let r = bench(&format!("decode step B={bsz} merged t={threshold}"), || {
                for c in caches.iter_mut() {
                    c.len = ctx_len;
                }
                std::hint::black_box(mm.decode_step_batch(&toks, &mut caches, &Hooks::none()));
            });
            let tps = bsz as f64 / (r.mean_ns / 1e9);
            println!(
                "    -> t={threshold}: {} -> {} experts, {:.2} MB routed, {tps:.0} decode tok/s",
                rep.experts_before,
                rep.experts_after,
                routed_bytes as f64 / 1e6
            );
            let mut o = Json::obj();
            o.set("experts_before", Json::Num(rep.experts_before as f64))
                .set("experts_after", Json::Num(rep.experts_after as f64))
                .set("routed_bytes", Json::Num(routed_bytes as f64))
                .set("tokens_per_sec", Json::Num(tps));
            json.set(&format!("merge/t{threshold}"), o);
        }
    }

    // --- Decode step (kv-cache path; quantization's bandwidth-bound case).
    let mut cache = eac_moe::model::KvCache::new(model.cfg());
    for &t in tokens.iter().take(64) {
        model.decode_step(t, &mut cache, &eac_moe::model::hooks::Hooks::none());
    }
    let ctx = cache.len;
    bench("decode step @ctx64", || {
        cache.len = ctx; // rewind instead of cloning the cache per call
        std::hint::black_box(model.decode_step(
            1,
            &mut cache,
            &eac_moe::model::hooks::Hooks::none(),
        ));
    });
    let mut c2 = eac_moe::model::KvCache::new(packed_model.cfg());
    for &t in tokens.iter().take(64) {
        packed_model.decode_step(t, &mut c2, &eac_moe::model::hooks::Hooks::none());
    }
    bench("decode step @ctx64 packed 4-bit experts", || {
        c2.len = ctx;
        std::hint::black_box(packed_model.decode_step(
            1,
            &mut c2,
            &eac_moe::model::hooks::Hooks::none(),
        ));
    });

    // --- Streaming/SLO serving (`serve_slo/*`): one small open-loop
    // Poisson burst (bimodal prompts) served twice on the same schedule —
    // monolithic prefill vs chunked-and-interleaved — reporting the
    // p50/p95/p99 TTFT and ITL tails plus the short-request p99 TTFT the
    // chunking exists to move. Outputs are asserted token-identical across
    // the two runs (chunking is scheduling-only), so the entries measure
    // pure latency shape. CI asserts these keys exist before appending to
    // BENCH_TRAJECTORY.json.
    {
        use eac_moe::serve::workload::{self, LenDist, WorkloadSpec};
        use eac_moe::serve::{BatchPolicy, Engine, EngineConfig};
        use std::time::Duration;
        let spec = WorkloadSpec {
            n_requests: 12,
            rate_per_sec: 400.0,
            prompt_len: LenDist::Bimodal { short: 8, long: 96, p_short: 0.75 },
            decode_len: LenDist::Fixed(4),
            tenants: 1,
            vocab: 512,
            seed: 11,
            deadline_budget: None,
        };
        let arrivals = workload::generate(&spec);
        let short_ids: Vec<u64> = arrivals
            .iter()
            .filter(|t| t.req.tokens.len() == 8)
            .map(|t| t.req.id)
            .collect();
        let pctl = |mut v: Vec<f64>, p: f64| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v.sort_by(|a, b| a.total_cmp(b));
            v[((p * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)]
        };
        let mut outputs = Vec::new();
        let mut short_p99 = Vec::new();
        for (name, chunk) in [("monolithic", 0usize), ("chunk32", 32)] {
            let engine = Engine::new(
                Model::new(model.weights.clone()),
                EngineConfig {
                    batch: BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_micros(100),
                        ..Default::default()
                    },
                    workers: 1,
                    prefill_chunk: chunk,
                    ..Default::default()
                },
            );
            let (resps, m) = engine.serve_timed(arrivals.clone());
            assert_eq!(resps.len(), spec.n_requests);
            let mut out: Vec<(u64, u32, Vec<u32>)> =
                resps.iter().map(|r| (r.id, r.next_token, r.generated.clone())).collect();
            out.sort_by_key(|(id, _, _)| *id);
            outputs.push(out);
            let sp99 = pctl(
                resps
                    .iter()
                    .filter(|r| short_ids.contains(&r.id))
                    .map(|r| r.ttft_secs * 1e3)
                    .collect(),
                0.99,
            );
            short_p99.push(sp99);
            println!(
                "serve_slo {name}: ttft p50={:.1} p95={:.1} p99={:.1}ms | itl p99={:.1}ms | short p99={sp99:.1}ms",
                m.ttft.percentile_ms(0.5),
                m.ttft.percentile_ms(0.95),
                m.ttft.percentile_ms(0.99),
                m.itl.percentile_ms(0.99),
            );
            let mut o = Json::obj();
            o.set("ttft_p50_ms", Json::Num(m.ttft.percentile_ms(0.5)))
                .set("ttft_p95_ms", Json::Num(m.ttft.percentile_ms(0.95)))
                .set("ttft_p99_ms", Json::Num(m.ttft.percentile_ms(0.99)))
                .set("itl_p50_ms", Json::Num(m.itl.percentile_ms(0.5)))
                .set("itl_p95_ms", Json::Num(m.itl.percentile_ms(0.95)))
                .set("itl_p99_ms", Json::Num(m.itl.percentile_ms(0.99)))
                .set("short_ttft_p99_ms", Json::Num(sp99));
            json.set(&format!("serve_slo/{name}"), o);
        }
        assert_eq!(
            outputs[0], outputs[1],
            "chunked prefill changed tokens — it must be scheduling-only"
        );
        let mut o = Json::obj();
        o.set(
            "chunked_over_monolithic",
            Json::Num(short_p99[1] / short_p99[0].max(1e-9)),
        );
        json.set("serve_slo/short_ttft_p99", o);
    }

    if let Err(e) = eac_moe::report::save_result("bench_perf", &json) {
        eprintln!("warning: could not write results/bench_perf.json: {e:#}");
    } else {
        println!("wrote results/bench_perf.json");
    }
}
