//! Performance micro-benches for the hot paths (EXPERIMENTS.md §Perf):
//! native GEMM, fused packed dequant-matmul, GPTQ per-layer, model prefill
//! (dense vs packed weights), PESF overhead. `harness = false` — uses the
//! in-crate timing harness (criterion is not in the offline registry).
//!
//! Emits `results/bench_perf.json` with the dense-vs-packed GEMM and
//! end-to-end prefill numbers, same shape as the bench_tables outputs.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::quant::gptq::{gptq_quantize_mat, GptqConfig, Hessian};
use eac_moe::quant::pack::PackedMat;
use eac_moe::quant::quantizer::{GroupQuant, QuantConfig};
use eac_moe::tensor::{matmul, Mat, Pcg64};
use eac_moe::util::json::Json;
use eac_moe::util::timing::bench;

fn main() {
    println!("== bench_perf (EAC_MOE_BENCH_MS={}ms/case) ==",
        std::env::var("EAC_MOE_BENCH_MS").unwrap_or_else(|_| "2000".into()));
    let mut rng = Pcg64::seeded(1);
    let mut json = Json::obj();

    // --- GEMM: the prefill workhorse (tokens x d_model @ d_model x d_ff).
    for &(m, k, n) in &[(512usize, 128usize, 256usize), (128, 128, 512), (1, 128, 512)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let r = bench(&format!("matmul {m}x{k}x{n}"), || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);
    }

    // --- Dense GEMM vs fused packed dequant-GEMM at 2 and 4 bits.
    // The fused kernel unpacks K-tiles into a reused panel, so its cost
    // should sit within ~1.5-2x of dense at batch M, not the ~column-count
    // multiple the old per-call unpack paid.
    let (k, n) = (128usize, 512usize);
    let w = Mat::randn(k, n, 1.0, &mut rng);
    for &bits in &[2u32, 4] {
        let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 128));
        let packed = PackedMat::pack(&gq);
        let dq = gq.dequantize();
        for &m in &[1usize, 16, 512] {
            let x = Mat::randn(m, k, 1.0, &mut rng);
            let rp = bench(&format!("packed{bits} fused dequant-matmul m={m}"), || {
                std::hint::black_box(packed.matmul_dequant(&x));
            });
            let rd = bench(&format!("dense matmul (pre-dequantized) m={m}"), || {
                std::hint::black_box(matmul(&x, &dq));
            });
            let ru = bench(&format!("dequant-then-matmul          m={m}"), || {
                let dq = gq.dequantize();
                std::hint::black_box(matmul(&x, &dq));
            });
            println!("    -> packed/dense ratio: {:.2}x", rp.mean_ns / rd.mean_ns);
            let mut o = Json::obj();
            o.set("fused_ns", Json::Num(rp.mean_ns))
                .set("dense_ns", Json::Num(rd.mean_ns))
                .set("unpack_per_call_ns", Json::Num(ru.mean_ns))
                .set("fused_over_dense", Json::Num(rp.mean_ns / rd.mean_ns));
            json.set(&format!("gemm/{bits}bit/m{m}"), o);
        }
    }

    // --- GPTQ one expert matrix (the Table-7 dominant cost).
    let x = Mat::randn(512, 128, 1.0, &mut rng);
    let mut h = Hessian::new(128);
    h.update(&x);
    let w = Mat::randn(128, 256, 1.0, &mut rng);
    bench("gptq 128x256 @3bit g128", || {
        std::hint::black_box(gptq_quantize_mat(&w, &h, GptqConfig::new(3, 128)));
    });

    // --- Model prefill (mixtral-mini shape): dense, packed 4-bit, PESF.
    let cfg = ModelConfig {
        name: "bench".into(),
        n_layers: 4,
        d_model: 128,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 512,
        max_seq: 512,
    };
    let model = Model::new(Weights::init(&cfg, 2));
    let mut packed_weights = model.weights.clone();
    packed_weights.pack_experts_rtn(4, 128);
    let packed_model = Model::new(packed_weights);
    let tokens: Vec<u32> = (0..256u32).map(|i| (i * 7) % 512).collect();
    let rd = bench("prefill 256 tok dense (mixtral-mini shape)", || {
        std::hint::black_box(model.forward(&tokens));
    });
    let rp = bench("prefill 256 tok packed 4-bit experts", || {
        std::hint::black_box(packed_model.forward(&tokens));
    });
    println!(
        "    -> packed/dense prefill ratio: {:.2}x  (resident weights {:.2} MB vs {:.2} MB)",
        rp.mean_ns / rd.mean_ns,
        packed_model.weights.storage_bytes() as f64 / 1e6,
        model.weights.storage_bytes() as f64 / 1e6
    );
    let mut o = Json::obj();
    o.set("dense_ns", Json::Num(rd.mean_ns))
        .set("packed_ns", Json::Num(rp.mean_ns))
        .set("packed_over_dense", Json::Num(rp.mean_ns / rd.mean_ns))
        .set("dense_weight_bytes", Json::Num(model.weights.storage_bytes() as f64))
        .set("packed_weight_bytes", Json::Num(packed_model.weights.storage_bytes() as f64));
    json.set("prefill/256tok", o);
    bench("prefill 256 tok + PESF(0.5)", || {
        let hooks = eac_moe::model::hooks::Hooks {
            pesf_alpha: Some(0.5),
            ..Default::default()
        };
        std::hint::black_box(model.forward_with_hooks(&tokens, &hooks));
    });

    // --- Decode step (kv-cache path; quantization's bandwidth-bound case).
    let mut cache = eac_moe::model::KvCache::new(model.cfg());
    for &t in tokens.iter().take(64) {
        model.decode_step(t, &mut cache, &eac_moe::model::hooks::Hooks::none());
    }
    bench("decode step @ctx64", || {
        let mut c2 = eac_moe::model::KvCache::new(model.cfg());
        c2.len = cache.len;
        for li in 0..cfg.n_layers {
            c2.k[li] = cache.k[li].clone();
            c2.v[li] = cache.v[li].clone();
        }
        std::hint::black_box(model.decode_step(1, &mut c2, &eac_moe::model::hooks::Hooks::none()));
    });
    let mut c2 = eac_moe::model::KvCache::new(packed_model.cfg());
    for &t in tokens.iter().take(64) {
        packed_model.decode_step(t, &mut c2, &eac_moe::model::hooks::Hooks::none());
    }
    bench("decode step @ctx64 packed 4-bit experts", || {
        let mut c3 = eac_moe::model::KvCache::new(packed_model.cfg());
        c3.len = c2.len;
        for li in 0..cfg.n_layers {
            c3.k[li] = c2.k[li].clone();
            c3.v[li] = c2.v[li].clone();
        }
        std::hint::black_box(packed_model.decode_step(
            1,
            &mut c3,
            &eac_moe::model::hooks::Hooks::none(),
        ));
    });

    if let Err(e) = eac_moe::report::save_result("bench_perf", &json) {
        eprintln!("warning: could not write results/bench_perf.json: {e:#}");
    } else {
        println!("wrote results/bench_perf.json");
    }
}
