//! Offline stand-in for the `anyhow` crate, API-compatible with the subset
//! this repository uses: [`Error`], [`Result`], the [`Context`] extension
//! trait on `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. The build environment has no registry access, so the real crate
//! is replaced by this ~150-line shim (a `path` dependency in the root
//! `Cargo.toml`); swapping back to crates.io anyhow is a one-line change.
//!
//! Fidelity notes: the error chain is captured as strings at construction
//! time (no downcasting), `{e}` prints the outermost message, `{e:#}`
//! prints the full `outer: inner: ...` chain, and `{e:?}` prints the
//! anyhow-style "Caused by" listing — the three formats used in this crate.

use std::fmt;

/// String-chained error value. Like `anyhow::Error`, it deliberately does
/// **not** implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    /// Outermost message first, followed by its causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("open config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("empty")?;
            ensure!(v < 10, "too big: {v}");
            if v == 7 {
                bail!("unlucky {}", v);
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "empty");
        assert_eq!(format!("{}", f(Some(12)).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(Some(7)).unwrap_err()), "unlucky 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
