//! Integration: the memory-budgeted tiered ExpertStore.
//!
//! Acceptance (ISSUE 5): a packed model served under a budget ≤ 50% of its
//! total expert bytes produces **bit-identical** responses to the
//! unbudgeted `Resident` store — asserted across budget fractions
//! {100%, 50%, smallest-that-fits} × pool sizes {1, 4} — while
//! `ServeMetrics` shows `resident_expert_bytes` (and its peak) ≤ the
//! configured budget and a nonzero eviction count. Tiering changes *when*
//! an expert is resident, never its math.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::serve::{BatchPolicy, Engine, EngineConfig, PrunePolicy, Request};
use eac_moe::prune::pesf::PesfConfig;
use std::time::Duration;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "store-itest".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 8,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 128,
        max_seq: 128,
    }
}

/// Packed 4-bit experts — the compressed serving shape the budget manages.
fn packed_weights() -> Weights {
    let mut w = Weights::init(&cfg(), 93);
    w.pack_experts_rtn(4, 16);
    w
}

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("eac_moe_estore_{tag}_{}.bin", std::process::id()))
}

fn reqs(n: u64, len: usize, decode: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(i, (0..len as u32).map(|t| (t * 13 + i as u32 * 7) % 128).collect())
                .with_decode(decode)
        })
        .collect()
}

type Fingerprint = Vec<(u64, Vec<u32>, u32, u32)>;

fn serve_fingerprint(model: Model, threads: usize) -> (Fingerprint, eac_moe::serve::ServeMetrics) {
    let e = Engine::new(
        model,
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 2,
            prune: PrunePolicy::None,
            threads: Some(threads),
        },
    );
    let (mut out, m) = e.serve(reqs(8, 20, 6));
    out.sort_by_key(|r| r.id);
    let fp = out
        .into_iter()
        .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob.to_bits()))
        .collect();
    (fp, m)
}

#[test]
fn budgeted_serving_bit_identical_across_budgets_and_pools() {
    let w = packed_weights();
    let path = temp_path("accept");
    w.save(&path).unwrap();
    let total = Model::new(w.clone()).expert_store_stats().total_bytes;
    let min_fit = w.max_expert_bytes();
    assert!(min_fit * 2 < total / 2, "model too small for a meaningful 50% budget");
    for threads in [1usize, 4] {
        let (want, mr) = serve_fingerprint(Model::new(w.clone()), threads);
        assert!(want.iter().all(|(_, g, _, _)| g.len() == 6));
        // Resident store: no budget, experts fully resident, no traffic.
        assert_eq!(mr.expert_budget_bytes, 0);
        assert_eq!(mr.resident_expert_bytes, total);
        assert_eq!(mr.total_expert_bytes, total);
        assert_eq!(mr.expert_evictions, 0);
        for budget in [total, total / 2, min_fit] {
            let tiered = Model::open_tiered(&path, "store-itest", budget).unwrap();
            let (got, mt) = serve_fingerprint(tiered, threads);
            assert_eq!(got, want, "outputs differ at budget {budget} threads {threads}");
            // The budget is a hard ceiling on what the store holds.
            assert_eq!(mt.expert_budget_bytes, budget);
            assert!(mt.resident_expert_bytes <= budget);
            assert!(mt.peak_resident_expert_bytes <= budget);
            assert_eq!(mt.total_expert_bytes, total);
            assert!(mt.expert_misses > 0, "a cold store must load on demand");
            if budget < total {
                assert!(
                    mt.expert_evictions > 0,
                    "budget {budget} < total {total} must evict"
                );
            }
            // The paper's memory axis, observable end to end: the served
            // footprint under the 50% budget is genuinely smaller than
            // fully resident.
            if budget <= total / 2 {
                assert!(mt.resident_weight_bytes < mr.resident_weight_bytes);
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn budgeted_serving_composes_with_pesf_decode() {
    // PESF + tiered store: pruned experts are never fetched, and outputs
    // under a tight budget still match the resident PESF engine exactly.
    let w = packed_weights();
    let path = temp_path("pesf");
    w.save(&path).unwrap();
    let prune = PrunePolicy::Pesf(PesfConfig { alpha: 0.9, refresh_every: 2, window: 8 });
    let run = |model: Model| {
        let e = Engine::new(
            model,
            EngineConfig {
                batch: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_micros(100),
                    ..Default::default()
                },
                workers: 1,
                prune,
                threads: Some(2),
            },
        );
        let (mut out, m) = e.serve(reqs(6, 24, 5));
        out.sort_by_key(|r| r.id);
        let fp: Fingerprint = out
            .into_iter()
            .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob.to_bits()))
            .collect();
        (fp, m)
    };
    let (want, mr) = run(Model::new(w.clone()));
    assert!(mr.mean_prune_rate > 0.0);
    let budget = w.max_expert_bytes();
    let (got, mt) = run(Model::open_tiered(&path, "store-itest", budget).unwrap());
    assert_eq!(got, want, "tiered PESF serving must match resident PESF serving");
    assert!(mt.mean_decode_prune_rate > 0.0);
    assert!(mt.peak_resident_expert_bytes <= budget);
    assert!(mt.expert_evictions > 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn dense_models_tier_too() {
    // The store is storage-form agnostic: dense (uncompressed) experts
    // roundtrip through the byte-range loader bitwise as well.
    let w = Weights::init(&cfg(), 94);
    let path = temp_path("dense");
    w.save(&path).unwrap();
    let (want, _) = serve_fingerprint(Model::new(w.clone()), 2);
    let budget = w.max_expert_bytes() * 3;
    let (got, mt) = serve_fingerprint(Model::open_tiered(&path, "store-itest", budget).unwrap(), 2);
    assert_eq!(got, want);
    assert!(mt.expert_evictions > 0);
    assert!(mt.peak_resident_expert_bytes <= budget);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn into_tiered_spill_roundtrip_matches_original() {
    // The CLI path: a resident model spilled + reopened under a budget
    // serves identically to its original self.
    let w = packed_weights();
    let (want, _) = serve_fingerprint(Model::new(w.clone()), 2);
    let spill = temp_path("spill");
    let total = Model::new(w.clone()).expert_store_stats().total_bytes;
    let tiered = Model::new(w).into_tiered(total / 2, &spill).unwrap();
    assert!(tiered.store.is_tiered());
    let (got, mt) = serve_fingerprint(tiered, 2);
    assert_eq!(got, want);
    assert!(mt.expert_budget_bytes == total / 2);
    assert!(mt.summary().contains("budget="), "{}", mt.summary());
    let _ = std::fs::remove_file(&spill);
}
