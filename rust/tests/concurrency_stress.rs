//! Concurrency stress: many producers hammering the batcher against
//! concurrent consumers (blocking `next_batch` and non-blocking
//! `try_take`), with and without a queue bound. These tests are the
//! ThreadSanitizer workload for the serving layer — they chase the
//! races the unit tests can't reach (push vs drain vs close
//! interleavings) and assert request conservation under all of them:
//! every submitted request is either delivered exactly once or handed
//! back to its producer, never both and never lost.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::serve::{BatchPolicy, Batcher, Engine, EngineConfig, PrunePolicy, Request};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn req(id: u64) -> Request {
    Request::new(id, vec![1, 2, 3])
}

/// Bounded queue, multi-producer vs mixed consumers. Producers keep the
/// ids of rejected pushes; consumers record delivered ids. Conservation:
/// delivered ∪ rejected == submitted, with no id on both sides and no
/// duplicates.
#[test]
fn bounded_queue_push_vs_try_take_conserves_requests() {
    let n_producers: u64 = 4;
    let per: u64 = 300;
    let b = Arc::new(Batcher::new(BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_micros(50),
        max_queue: 8,
    }));
    let done = Arc::new(AtomicBool::new(false));

    let mut producers = Vec::new();
    for p in 0..n_producers {
        let bb = b.clone();
        producers.push(std::thread::spawn(move || {
            let mut rejected = Vec::new();
            for i in 0..per {
                if let Err(r) = bb.push(req(p * 10_000 + i)) {
                    rejected.push(r.id);
                }
            }
            rejected
        }));
    }

    // One blocking consumer (drains until close) and one spinning
    // try_take consumer (exits once producers are done and the queue is
    // observed empty — try_take never blocks, so this is the racy side).
    let blocking = {
        let bb = b.clone();
        std::thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some(batch) = bb.next_batch() {
                seen.extend(batch.into_iter().map(|r| r.id));
            }
            seen
        })
    };
    let spinning = {
        let bb = b.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut seen = Vec::new();
            loop {
                let got = bb.try_take(3);
                let empty = got.is_empty();
                seen.extend(got.into_iter().map(|r| r.id));
                if empty && done.load(Ordering::SeqCst) && bb.is_empty() {
                    return seen;
                }
                if empty {
                    std::thread::yield_now();
                }
            }
        })
    };

    let mut rejected: Vec<u64> = Vec::new();
    for p in producers {
        rejected.extend(p.join().unwrap());
    }
    done.store(true, Ordering::SeqCst);
    b.close();
    let mut delivered = blocking.join().unwrap();
    delivered.extend(spinning.join().unwrap());

    // With an 8-deep queue and 1200 fast pushes, some rejections are
    // effectively certain — but don't assert on scheduling luck, only on
    // conservation.
    let mut all: Vec<u64> = delivered.iter().chain(rejected.iter()).copied().collect();
    all.sort_unstable();
    let mut want: Vec<u64> =
        (0..n_producers).flat_map(|p| (0..per).map(move |i| p * 10_000 + i)).collect();
    want.sort_unstable();
    assert_eq!(all, want, "each request must be delivered XOR rejected, exactly once");
}

/// Unbounded (default) queue: every push is accepted even under
/// contention, and every accepted request is delivered exactly once.
#[test]
fn unbounded_queue_accepts_and_delivers_everything() {
    let n_producers: u64 = 4;
    let per: u64 = 250;
    let b = Arc::new(Batcher::new(BatchPolicy {
        max_batch: 3,
        max_wait: Duration::from_micros(50),
        ..Default::default()
    }));
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let bb = b.clone();
        producers.push(std::thread::spawn(move || {
            for i in 0..per {
                assert!(bb.push(req(p * 10_000 + i)).is_ok());
            }
        }));
    }
    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = bb.next_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    b.close();
    let mut seen: Vec<u64> = Vec::new();
    for c in consumers {
        seen.extend(c.join().unwrap());
    }
    seen.sort_unstable();
    let mut want: Vec<u64> =
        (0..n_producers).flat_map(|p| (0..per).map(move |i| p * 10_000 + i)).collect();
    want.sort_unstable();
    assert_eq!(seen, want);
}

/// Close racing in-flight pushes: whatever `push` accepted must come out
/// the other side, and whatever it rejected must not.
#[test]
fn close_mid_stream_conserves_accepted_requests() {
    for _ in 0..20 {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(20),
            ..Default::default()
        }));
        let producer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut accepted = Vec::new();
                for i in 0..200u64 {
                    if bb.push(req(i)).is_ok() {
                        accepted.push(i);
                    }
                }
                accepted
            })
        };
        let closer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                std::thread::yield_now();
                bb.close();
            })
        };
        let consumer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = bb.next_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            })
        };
        let mut accepted = producer.join().unwrap();
        closer.join().unwrap();
        let mut seen = consumer.join().unwrap();
        accepted.sort_unstable();
        seen.sort_unstable();
        assert_eq!(seen, accepted, "close must not lose accepted or leak rejected requests");
    }
}

/// End-to-end: the engine's offline `serve` honors a tiny queue bound by
/// waiting out backpressure, so a closed request set is still served
/// exactly once per request.
#[test]
fn engine_serves_closed_set_through_tiny_bounded_queue() {
    let cfg = ModelConfig {
        name: "stress".into(),
        n_layers: 2,
        d_model: 16,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        n_shared: 0,
        n_heads: 2,
        vocab: 64,
        max_seq: 64,
    };
    let engine = Engine::new(
        Model::new(Weights::init(&cfg, 11)),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_micros(50),
                max_queue: 2,
            },
            workers: 2,
            prune: PrunePolicy::None,
            ..Default::default()
        },
    );
    let reqs: Vec<Request> = (0..24)
        .map(|i| Request::new(i, (0..8u32).map(|t| (t * 7 + i as u32) % 64).collect()))
        .collect();
    let (out, metrics) = engine.serve(reqs);
    let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..24).collect::<Vec<u64>>());
    assert_eq!(metrics.total_requests, 24);
}
