//! Thread-count invariance — the determinism guarantee the worker pool is
//! built around: pool size changes *where* work runs, never what it
//! computes. Prefill logits, decode tokens, and packed-path outputs must
//! be **bit-identical** at pool sizes 1, 2, and 8, for dense and packed
//! weights alike. (Size 1 is exactly sequential execution — no worker
//! threads exist — so these tests pin the parallel paths to the
//! sequential semantics, not just to each other.)

use eac_moe::model::hooks::Hooks;
use eac_moe::model::{KvCache, Model, ModelConfig, Weights};
use eac_moe::tensor::pool::ThreadPool;
use std::sync::Arc;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "tinv".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 8,
        top_k: 2,
        n_shared: 1,
        n_heads: 4,
        vocab: 96,
        max_seq: 96,
    }
}

fn weight_variants() -> Vec<(&'static str, Weights)> {
    let dense = Weights::init(&cfg(), 61);
    let mut packed = dense.clone();
    packed.pack_experts_rtn(4, 16);
    vec![("dense", dense), ("packed", packed)]
}

/// Prompt long enough (≥ 64 rows) to engage the row-parallel GEMM path on
/// top of expert- and head-level tasks.
fn prompt() -> Vec<u32> {
    (0..80u32).map(|i| (i * 11 + 3) % 96).collect()
}

const POOL_SIZES: [usize; 3] = [1, 2, 8];

#[test]
fn prefill_logits_bitwise_invariant() {
    for (name, weights) in weight_variants() {
        let mut base: Option<Vec<f32>> = None;
        for threads in POOL_SIZES {
            let m = Model::with_pool(weights.clone(), Arc::new(ThreadPool::new(threads)));
            let logits = m.forward(&prompt());
            match &base {
                None => base = Some(logits.data),
                Some(want) => {
                    assert_eq!(&logits.data, want, "{name} prefill differs at threads={threads}")
                }
            }
        }
    }
}

#[test]
fn greedy_decode_tokens_and_logits_bitwise_invariant() {
    for (name, weights) in weight_variants() {
        let mut base: Option<(Vec<u32>, Vec<f32>)> = None;
        for threads in POOL_SIZES {
            let m = Model::with_pool(weights.clone(), Arc::new(ThreadPool::new(threads)));
            let mut cache = KvCache::new(m.cfg());
            let logits = m.prefill_into_cache(&prompt(), &Hooks::none(), &mut cache);
            let mut cur =
                eac_moe::tensor::ops::topk_indices(logits.row(logits.rows - 1), 1)[0] as u32;
            let mut toks = Vec::new();
            let mut last = Vec::new();
            for _ in 0..6 {
                toks.push(cur);
                last = m.decode_step(cur, &mut cache, &Hooks::none());
                cur = eac_moe::tensor::ops::topk_indices(&last, 1)[0] as u32;
            }
            match &base {
                None => base = Some((toks, last)),
                Some((want_toks, want_logits)) => {
                    assert_eq!(&toks, want_toks, "{name} decode tokens differ at threads={threads}");
                    assert_eq!(
                        &last, want_logits,
                        "{name} decode logits differ at threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_decode_bitwise_invariant() {
    // Unequal-length sequences decoded as a batch: every row of every step
    // must match across pool sizes (exercises the chunked per-(seq, head)
    // attention tasks and the cross-batch expert gather).
    let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5], &[7, 11, 13, 17, 19, 23, 29, 31], &[5]];
    for (name, weights) in weight_variants() {
        let mut base: Option<Vec<Vec<f32>>> = None;
        for threads in POOL_SIZES {
            let m = Model::with_pool(weights.clone(), Arc::new(ThreadPool::new(threads)));
            let mut caches: Vec<KvCache> = prompts
                .iter()
                .map(|p| {
                    let mut c = KvCache::new(m.cfg());
                    m.prefill_into_cache(p, &Hooks::none(), &mut c);
                    c
                })
                .collect();
            let mut toks: Vec<u32> = prompts.iter().map(|p| p[0]).collect();
            let mut steps: Vec<Vec<f32>> = Vec::new();
            for _ in 0..4 {
                let logits = m.decode_step_batch(&toks, &mut caches, &Hooks::none());
                for b in 0..toks.len() {
                    toks[b] = eac_moe::tensor::ops::topk_indices(logits.row(b), 1)[0] as u32;
                }
                steps.push(logits.data);
            }
            match &base {
                None => base = Some(steps),
                Some(want) => {
                    assert_eq!(&steps, want, "{name} batched decode differs at threads={threads}")
                }
            }
        }
    }
}
