//! Integration: the full QESC compression pipeline + PESF pruning over a
//! real (randomly-initialized) model, artifact-free. Cross-module
//! invariants that unit tests can't see.

use eac_moe::calib::qesc::{qesc_compress, QescConfig};
use eac_moe::calib::shift::mean_change_rates;
use eac_moe::model::hooks::Hooks;
use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::quant::alloc::Allocator;
use eac_moe::tensor::Pcg64;

fn model(seed: u64) -> Model {
    let cfg = ModelConfig {
        name: "itest".into(),
        n_layers: 3,
        d_model: 32,
        d_ff: 16,
        n_experts: 8,
        top_k: 2,
        n_shared: 1,
        n_heads: 4,
        vocab: 64,
        max_seq: 128,
    };
    Model::new(Weights::init(&cfg, seed))
}

fn seqs(n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.below(64) as u32).collect()).collect()
}

#[test]
fn full_pipeline_bit_settings_are_ordered() {
    // More bits => lower weight-reconstruction error and more storage.
    // (Downstream PPL of a *random-init* net is noise-dominated, so the
    // deterministic invariant is at the weight level; the PPL shape on
    // trained models is covered by `experiment table2`.)
    let m = model(1);
    let calib = seqs(4, 24, 10);
    let eval = seqs(3, 24, 11);
    let mut rows = Vec::new();
    for bits in [2u32, 3, 4] {
        let (q, report) = qesc_compress(&m, &calib, &QescConfig::qesc(bits, 4));
        // Mean MSE across all expert weight matrices vs the original.
        let mut mse = 0f64;
        let mut count = 0usize;
        for (lo, lq) in m.weights.layers.iter().zip(&q.weights.layers) {
            for (eo, eq) in lo.experts().iter().zip(lq.experts()) {
                mse += eo.w1.mse(&eq.w1) as f64 + eo.w2.mse(&eq.w2) as f64
                    + eo.w3.mse(&eq.w3) as f64;
                count += 3;
            }
        }
        rows.push((bits, mse / count as f64, report.compressed_bytes));
        // Quantized model still evaluates finitely.
        assert!(eac_moe::eval::perplexity(&q, &eval).is_finite());
    }
    // Memory: 2 < 3 < 4 bits.
    assert!(rows[0].2 < rows[1].2 && rows[1].2 < rows[2].2, "{rows:?}");
    // Reconstruction error strictly improves with bits.
    assert!(rows[0].1 > rows[1].1 && rows[1].1 > rows[2].1, "{rows:?}");
}

#[test]
fn qesc_reduces_shift_vs_gptq_at_2bit() {
    let m = model(2);
    let calib = seqs(6, 32, 20);
    let eval = seqs(4, 32, 21);
    let (gptq, _) = qesc_compress(&m, &calib, &QescConfig::gptq(2));
    let qesc_cfg = QescConfig { router_steps: 200, ..QescConfig::qesc(2, 4) };
    let (qesc, _) = qesc_compress(&m, &calib, &qesc_cfg);
    let record = |mm: &Model| {
        let h = Hooks::recording(3);
        for s in &eval {
            mm.forward_with_hooks(s, &h);
        }
        h.take_selections().unwrap()
    };
    let fp = record(&m);
    let cg = mean_change_rates(&fp, &record(&gptq));
    let cq = mean_change_rates(&fp, &record(&qesc));
    assert!(
        cq.any_changed <= cg.any_changed + 0.02,
        "QESC must not increase expert-shift: qesc {cq:?} gptq {cg:?}"
    );
}

#[test]
fn mixed_precision_pipeline_end_to_end() {
    let m = model(3);
    let calib = seqs(3, 24, 30);
    for alloc in [
        Allocator::Bsp { hi: 4, lo: 2, hi_count: 4, shared: 8 },
        Allocator::Pmq { avg_bits: 2.5, shared: 3 },
        Allocator::HalfSplit { hi: 3, lo: 2 },
    ] {
        let cfg = QescConfig {
            expert_alloc: alloc,
            calib_router: false,
            ..QescConfig::qesc(2, 4)
        };
        let (q, report) = qesc_compress(&m, &calib, &cfg);
        assert!(report.avg_expert_bits >= 2.0 && report.avg_expert_bits <= 8.0);
        let out = q.forward(&[1, 2, 3, 4, 5]);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn pesf_on_compressed_model_prunes_and_stays_finite() {
    let m = model(4);
    let calib = seqs(3, 24, 40);
    let (q, _) = qesc_compress(&m, &calib, &QescConfig::qesc(3, 4));
    let tokens: Vec<u32> = (0..48).map(|i| (i * 5) % 64).collect();
    let (logits, stats) = eac_moe::prune::pesf::pesf_prefill(
        &q,
        &tokens,
        eac_moe::prune::pesf::PesfConfig { alpha: 0.8, ..Default::default() },
    );
    assert!(logits.data.iter().all(|x| x.is_finite()));
    assert!(stats.prune_rate() > 0.0, "alpha=0.8 must prune something on 8 experts");
    // Dense and alpha->0 outputs agree.
    let (l0, _) = eac_moe::prune::pesf::pesf_prefill(
        &q,
        &tokens,
        eac_moe::prune::pesf::PesfConfig { alpha: 0.0, ..Default::default() },
    );
    let dense = q.forward(&tokens);
    for (a, b) in l0.data.iter().zip(&dense.data) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn compress_report_accounting_consistent() {
    let m = model(5);
    let calib = seqs(2, 16, 50);
    let (_, report) = qesc_compress(&m, &calib, &QescConfig::qesc(2, 4));
    // fp bytes = params * 4.
    assert_eq!(report.fp_bytes, m.weights.param_count() * 4);
    // Compressed must be far below fp32 but above the pure-code floor.
    let floor = m.cfg().expert_param_count() / 4; // 2 bits = 1/16 of fp32... loose floor
    assert!(report.compressed_bytes > floor / 4);
    assert!(report.compressed_bytes < report.fp_bytes / 2);
    assert_eq!(report.router_loss_before.len(), 3);
    assert_eq!(report.router_loss_after.len(), 3);
}
