//! SIMD kernel equivalence — the dispatch layer's correctness contract
//! from the outside: every kernel level (`scalar`, and `avx2`/`neon`
//! where the host supports them) computes **bit-identical** results to an
//! independently written scalar reference, at every odd length; the fused
//! packed GEMM and the whole-model decode path are pinned bitwise across
//! forced kernel levels at every bit-width (the in-process analogue of
//! CI's `EAC_MOE_NO_SIMD=1` rerun); and the opt-in int8 KV cache stays
//! within its documented tolerance on logits and decode-path perplexity.

use eac_moe::model::hooks::Hooks;
use eac_moe::model::{KvCache, KvPrecision, Model, ModelConfig, Weights};
use eac_moe::quant::pack::PackedMat;
use eac_moe::quant::quantizer::{GroupQuant, QuantConfig};
use eac_moe::tensor::{simd, Mat, Pcg64};
use std::sync::Mutex;

/// `simd::force` is process-global; tests that flip it serialize here so
/// parallel test threads never observe each other's override. A poisoned
/// lock is safe to reuse — every kernel level computes the same bits, so
/// a panicked holder cannot leave state behind that changes results.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn force_lock() -> std::sync::MutexGuard<'static, ()> {
    FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Odd, boundary-straddling lengths: empty, sub-lane, one lane, lane ± 1,
/// multiple lanes ± 1, and larger ragged sizes.
const LENGTHS: &[usize] = &[0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 250];

// Independent scalar references, written fresh rather than calling into
// the crate, so a bug shared between `simd`'s scalar and vector paths
// cannot cancel out.

fn ref_axpy(out: &mut [f32], a: f32, x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

fn ref_axpy_i8(out: &mut [f32], a: f32, x: &[i8]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * (v as f32);
    }
}

fn ref_affine(buf: &mut [f32], zero: f32, scale: f32) {
    for v in buf.iter_mut() {
        *v = (*v - zero) * scale;
    }
}

fn ref_bytes_to_f32(src: &[u8], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32;
    }
}

/// The pinned dot semantics: 8 independent lane accumulators over the
/// aligned body, the fixed pairwise reduction tree, sequential tail.
fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
    let n8 = a.len() & !7;
    let mut lanes = [0f32; 8];
    let mut i = 0;
    while i < n8 {
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut s = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
        + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
    for j in n8..a.len() {
        s += a[j] * b[j];
    }
    s
}

fn ref_dot_i8(a: &[f32], k: &[i8]) -> f32 {
    let kf: Vec<f32> = k.iter().map(|&v| v as f32).collect();
    ref_dot(a, &kf)
}

fn floats(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    rng.gaussian_vec(n, 1.0)
}

fn codes(rng: &mut Pcg64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below_usize(255) as i64 - 127) as i8).collect()
}

#[test]
fn every_kernel_matches_reference_bitwise_on_odd_shapes() {
    let _g = force_lock();
    for kernel in simd::available() {
        simd::force(Some(kernel));
        let mut rng = Pcg64::seeded(0xF00D + kernel as u64);
        for &n in LENGTHS {
            let x = floats(&mut rng, n);
            let y = floats(&mut rng, n);
            let q = codes(&mut rng, n);
            let bytes: Vec<u8> = (0..n).map(|_| rng.below_usize(256) as u8).collect();
            let a = 0.73f32;

            let mut got = y.clone();
            let mut want = y.clone();
            simd::axpy(&mut got, a, &x);
            ref_axpy(&mut want, a, &x);
            assert_eq!(got, want, "axpy {} n={n}", kernel.name());

            let mut got = y.clone();
            let mut want = y.clone();
            simd::axpy_i8(&mut got, a, &q);
            ref_axpy_i8(&mut want, a, &q);
            assert_eq!(got, want, "axpy_i8 {} n={n}", kernel.name());

            let mut got = x.clone();
            let mut want = x.clone();
            simd::affine(&mut got, 0.31, 1.7);
            ref_affine(&mut want, 0.31, 1.7);
            assert_eq!(got, want, "affine {} n={n}", kernel.name());

            let mut got = vec![0f32; n];
            let mut want = vec![0f32; n];
            simd::bytes_to_f32(&bytes, &mut got);
            ref_bytes_to_f32(&bytes, &mut want);
            assert_eq!(got, want, "bytes_to_f32 {} n={n}", kernel.name());

            assert_eq!(
                simd::dot(&x, &y).to_bits(),
                ref_dot(&x, &y).to_bits(),
                "dot {} n={n}",
                kernel.name()
            );
            assert_eq!(
                simd::dot_i8(&x, &q).to_bits(),
                ref_dot_i8(&x, &q).to_bits(),
                "dot_i8 {} n={n}",
                kernel.name()
            );
        }
    }
    simd::force(None);
}

/// The fused packed dequant-GEMM must be bitwise-invariant to the kernel
/// level at every supported bit-width, on ragged shapes that leave odd
/// K-tile tails, partial groups, and sub-strip N remainders.
#[test]
fn packed_gemm_bitwise_invariant_across_kernels_at_all_bits() {
    let _g = force_lock();
    let mut rng = Pcg64::seeded(42);
    // (m, k, n, group): deliberately not multiples of tile/strip sizes.
    let shapes = [(1usize, 33usize, 19usize, 16usize), (5, 130, 61, 32), (17, 96, 40, 24)];
    for &bits in &[2u32, 3, 4, 8] {
        for &(m, k, n, group) in &shapes {
            let w = Mat::randn(k, n, 1.0, &mut rng);
            let packed = PackedMat::pack(&GroupQuant::quantize(&w, QuantConfig::new(bits, group)));
            let x = Mat::randn(m, k, 1.0, &mut rng);
            simd::force(Some(simd::Kernel::Scalar));
            let want = packed.matmul_dequant(&x);
            for kernel in simd::available() {
                simd::force(Some(kernel));
                let got = packed.matmul_dequant(&x);
                assert_eq!(
                    got.data,
                    want.data,
                    "packed GEMM differs: {} vs scalar at bits={bits} {m}x{k}x{n} g{group}",
                    kernel.name()
                );
            }
        }
    }
    simd::force(None);
}

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "simdtest".into(),
        n_layers: 2,
        d_model: 24,
        d_ff: 16,
        n_experts: 4,
        top_k: 2,
        n_shared: 1,
        n_heads: 2,
        vocab: 64,
        max_seq: 96,
    }
}

/// Whole-model decode pinned bitwise across kernel levels, for dense and
/// packed weights — the in-process analogue of rerunning the suite under
/// `EAC_MOE_NO_SIMD=1`: forcing scalar must reproduce the SIMD outputs
/// exactly, greedy tokens and final logits alike.
#[test]
fn model_decode_bitwise_invariant_across_kernels() {
    let _g = force_lock();
    let dense = Weights::init(&tiny_cfg(), 7);
    let mut packed = dense.clone();
    packed.pack_experts_rtn(4, 8);
    let prompt: Vec<u32> = (0..40u32).map(|i| (i * 11 + 3) % 64).collect();
    for (name, weights) in [("dense", dense), ("packed", packed)] {
        let m = Model::new(weights);
        let run = || {
            let mut cache = KvCache::new(m.cfg());
            let logits = m.prefill_into_cache(&prompt, &Hooks::none(), &mut cache);
            let mut cur =
                eac_moe::tensor::ops::topk_indices(logits.row(logits.rows - 1), 1)[0] as u32;
            let mut toks = Vec::new();
            let mut last = Vec::new();
            for _ in 0..6 {
                toks.push(cur);
                last = m.decode_step(cur, &mut cache, &Hooks::none());
                cur = eac_moe::tensor::ops::topk_indices(&last, 1)[0] as u32;
            }
            (logits.data, toks, last)
        };
        simd::force(Some(simd::Kernel::Scalar));
        let want = run();
        for kernel in simd::available() {
            simd::force(Some(kernel));
            let got = run();
            assert_eq!(
                got, want,
                "{name} decode differs: {} vs scalar",
                kernel.name()
            );
        }
    }
    simd::force(None);
}

/// Int8 KV is tolerance-pinned, not bitwise: per-step logits stay within
/// a small relative inf-norm of the f32-KV run, and the decode-path
/// perplexity over a fixed stream moves by well under 5%.
#[test]
fn int8_kv_decode_stays_within_tolerance() {
    let cfg = tiny_cfg();
    let m = Model::new(Weights::init(&cfg, 23));
    let stream: Vec<u32> = (0..64u32).map(|i| (i * 13 + 5) % 64).collect();
    let run = |prec: KvPrecision| -> (Vec<Vec<f32>>, f64) {
        let mut cache = KvCache::with_precision(m.cfg(), prec);
        let mut logits = Vec::new();
        let mut logp = vec![0f32; cfg.vocab];
        let mut nll = 0.0f64;
        for w in stream.windows(2) {
            let l = m.decode_step(w[0], &mut cache, &Hooks::none());
            eac_moe::tensor::ops::log_softmax_into(&l, &mut logp);
            nll -= logp[w[1] as usize] as f64;
            logits.push(l);
        }
        (logits, (nll / (stream.len() - 1) as f64).exp())
    };
    let (l32, ppl32) = run(KvPrecision::F32);
    let (l8, ppl8) = run(KvPrecision::Int8);
    for (step, (a, b)) in l32.iter().zip(&l8).enumerate() {
        let scale = a.iter().fold(0f32, |s, &x| s.max(x.abs())).max(1e-6);
        let diff = a.iter().zip(b).fold(0f32, |d, (&x, &y)| d.max((x - y).abs()));
        assert!(
            diff / scale < 0.05,
            "int8 KV logit drift {:.4} at step {step} exceeds 5% of |logits|={scale:.4}",
            diff / scale
        );
    }
    let rel = ((ppl8 - ppl32) / ppl32).abs();
    assert!(
        rel < 0.05,
        "decode ppl moved {:.2}% (f32 {ppl32:.4} -> int8 {ppl8:.4})",
        rel * 100.0
    );
}
