//! Integration: expert merging as a third compression axis — the
//! analyze → merge → remap pipeline end to end.
//!
//! Pins the issue's acceptance contracts:
//! - threshold = 1.0 is bit-identical to the unmerged model (dense and
//!   packed experts, pool sizes 1 and 4);
//! - the merged forward pass equals a manually-remapped reference on a
//!   toy model with duplicated experts;
//! - selection records and PESF masks run over merged ids at the merged
//!   width;
//! - a tiered store at a 50% routed-byte budget (deltas are the eviction
//!   unit; bases stay resident) is bit-identical to resident serving;
//! - a merged model survives a TensorFile save/load round trip with
//!   bit-identical outputs, and serving metrics surface the reduced
//!   expert count.

use eac_moe::model::{Hooks, Model, ModelConfig, Weights};
use eac_moe::prune::pesf::{PesfConfig, PesfDecodeState};
use eac_moe::prune::{merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig};
use eac_moe::serve::{Engine, EngineConfig, Request};
use eac_moe::tensor::ops::{add_inplace, axpy, softmax_inplace, topk_indices};
use eac_moe::tensor::{matmul, Mat, Pcg64, ThreadPool};
use std::sync::Arc;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "merge-itest".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 8,
        top_k: 2,
        n_shared: 1,
        n_heads: 4,
        vocab: 64,
        max_seq: 64,
    }
}

fn seqs() -> Vec<Vec<u32>> {
    (0..4u32).map(|i| (0..24).map(|t| (t * 13 + i * 7) % 64).collect()).collect()
}

/// Merge at `threshold` with uniform frequencies, asserting it actually
/// merged when expected to.
fn merged_weights(base: &Weights, threshold: f32) -> Weights {
    let mut w = base.clone();
    let rep = merge_experts(
        &mut w,
        &uniform_frequencies(w.cfg.n_layers, w.cfg.n_experts),
        &MergeConfig::at_threshold(threshold),
    );
    assert_eq!(rep.merged_any(), threshold < 1.0, "merge outcome at threshold {threshold}");
    w
}

/// Threshold 1.0 installs nothing: forward outputs are bit-identical to
/// the unmerged model, for dense and packed experts, at pool sizes 1 and 4.
#[test]
fn threshold_one_bit_identical_dense_and_packed() {
    let c = cfg();
    for packed in [false, true] {
        let mut w = Weights::init(&c, 41);
        synthesize_mergeable_pairs(&mut w, 0.05, 5);
        if packed {
            w.pack_experts_rtn(4, 16);
        }
        let wm = merged_weights(&w, 1.0);
        assert!(wm.layers.iter().all(|l| l.remap().is_none()));
        assert_eq!(wm.routed_expert_bytes(), w.routed_expert_bytes());
        for threads in [1usize, 4] {
            let pool = || Arc::new(ThreadPool::new(threads));
            let base = Model::with_pool(w.clone(), pool());
            let merged = Model::with_pool(wm.clone(), pool());
            for s in seqs() {
                let a = base.forward(&s);
                let b = merged.forward(&s);
                assert_eq!(a.data, b.data, "packed={packed} threads={threads}");
            }
        }
    }
}

/// On a toy model with exactly duplicated experts (pairs (0,1) and (2,3),
/// …) the merged MoE layer must equal a reference computed by hand from
/// the remap: reduce old-id logits with max, softmax/top-k over merged
/// ids, renormalize survivors, run each selected cluster base, add shared
/// experts. Duplicates merge without deltas, so the reference needs no
/// low-rank math.
#[test]
fn merged_forward_matches_manual_remap_reference() {
    let c = cfg();
    let mut w = Weights::init(&c, 42);
    for l in &mut w.layers {
        for e in (0..c.n_experts).step_by(2) {
            let src = (*l.expert_arc(e)).clone();
            *l.expert_mut(e + 1) = src;
        }
    }
    let wm = merged_weights(&w, 0.99);
    let m = Model::new(wm);
    let layer = &m.weights.layers[0];
    let rm = layer.remap().expect("remap installed");
    assert_eq!(rm.n_merged, c.n_experts / 2);
    // Exact duplicates leave zero residuals: no deltas anywhere.
    assert!(layer.deltas().iter().all(|d| d.is_none()));

    let mut rng = Pcg64::seeded(43);
    let x = Mat::randn(6, c.d_model, 1.0, &mut rng);
    let (got, diag) = m.moe_layer(&x, layer, 0, &Hooks::none());
    assert_eq!(diag.expert_tokens.len(), rm.n_merged, "diagnostics at merged width");

    let n = rm.n_merged;
    let k = c.top_k.min(n);
    let raw = matmul(&x, &layer.router);
    let mut want = Mat::zeros(x.rows, c.d_model);
    for t in 0..x.rows {
        let mut scores = vec![f32::NEG_INFINITY; n];
        for (o, &logit) in raw.row(t).iter().enumerate() {
            let mi = rm.map[o] as usize;
            scores[mi] = scores[mi].max(logit);
        }
        softmax_inplace(&mut scores);
        let idx = topk_indices(&scores, k);
        // Denominator in selection (top-k) order, like the survivor loop.
        let denom: f32 = idx.iter().map(|&i| scores[i]).sum();
        // Accumulation in ascending merged-id order, like the scatter.
        let mut sel = idx.clone();
        sel.sort_unstable();
        for mi in sel {
            let y = eac_moe::model::expert_forward(&x.gather_rows(&[t]), &layer.experts()[mi]);
            axpy(want.row_mut(t), scores[mi] / denom, y.row(0));
        }
    }
    for sh in layer.shared() {
        let y = eac_moe::model::expert_forward(&x, sh);
        for t in 0..x.rows {
            add_inplace(want.row_mut(t), y.row(t));
        }
    }
    assert_eq!(got.data, want.data, "merged moe_layer != manual remap reference");
}

/// Selection records and PESF masks operate over merged ids: every
/// recorded id is below the merged width, per-layer counts live at that
/// width, and `PesfDecodeState::from_prefill_widths` thresholds each
/// layer by its own routed width.
#[test]
fn selection_records_and_pesf_masks_use_merged_width() {
    let c = cfg();
    let mut w = Weights::init(&c, 44);
    synthesize_mergeable_pairs(&mut w, 0.05, 6);
    let m = Model::new(merged_weights(&w, 0.9));
    let widths: Vec<usize> = m.weights.layers.iter().map(|l| l.n_routed()).collect();
    assert!(widths.iter().all(|&n| n == c.n_experts / 2));

    let hooks = Hooks::recording(c.n_layers);
    m.forward_with_hooks(&seqs()[0], &hooks);
    let rec = hooks.take_selections().unwrap();
    for (li, layer) in rec.layers.iter().enumerate() {
        for sel in layer {
            assert!(
                sel.experts.iter().all(|&e| (e as usize) < widths[li]),
                "layer {li}: selection id beyond merged width"
            );
        }
    }
    let st = PesfDecodeState::from_prefill_widths(
        &rec,
        &widths,
        c.top_k,
        PesfConfig { alpha: 0.9, ..Default::default() },
    );
    let mask = st.mask();
    assert_eq!(mask.len(), c.n_layers);
    for (li, row) in mask.iter().enumerate() {
        assert_eq!(row.len(), widths[li], "layer {li}: mask row at merged width");
    }
    // A merged-width mask row drives the forward pass without panicking
    // and with finite outputs.
    let masked_hooks = Hooks::with_seq_masks(vec![Some(st.mask())]);
    let mut cache = eac_moe::model::KvCache::new(m.cfg());
    m.prefill_into_cache(&seqs()[0], &Hooks::none(), &mut cache);
    let logits = m.decode_step_batch(&[3], std::slice::from_mut(&mut cache), &masked_hooks);
    assert!(logits.data.iter().all(|v| v.is_finite()));
}

/// A merged model under a tiered store at 50% of its routed bytes serves
/// bit-identically to the resident model: cluster bases stay resident,
/// deltas are the (evicting) tiered unit.
#[test]
fn tiered_store_at_half_budget_bit_identical_with_deltas_tiered() {
    let c = cfg();
    let mut w = Weights::init(&c, 45);
    synthesize_mergeable_pairs(&mut w, 0.05, 8);
    let wm = merged_weights(&w, 0.9);
    // The synthesized residuals are nonzero, so deltas exist to tier.
    assert!(wm.layers.iter().any(|l| l.deltas().iter().any(|d| d.is_some())));
    let resident = Model::new(wm.clone());

    // Two budgets: the issue's 50%-of-routed-bytes configuration (holds
    // every delta comfortably — bases dominate the routed footprint), and
    // the minimum feasible budget (one largest delta), which forces
    // eviction/reload churn on every layer.
    let half = (wm.routed_expert_bytes() / 2).max(wm.max_expert_bytes());
    let tight = wm.max_expert_bytes();
    assert!(tight > 0, "synthesized merge produced no deltas to tier");
    for (tag, budget) in [("half", half), ("tight", tight)] {
        let spill = std::env::temp_dir()
            .join(format!("eac_moe_merge_itest_{}_{tag}.bin", std::process::id()));
        let tiered =
            Model::new(wm.clone()).into_tiered(budget, &spill).expect("tiered merged model");
        let _ = std::fs::remove_file(&spill);
        assert!(tiered.store.is_tiered());
        for (li, l) in tiered.weights.layers.iter().enumerate() {
            assert_eq!(l.experts().len(), l.n_routed(), "layer {li}: bases stay resident");
            assert!(l.deltas().is_empty(), "layer {li}: deltas owned by the store");
        }
        for s in seqs() {
            let a = resident.forward(&s);
            let b = tiered.forward(&s);
            assert_eq!(a.data, b.data, "tiered({tag}) merged forward drifted from resident");
        }
    }
}

/// A merged model (remap + bases + deltas) round-trips through TensorFile
/// save/load with bit-identical outputs, and the serving engine reports
/// the reduced expert count.
#[test]
fn merged_model_roundtrips_and_serves_with_reduced_expert_count() {
    let c = cfg();
    let mut w = Weights::init(&c, 46);
    synthesize_mergeable_pairs(&mut w, 0.05, 9);
    let wm = merged_weights(&w, 0.7);
    let path = std::env::temp_dir()
        .join(format!("eac_moe_merge_ckpt_{}.bin", std::process::id()));
    wm.save(&path).expect("save merged checkpoint");
    let back = Weights::load(&path, "merge-itest").expect("load merged checkpoint");
    let _ = std::fs::remove_file(&path);
    let a = Model::new(wm);
    let b = Model::new(back);
    for s in seqs() {
        assert_eq!(a.forward(&s).data, b.forward(&s).data, "roundtrip drifted");
    }

    let routed: usize = b.weights.layers.iter().map(|l| l.n_routed()).sum();
    let original = c.n_layers * c.n_experts;
    assert!(routed < original);
    let engine = Engine::new(b, EngineConfig { workers: 2, ..Default::default() });
    let rs: Vec<Request> = (0..6u64)
        .map(|i| {
            Request::new(i, (0..20u32).map(|t| (t * 11 + i as u32) % 64).collect()).with_decode(4)
        })
        .collect();
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 6);
    assert!(resps.iter().all(|r| r.generated.len() == 4));
    assert_eq!(metrics.routed_expert_count, routed);
    assert_eq!(metrics.original_expert_count, original);
    assert!(metrics.summary().contains("(merged)"), "summary surfaces the merge");
}
