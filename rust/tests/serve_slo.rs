//! Integration: the streaming/SLO serving surface — the chunked-prefill
//! bit-identity acceptance matrix (chunk size x compute-pool size x
//! dense/packed weights), deadline shedding under a timed burst, and the
//! end-to-end streaming event contract.
//!
//! The matrix here is the PR's acceptance pin: chunked prefill is a
//! *scheduling* change, so every served token, next-token prediction and
//! mean logprob must be bit-identical to the monolithic path at any chunk
//! size, at every pool size, on dense and packed expert weights.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::serve::workload::{self, LenDist, WorkloadSpec};
use eac_moe::serve::{
    BatchPolicy, Engine, EngineConfig, FinishReason, PrunePolicy, Request, StreamEvent,
    StreamSink, TimedRequest,
};
use std::time::Duration;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "slo-itest".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 16,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 128,
        max_seq: 256,
    }
}

fn dense_weights() -> Weights {
    Weights::init(&cfg(), 7)
}

fn packed_weights() -> Weights {
    let mut w = dense_weights();
    w.pack_experts_rtn(4, 16);
    w
}

/// Mixed-length request set: short prompts landing behind long ones is
/// exactly the shape chunked prefill reschedules.
fn mixed_reqs() -> Vec<Request> {
    let lens = [23usize, 5, 17, 3, 29, 11];
    lens.iter()
        .enumerate()
        .map(|(i, &len)| {
            Request::new(
                i as u64,
                (0..len as u32).map(|t| (t * 13 + i as u32 * 7) % 128).collect(),
            )
            .with_decode([4usize, 0, 6, 3, 2, 5][i])
        })
        .collect()
}

fn serve_sorted(
    weights: Weights,
    threads: Option<usize>,
    prefill_chunk: usize,
) -> Vec<(u64, u32, Vec<u32>, u32)> {
    let engine = Engine::new(
        Model::new(weights),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 1,
            prune: PrunePolicy::None,
            threads,
            prefill_chunk,
            ..Default::default()
        },
    );
    let (mut resps, metrics) = engine.serve(mixed_reqs());
    assert_eq!(resps.len(), 6);
    assert_eq!(metrics.prompt_tokens, 23 + 5 + 17 + 3 + 29 + 11);
    assert_eq!(metrics.generated_tokens, 4 + 6 + 3 + 2 + 5);
    resps.sort_by_key(|r| r.id);
    resps
        .into_iter()
        .map(|r| (r.id, r.next_token, r.generated, r.mean_logprob.to_bits()))
        .collect()
}

#[test]
fn chunked_prefill_bit_identical_across_chunk_pool_and_weight_format() {
    // The acceptance matrix: for each weight format and pool size, the
    // monolithic run (chunk 0) is the reference and every chunk size must
    // reproduce it exactly — same tokens, same logprob bits.
    for (fmt, weights) in [("dense", dense_weights()), ("packed", packed_weights())] {
        for threads in [Some(1usize), Some(4)] {
            let reference = serve_sorted(weights.clone(), threads, 0);
            for chunk in [1usize, 3, 7, 64] {
                let got = serve_sorted(weights.clone(), threads, chunk);
                assert_eq!(
                    got, reference,
                    "{fmt} weights, threads={threads:?}, chunk={chunk}: \
                     chunked prefill must be scheduling-only"
                );
            }
        }
    }
}

#[test]
fn timed_burst_sheds_expired_and_serves_the_rest() {
    // An open-loop burst where half the requests carry an impossible
    // deadline (0 ns budget — already expired when a worker picks them
    // up): the engine must shed exactly those as DeadlineExceeded without
    // prefilling them, serve everything else to completion, and conserve
    // every request.
    let engine = Engine::new(
        Model::new(dense_weights()),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 2,
            ..Default::default()
        },
    );
    let arrivals: Vec<TimedRequest> = (0..12u64)
        .map(|i| TimedRequest {
            at_secs: i as f64 * 1e-4,
            req: Request::new(i, (0..16u32).map(|t| (t * 13 + i as u32 * 7) % 128).collect())
                .with_decode(2),
            deadline_budget: if i % 2 == 1 { Some(Duration::from_secs(0)) } else { None },
        })
        .collect();
    let (resps, metrics) = engine.serve_timed(arrivals);
    assert_eq!(resps.len(), 12, "every request answered exactly once");
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 12);
    for r in &resps {
        if r.id % 2 == 1 {
            assert_eq!(r.finish_reason, FinishReason::DeadlineExceeded, "id {}", r.id);
            assert!(r.generated.is_empty());
            assert_eq!(r.ttft_secs, 0.0, "shed requests never reach a first token");
        } else {
            assert_eq!(r.finish_reason, FinishReason::Length, "id {}", r.id);
            assert_eq!(r.generated.len(), 2);
            assert!(r.ttft_secs > 0.0);
        }
    }
    // Shed requests never prefill: only the 6 served prompts count.
    assert_eq!(metrics.prompt_tokens, 6 * 16);
    assert_eq!(metrics.deadline_shed, 6);
    assert_eq!(metrics.ttft.count(), 6);
}

#[test]
fn workload_burst_streams_every_request_in_order() {
    // Generator -> timed engine -> streaming consumers, end to end: every
    // request's event stream is Started -> Token* -> Finished, token
    // events replay `generated` exactly, and the finish responses match
    // the blocking return values.
    let spec = WorkloadSpec {
        n_requests: 8,
        rate_per_sec: 2000.0,
        prompt_len: LenDist::Bimodal { short: 4, long: 40, p_short: 0.5 },
        decode_len: LenDist::Uniform { lo: 1, hi: 4 },
        tenants: 2,
        vocab: 128,
        seed: 5,
        deadline_budget: None,
    };
    let mut arrivals = workload::generate(&spec);
    let mut receivers = Vec::new();
    for t in &mut arrivals {
        let (sink, rx) = StreamSink::channel();
        t.req.stream = Some(sink);
        receivers.push((t.req.id, rx));
    }
    let engine = Engine::new(
        Model::new(dense_weights()),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 1,
            prefill_chunk: 8,
            ..Default::default()
        },
    );
    let (resps, _) = engine.serve_timed(arrivals);
    assert_eq!(resps.len(), 8);
    for (id, rx) in receivers {
        let resp = resps.iter().find(|r| r.id == id).unwrap();
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 2 + resp.generated.len(), "id {id}");
        match &events[0] {
            StreamEvent::Started { id: sid, next_token, ttft_secs } => {
                assert_eq!(*sid, id);
                assert_eq!(*next_token, resp.next_token);
                assert_eq!(*ttft_secs, resp.ttft_secs);
            }
            other => panic!("id {id}: first event {other:?}, want Started"),
        }
        for (i, ev) in events[1..events.len() - 1].iter().enumerate() {
            match ev {
                StreamEvent::Token { id: sid, token, index } => {
                    assert_eq!(*sid, id);
                    assert_eq!(*index, i);
                    assert_eq!(*token, resp.generated[i], "id {id} token {i}");
                }
                other => panic!("id {id}: event {i} is {other:?}, want Token"),
            }
        }
        match events.last().unwrap() {
            StreamEvent::Finished(r) => {
                assert_eq!(r.id, id);
                assert_eq!(r.generated, resp.generated);
                assert_eq!(r.finish_reason, resp.finish_reason);
            }
            other => panic!("id {id}: last event {other:?}, want Finished"),
        }
    }
}
