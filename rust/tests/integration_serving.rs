//! Integration: serving engine under load — conservation, policy effects,
//! decode-time PESF invariants, and the eval harness' PESF plumbing.

use eac_moe::model::hooks::{Hooks, SelectionRecord, SeqExpertMask};
use eac_moe::model::{KvCache, Model, ModelConfig, Weights};
use eac_moe::prune::pesf::{pesf_mask, PesfConfig, PesfDecodeState};
use eac_moe::serve::{BatchPolicy, Engine, EngineConfig, PrunePolicy, Request};
use std::cell::RefCell;
use std::sync::Arc;
use std::time::Duration;

/// When `EAC_MOE_EXPERT_BUDGET_MB` is set (CI's tight-budget pass), wrap
/// the model in a tiered ExpertStore under that byte budget (clamped up to
/// the smallest feasible budget, i.e. one expert), spilling the weights to
/// a unique temp checkpoint. Outputs are bit-identical to resident
/// serving, so every assertion in this suite doubles as a
/// miss/evict/reload exercise of the store.
fn maybe_tiered(m: Model) -> Model {
    // The accessor panics on a set-but-unparseable value, keeping CI's
    // tight-budget pass loud about misconfiguration.
    let Some(mb) = eac_moe::util::env::expert_budget_mb() else { return m };
    static SPILL_ID: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let id = SPILL_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let spill = std::env::temp_dir()
        .join(format!("eac_moe_itest_spill_{}_{id}.bin", std::process::id()));
    let budget = ((mb * 1e6) as usize).max(m.weights.max_expert_bytes());
    let m = m.into_tiered(budget, &spill).expect("tiered spill for EAC_MOE_EXPERT_BUDGET_MB");
    // Eager unlink (unix: the store keeps reading through its open fd) so
    // the suite leaves no spill checkpoints behind even if a test aborts;
    // the store also removes its own spill on drop.
    let _ = std::fs::remove_file(&spill);
    m
}

/// When `EAC_MOE_MERGE_THRESHOLD` is set (CI's merged-model rerun), make
/// the random-init experts mergeable (pairs at ~0.999 cosine — random
/// experts are near-orthogonal, so nothing would merge otherwise) and
/// permanently merge them at that threshold before serving. Every
/// assertion in this suite then exercises the remapped `moe_layer` path,
/// merged-width selection records/PESF masks, and (combined with
/// `EAC_MOE_EXPERT_BUDGET_MB`) the deltas-only tiered store.
///
/// Mask widths in this file stay at the *original* expert count (16):
/// merged selection ids are always below `n_routed`, so wider mask rows
/// and count buffers are valid by the merged-id mask contract.
fn maybe_merged(mut m: Model) -> Model {
    // The accessor panics on a set-but-unparseable value — the merged
    // rerun must not silently serve the unmerged model.
    let Some(t) = eac_moe::util::env::merge_threshold() else { return m };
    use eac_moe::prune::{merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig};
    synthesize_mergeable_pairs(&mut m.weights, 0.05, 23);
    let cfg = m.weights.cfg.clone();
    let rep = merge_experts(
        &mut m.weights,
        &uniform_frequencies(cfg.n_layers, cfg.n_experts),
        &MergeConfig::at_threshold(t),
    );
    assert!(
        t >= 1.0 || rep.merged_any(),
        "EAC_MOE_MERGE_THRESHOLD={t} merged nothing on synthesized pairs"
    );
    m
}

fn model() -> Model {
    let cfg = ModelConfig {
        name: "itest".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 16,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 128,
        max_seq: 256,
    };
    maybe_tiered(maybe_merged(Model::new(Weights::init(&cfg, 7))))
}

fn reqs(n: u64, len: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(i, (0..len as u32).map(|t| (t * 13 + i as u32 * 7) % 128).collect())
        })
        .collect()
}

#[test]
fn large_burst_all_served_exactly_once() {
    let engine = Engine::new(
        model(),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 4,
            prune: PrunePolicy::None,
            ..Default::default()
        },
    );
    let (resps, metrics) = engine.serve(reqs(64, 24));
    assert_eq!(resps.len(), 64);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 64, "duplicate or lost responses");
    assert_eq!(metrics.prompt_tokens, 64 * 24);
    assert_eq!(metrics.total_tokens(), 64 * 24);
}

#[test]
fn decode_burst_counts_generated_tokens_and_batches() {
    // Decode-heavy load through the batched path: every request decodes,
    // all are served exactly once, and the metrics account generated
    // tokens separately from prompt tokens.
    let engine = Engine::new(
        model(),
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 2,
            prune: PrunePolicy::None,
            ..Default::default()
        },
    );
    let rs: Vec<Request> = reqs(16, 24).into_iter().map(|r| r.with_decode(8)).collect();
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 16);
    assert!(resps.iter().all(|r| r.generated.len() == 8));
    assert!(resps.iter().all(|r| r.finish_reason == eac_moe::serve::FinishReason::Length));
    assert_eq!(metrics.prompt_tokens, 16 * 24);
    assert_eq!(metrics.generated_tokens, 16 * 8);
    assert_eq!(metrics.total_tokens(), 16 * 24 + 16 * 8);
    assert!(metrics.decode_tokens_per_sec() > 0.0);
    assert!(metrics.decode_tokens_per_sec() < metrics.throughput_tokens_per_sec());
}

#[test]
fn burst_with_overlong_prompts_served_without_engine_abort() {
    // Regression (admission validation): malformed prompts sprinkled
    // through a multi-worker burst finish with rejection reasons while
    // every valid request — including valid requests *behind* the bad
    // ones in the queue — serves to completion.
    let m = model();
    let max_seq = m.cfg().max_seq;
    let engine = Engine::new(
        m,
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 3,
            ..Default::default()
        },
    );
    let mut rs: Vec<Request> = Vec::new();
    for i in 0..24u64 {
        if i % 6 == 5 {
            // Over-long prompt, decode requested: would have panicked a
            // worker pre-fix.
            rs.push(
                Request::new(i, (0..(max_seq + 3) as u32).map(|t| t % 128).collect())
                    .with_decode(4),
            );
        } else {
            rs.push(Request::new(i, (0..20).map(|t| (t * 13 + i as u32) % 128).collect())
                .with_decode(2));
        }
    }
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 24, "no responses lost to a worker abort");
    let rejected: Vec<_> =
        resps.iter().filter(|r| r.finish_reason.is_rejection()).collect();
    assert_eq!(rejected.len(), 4);
    assert!(rejected.iter().all(|r| r.generated.is_empty()));
    for r in resps.iter().filter(|r| !r.finish_reason.is_rejection()) {
        assert_eq!(r.generated.len(), 2);
        assert!(r.mean_logprob.is_finite());
    }
    assert_eq!(metrics.prompt_tokens, 20 * 20);
    assert_eq!(metrics.generated_tokens, 20 * 2);
    assert_eq!(metrics.decode.count(), 20);
}

#[test]
fn pesf_pruning_rate_grows_with_alpha_under_serving() {
    let mut last = -1.0f32;
    for alpha in [0.2f32, 0.5, 0.9] {
        // model() is seed-deterministic, so each engine serves identical
        // weights (and inherits the tight-budget tiered store under
        // EAC_MOE_EXPERT_BUDGET_MB).
        let engine = Engine::new(
            model(),
            EngineConfig {
                workers: 2,
                prune: PrunePolicy::Pesf(PesfConfig { alpha, ..Default::default() }),
                ..Default::default()
            },
        );
        let (_, metrics) = engine.serve(reqs(12, 48));
        assert!(
            metrics.mean_prune_rate >= last - 1e-4,
            "prune rate not monotone: alpha={alpha} rate={} last={last}",
            metrics.mean_prune_rate
        );
        last = metrics.mean_prune_rate;
    }
    assert!(last > 0.0);
}

#[test]
fn pesf_alpha_zero_equals_dense_outputs() {
    let dense_engine = Engine::new(
        model(),
        EngineConfig { workers: 1, prune: PrunePolicy::None, ..Default::default() },
    );
    let pesf_engine = Engine::new(
        model(),
        EngineConfig {
            workers: 1,
            prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.0, ..Default::default() }),
            ..Default::default()
        },
    );
    let (mut a, _) = dense_engine.serve(reqs(6, 20));
    let (mut b, _) = pesf_engine.serve(reqs(6, 20));
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.next_token, y.next_token);
        assert!((x.mean_logprob - y.mean_logprob).abs() < 1e-5);
    }
}

#[test]
fn pesf_alpha_zero_decode_bitwise_identical_to_unpruned() {
    // Acceptance invariant: with PrunePolicy::Pesf(alpha=0) the whole
    // masked decode machinery (per-row masks, per-step routing record,
    // rolling window) is live but every mask is all-false — outputs must
    // be bit-identical to PrunePolicy::None at every pool size and batch
    // shape.
    for threads in [Some(1usize), Some(4)] {
        for max_batch in [1usize, 4] {
            let run = |prune: PrunePolicy| {
                let e = Engine::new(
                    model(),
                    EngineConfig {
                        batch: BatchPolicy {
                            max_batch,
                            max_wait: Duration::from_micros(100),
                            ..Default::default()
                        },
                        workers: 1,
                        prune,
                        threads,
                    },
                );
                let rs: Vec<Request> =
                    reqs(5, 20).into_iter().map(|r| r.with_decode(6)).collect();
                let (mut out, m) = e.serve(rs);
                out.sort_by_key(|r| r.id);
                let got: Vec<(u64, Vec<u32>, u32, u32)> = out
                    .into_iter()
                    .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob.to_bits()))
                    .collect();
                (got, m)
            };
            let (dense, _) = run(PrunePolicy::None);
            let (pesf, mp) = run(PrunePolicy::Pesf(PesfConfig {
                alpha: 0.0,
                refresh_every: 2,
                window: 8,
            }));
            assert_eq!(dense, pesf, "threads={threads:?} max_batch={max_batch}");
            assert_eq!(mp.mean_prune_rate, 0.0);
            assert_eq!(mp.mean_decode_prune_rate, 0.0);
        }
    }
}

#[test]
fn masked_batched_decode_matches_sequential_b1_bitwise() {
    // A mixed batch — two sequences with different PESF masks and one
    // unpruned — must produce, row for row, exactly what each sequence
    // gets when decoded alone with its own mask (B=1 through the same
    // entry point), across several chained steps.
    let m = model();
    let prompts: [&[u32]; 3] =
        [&[1, 2, 3, 4, 5, 6, 7, 8], &[9, 10, 11], &[21, 34, 55, 89, 13]];
    let mk_mask = |p: &[u32], alpha: f32| -> SeqExpertMask {
        let hooks = Hooks::recording(2);
        m.forward_with_hooks(p, &hooks);
        let rec = hooks.take_selections().unwrap();
        let (mask, _) = pesf_mask(&rec, 16, 2, PesfConfig { alpha, ..Default::default() });
        Arc::new(mask)
    };
    // Row 2 gets a handcrafted lopsided mask (half of layer 0 pruned).
    let mut lopsided = vec![vec![false; 16]; 2];
    for e in 0..8 {
        lopsided[0][e] = true;
    }
    let masks: Vec<Option<SeqExpertMask>> =
        vec![Some(mk_mask(prompts[0], 0.7)), None, Some(Arc::new(lopsided))];
    assert!(
        masks[0].as_ref().unwrap().iter().flatten().any(|&x| x),
        "alpha=0.7 mask should prune something on 16 experts"
    );
    let mk_caches = || -> Vec<KvCache> {
        prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(m.cfg());
                m.prefill_into_cache(p, &Hooks::none(), &mut c);
                c
            })
            .collect()
    };
    let mut batch_caches = mk_caches();
    let mut solo_caches = mk_caches();
    let mut toks: Vec<u32> = prompts.iter().map(|p| p[0]).collect();
    for step in 0..4 {
        let logits = m.decode_step_batch(
            &toks,
            &mut batch_caches,
            &Hooks::with_seq_masks(masks.clone()),
        );
        for b in 0..3 {
            let solo = m.decode_step_batch(
                &[toks[b]],
                std::slice::from_mut(&mut solo_caches[b]),
                &Hooks::with_seq_masks(vec![masks[b].clone()]),
            );
            assert_eq!(logits.row(b), solo.row(0), "step {step} row {b}");
        }
        toks = (0..3)
            .map(|b| eac_moe::tensor::ops::topk_indices(logits.row(b), 1)[0] as u32)
            .collect();
    }
}

#[test]
fn decode_mask_refreshes_at_exact_cadence_during_decode() {
    // Drive a real masked decode loop (the engine's shape) and pin the
    // refresh cadence: the mask Arc is replaced exactly every
    // `refresh_every` observed tokens, never in between.
    let m = model();
    let prompt: Vec<u32> = (0..32).map(|i| (i * 5) % 128).collect();
    let pc = PesfConfig { alpha: 0.9, refresh_every: 3, window: 8 };
    let rec_hooks = Hooks::recording(2);
    let mut cache = KvCache::new(m.cfg());
    m.prefill_into_cache(&prompt, &rec_hooks, &mut cache);
    let rec = rec_hooks.take_selections().unwrap();
    let mut st = PesfDecodeState::from_prefill(&rec, 16, 2, pc);
    assert!(st.prune_rate() > 0.0, "alpha=0.9 must prune on a random router");
    let mut cur = *prompt.last().unwrap();
    for step in 1..=9usize {
        let prev = st.mask();
        let hooks = Hooks {
            seq_expert_masks: Some(vec![Some(st.mask())]),
            record_selections: Some(RefCell::new(SelectionRecord::with_layers(2))),
            ..Default::default()
        };
        let logits = m.decode_step_batch(&[cur], std::slice::from_mut(&mut cache), &hooks);
        cur = eac_moe::tensor::ops::topk_indices(logits.row(0), 1)[0] as u32;
        st.observe(hooks.take_selections().unwrap().token_experts(0));
        let refreshed = !Arc::ptr_eq(&prev, &st.mask());
        assert_eq!(refreshed, step % 3 == 0, "refresh at step {step}");
    }
}

#[test]
fn mixed_pesf_batch_retires_and_admits_correctly() {
    // Continuous batching under decode-time PESF: a burst mixing
    // prefill-only requests, budget-1 requests (finish at admission),
    // longer decodes, and malformed prompts — all with per-sequence masks
    // in flight — must conserve every request and report decode-phase
    // pruning.
    let mdl = model();
    let max_seq = mdl.cfg().max_seq;
    let engine = Engine::new(
        mdl,
        EngineConfig {
            batch: BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
            workers: 1,
            prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.9, refresh_every: 2, window: 16 }),
            ..Default::default()
        },
    );
    let budgets = [0usize, 1, 4, 9];
    let mut rs: Vec<Request> = Vec::new();
    for i in 0..12u64 {
        rs.push(
            Request::new(i, (0..24).map(|t| (t * 13 + i as u32 * 7) % 128).collect())
                .with_decode(budgets[i as usize % 4]),
        );
    }
    rs.push(
        Request::new(100, (0..(max_seq + 1) as u32).map(|t| t % 128).collect()).with_decode(3),
    );
    rs.push(Request::new(101, vec![]).with_decode(2));
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 14, "every request answered exactly once");
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 14);
    for r in &resps {
        if r.finish_reason.is_rejection() {
            assert!(r.generated.is_empty());
            assert_eq!(r.decode_prune_rate, 0.0);
        } else {
            let want = budgets[r.id as usize % 4];
            assert_eq!(r.generated.len(), want, "id {}", r.id);
            if want > 1 {
                // Took at least one batched decode step under a mask.
                assert!(r.decode_prune_rate > 0.0, "id {}", r.id);
            } else {
                assert_eq!(r.decode_prune_rate, 0.0, "id {}", r.id);
            }
        }
    }
    assert_eq!(metrics.generated_tokens, 3 * (0 + 1 + 4 + 9));
    assert!(metrics.mean_prune_rate > 0.0);
    assert!(metrics.mean_decode_prune_rate > 0.0);
}

#[test]
fn decode_after_prefill_consistent_with_forward() {
    let m = model();
    let engine = Engine::new(model(), EngineConfig { workers: 1, ..Default::default() });
    let toks: Vec<u32> = (0..16).map(|i| (i * 11) % 128).collect();
    let (resps, _) = engine.serve(vec![Request::new(0, toks.clone()).with_decode(3)]);
    assert_eq!(resps[0].generated.len(), 3);
    // next_token equals argmax of the prefill logits' last row.
    let logits = m.forward(&toks);
    let want = eac_moe::tensor::ops::topk_indices(logits.row(15), 1)[0] as u32;
    assert_eq!(resps[0].next_token, want);
}
