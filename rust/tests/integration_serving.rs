//! Integration: serving engine under load — conservation, policy effects,
//! and the eval harness' PESF plumbing.

use eac_moe::model::{Model, ModelConfig, Weights};
use eac_moe::prune::pesf::PesfConfig;
use eac_moe::serve::{BatchPolicy, Engine, EngineConfig, PrunePolicy, Request};
use std::time::Duration;

fn model() -> Model {
    let cfg = ModelConfig {
        name: "itest".into(),
        n_layers: 2,
        d_model: 32,
        d_ff: 16,
        n_experts: 16,
        top_k: 2,
        n_shared: 0,
        n_heads: 4,
        vocab: 128,
        max_seq: 256,
    };
    Model::new(Weights::init(&cfg, 7))
}

fn reqs(n: u64, len: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(i, (0..len as u32).map(|t| (t * 13 + i as u32 * 7) % 128).collect())
        })
        .collect()
}

#[test]
fn large_burst_all_served_exactly_once() {
    let engine = Engine::new(
        model(),
        EngineConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(100) },
            workers: 4,
            prune: PrunePolicy::None,
            ..Default::default()
        },
    );
    let (resps, metrics) = engine.serve(reqs(64, 24));
    assert_eq!(resps.len(), 64);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 64, "duplicate or lost responses");
    assert_eq!(metrics.prompt_tokens, 64 * 24);
    assert_eq!(metrics.total_tokens(), 64 * 24);
}

#[test]
fn decode_burst_counts_generated_tokens_and_batches() {
    // Decode-heavy load through the batched path: every request decodes,
    // all are served exactly once, and the metrics account generated
    // tokens separately from prompt tokens.
    let engine = Engine::new(
        model(),
        EngineConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(100) },
            workers: 2,
            prune: PrunePolicy::None,
            ..Default::default()
        },
    );
    let rs: Vec<Request> = reqs(16, 24).into_iter().map(|r| r.with_decode(8)).collect();
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 16);
    assert!(resps.iter().all(|r| r.generated.len() == 8));
    assert!(resps.iter().all(|r| r.finish_reason == eac_moe::serve::FinishReason::Length));
    assert_eq!(metrics.prompt_tokens, 16 * 24);
    assert_eq!(metrics.generated_tokens, 16 * 8);
    assert_eq!(metrics.total_tokens(), 16 * 24 + 16 * 8);
    assert!(metrics.decode_tokens_per_sec() > 0.0);
    assert!(metrics.decode_tokens_per_sec() < metrics.throughput_tokens_per_sec());
}

#[test]
fn burst_with_overlong_prompts_served_without_engine_abort() {
    // Regression (admission validation): malformed prompts sprinkled
    // through a multi-worker burst finish with rejection reasons while
    // every valid request — including valid requests *behind* the bad
    // ones in the queue — serves to completion.
    let m = model();
    let max_seq = m.cfg().max_seq;
    let engine = Engine::new(
        m,
        EngineConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(100) },
            workers: 3,
            ..Default::default()
        },
    );
    let mut rs: Vec<Request> = Vec::new();
    for i in 0..24u64 {
        if i % 6 == 5 {
            // Over-long prompt, decode requested: would have panicked a
            // worker pre-fix.
            rs.push(
                Request::new(i, (0..(max_seq + 3) as u32).map(|t| t % 128).collect())
                    .with_decode(4),
            );
        } else {
            rs.push(Request::new(i, (0..20).map(|t| (t * 13 + i as u32) % 128).collect())
                .with_decode(2));
        }
    }
    let (resps, metrics) = engine.serve(rs);
    assert_eq!(resps.len(), 24, "no responses lost to a worker abort");
    let rejected: Vec<_> =
        resps.iter().filter(|r| r.finish_reason.is_rejection()).collect();
    assert_eq!(rejected.len(), 4);
    assert!(rejected.iter().all(|r| r.generated.is_empty()));
    for r in resps.iter().filter(|r| !r.finish_reason.is_rejection()) {
        assert_eq!(r.generated.len(), 2);
        assert!(r.mean_logprob.is_finite());
    }
    assert_eq!(metrics.prompt_tokens, 20 * 20);
    assert_eq!(metrics.generated_tokens, 20 * 2);
    assert_eq!(metrics.decode.count(), 20);
}

#[test]
fn pesf_pruning_rate_grows_with_alpha_under_serving() {
    let weights = model().weights.clone();
    let mut last = -1.0f32;
    for alpha in [0.2f32, 0.5, 0.9] {
        let engine = Engine::new(
            Model::new(weights.clone()),
            EngineConfig {
                workers: 2,
                prune: PrunePolicy::Pesf(PesfConfig { alpha }),
                ..Default::default()
            },
        );
        let (_, metrics) = engine.serve(reqs(12, 48));
        assert!(
            metrics.mean_prune_rate >= last - 1e-4,
            "prune rate not monotone: alpha={alpha} rate={} last={last}",
            metrics.mean_prune_rate
        );
        last = metrics.mean_prune_rate;
    }
    assert!(last > 0.0);
}

#[test]
fn pesf_alpha_zero_equals_dense_outputs() {
    let m = model();
    let dense_engine = Engine::new(
        Model::new(m.weights.clone()),
        EngineConfig { workers: 1, prune: PrunePolicy::None, ..Default::default() },
    );
    let pesf_engine = Engine::new(
        Model::new(m.weights.clone()),
        EngineConfig {
            workers: 1,
            prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.0 }),
            ..Default::default()
        },
    );
    let (mut a, _) = dense_engine.serve(reqs(6, 20));
    let (mut b, _) = pesf_engine.serve(reqs(6, 20));
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.next_token, y.next_token);
        assert!((x.mean_logprob - y.mean_logprob).abs() < 1e-5);
    }
}

#[test]
fn decode_after_prefill_consistent_with_forward() {
    let m = model();
    let engine = Engine::new(
        Model::new(m.weights.clone()),
        EngineConfig { workers: 1, ..Default::default() },
    );
    let toks: Vec<u32> = (0..16).map(|i| (i * 11) % 128).collect();
    let (resps, _) = engine.serve(vec![Request::new(0, toks.clone()).with_decode(3)]);
    assert_eq!(resps[0].generated.len(), 3);
    // next_token equals argmax of the prefill logits' last row.
    let logits = m.forward(&toks);
    let want = eac_moe::tensor::ops::topk_indices(logits.row(15), 1)[0] as u32;
    assert_eq!(resps[0].next_token, want);
}
