//! Integration over the PJRT runtime + AOT artifacts. These tests
//! self-gate on `artifacts/manifest.json` (produced by `make artifacts`):
//! without it they pass vacuously, so plain `cargo test` works in a fresh
//! checkout; `make test-artifacts` runs the real round-trips.

use eac_moe::model::expert_forward;
use eac_moe::model::{ExpertWeights, ModelConfig};
use eac_moe::runtime::{ArtifactManifest, RuntimeClient};
use eac_moe::tensor::{Mat, Pcg64};

fn client() -> Option<RuntimeClient> {
    let root = ArtifactManifest::default_root();
    if !ArtifactManifest::present(&root) {
        eprintln!("artifacts absent; skipping PJRT integration test");
        return None;
    }
    let manifest = ArtifactManifest::load(&root).expect("manifest parse");
    Some(RuntimeClient::new(manifest).expect("PJRT CPU client"))
}

fn mixtral_cfg() -> ModelConfig {
    eac_moe::model::ZooModel::MixtralMini.config()
}

#[test]
fn expert_ffn_artifact_matches_native() {
    let Some(client) = client() else { return };
    let cfg = mixtral_cfg();
    let mut rng = Pcg64::seeded(11);
    let exe = client.executable_for("mixtral-mini/expert_ffn", 10).expect("bucket");
    let m = exe.spec.bucket_m;
    let x = Mat::randn(m, cfg.d_model, 1.0, &mut rng);
    let e = ExpertWeights {
        w1: Mat::randn(cfg.d_model, cfg.d_ff, 0.1, &mut rng).into(),
        w2: Mat::randn(cfg.d_ff, cfg.d_model, 0.1, &mut rng).into(),
        w3: Mat::randn(cfg.d_model, cfg.d_ff, 0.1, &mut rng).into(),
    };
    // The artifact takes f32 tensors; materialize the WeightMats.
    let (w1, w2, w3) = (e.w1.to_dense(), e.w2.to_dense(), e.w3.to_dense());
    let out = exe.run(&[&x, &w1, &w2, &w3]).expect("execute")[0].clone();
    let native = expert_forward(&x, &e);
    assert_eq!(out.rows, m);
    let max_err = out
        .data
        .iter()
        .zip(&native.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "PJRT vs native expert_ffn: max err {max_err}");
}

#[test]
fn attention_artifact_matches_native_prefix() {
    let Some(client) = client() else { return };
    let cfg = mixtral_cfg();
    let mut rng = Pcg64::seeded(12);
    let exe = client.executable_for("mixtral-mini/attention", 20).expect("bucket");
    let m = exe.spec.bucket_m;
    let x = Mat::randn(m, cfg.d_model, 1.0, &mut rng);
    let ws: Vec<Mat> =
        (0..4).map(|_| Mat::randn(cfg.d_model, cfg.d_model, 0.1, &mut rng)).collect();
    let out = exe.run(&[&x, &ws[0], &ws[1], &ws[2], &ws[3]]).expect("execute")[0].clone();
    assert_eq!(out.rows, m);
    assert!(out.data.iter().all(|v| v.is_finite()));
    // Causality: row 0 of the artifact output only attends to itself, so a
    // second run with different later rows must produce the same row 0.
    let mut x2 = x.clone();
    for r in m / 2..m {
        for c in 0..cfg.d_model {
            *x2.at_mut(r, c) = rng.gaussian();
        }
    }
    let out2 = exe.run(&[&x2, &ws[0], &ws[1], &ws[2], &ws[3]]).expect("execute")[0].clone();
    for c in 0..cfg.d_model {
        assert!((out.at(0, c) - out2.at(0, c)).abs() < 1e-4);
    }
}

#[test]
fn router_artifact_scores_sum_to_one() {
    let Some(client) = client() else { return };
    let cfg = mixtral_cfg();
    let mut rng = Pcg64::seeded(13);
    let exe = client.executable_for("mixtral-mini/router", 8).expect("bucket");
    let m = exe.spec.bucket_m;
    let x = Mat::randn(m, cfg.d_model, 1.0, &mut rng);
    let w = Mat::randn(cfg.d_model, cfg.n_experts, 0.2, &mut rng);
    let outs = exe.run(&[&x, &w]).expect("execute");
    assert_eq!(outs.len(), 2, "router artifact returns (logits, scores)");
    let scores = &outs[1];
    for t in 0..m {
        let s: f32 = scores.row(t).iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {t}: sum {s}");
    }
}

#[test]
fn manifest_covers_all_models_and_kinds() {
    let root = ArtifactManifest::default_root();
    if !ArtifactManifest::present(&root) {
        return;
    }
    let m = ArtifactManifest::load(&root).unwrap();
    for model in ["mixtral-mini", "phi-mini", "deepseek-mini", "qwen-mini"] {
        for kind in ["attention", "expert_ffn", "expert_ffn_q", "router", "lm_head"] {
            assert!(
                !m.of_kind(&format!("{model}/{kind}")).is_empty(),
                "missing artifacts for {model}/{kind}"
            );
        }
    }
}

#[test]
fn quantized_expert_artifact_matches_native_dequant() {
    use eac_moe::quant::quantizer::{GroupQuant, QuantConfig};
    use eac_moe::runtime::RtInput;
    let Some(client) = client() else { return };
    let cfg = mixtral_cfg();
    let mut rng = Pcg64::seeded(14);
    let exe = client.executable_for("mixtral-mini/expert_ffn_q", 10).expect("bucket");
    let m = exe.spec.bucket_m;
    let x = Mat::randn(m, cfg.d_model, 1.0, &mut rng);
    let qc = QuantConfig::new(4, 128);
    let mk = |rows: usize, cols: usize, rng: &mut Pcg64| {
        GroupQuant::quantize(&Mat::randn(rows, cols, 0.1, rng), qc)
    };
    let g1 = mk(cfg.d_model, cfg.d_ff, &mut rng);
    let g2 = mk(cfg.d_ff, cfg.d_model, &mut rng);
    let g3 = mk(cfg.d_model, cfg.d_ff, &mut rng);
    let smat = |v: &Vec<f32>, r: usize, c: usize| Mat::from_vec(r, c, v.clone());
    let ng_d = qc.n_groups(cfg.d_model);
    let ng_ff = qc.n_groups(cfg.d_ff);
    let s1 = smat(&g1.scales, ng_d, cfg.d_ff);
    let z1 = smat(&g1.zeros, ng_d, cfg.d_ff);
    let s2 = smat(&g2.scales, ng_ff, cfg.d_model);
    let z2 = smat(&g2.zeros, ng_ff, cfg.d_model);
    let s3 = smat(&g3.scales, ng_d, cfg.d_ff);
    let z3 = smat(&g3.zeros, ng_d, cfg.d_ff);
    let out = exe
        .run_mixed(&[
            RtInput::F32(&x),
            RtInput::U8(&g1.codes), RtInput::F32(&s1), RtInput::F32(&z1),
            RtInput::U8(&g2.codes), RtInput::F32(&s2), RtInput::F32(&z2),
            RtInput::U8(&g3.codes), RtInput::F32(&s3), RtInput::F32(&z3),
        ])
        .expect("execute quantized expert")[0]
        .clone();
    // Native reference: dequantize then SwiGLU.
    let e = ExpertWeights {
        w1: g1.dequantize().into(),
        w2: g2.dequantize().into(),
        w3: g3.dequantize().into(),
    };
    let native = expert_forward(&x, &e);
    let max_err = out
        .data
        .iter()
        .zip(&native.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-2, "quantized PJRT vs native dequant: max err {max_err}");
}

/// Full PJRT smoke across every artifact (slow: compiles everything).
/// Run via `make test-artifacts` (`cargo test -- --ignored`).
#[test]
#[ignore]
fn compile_every_artifact() {
    let Some(client) = client() else { return };
    let names: Vec<String> =
        client.manifest().entries.iter().map(|e| e.name.clone()).collect();
    for name in names {
        client.executable(&name).unwrap_or_else(|e| panic!("compile {name}: {e:#}"));
    }
    assert!(client.compiled_count() > 0);
}
