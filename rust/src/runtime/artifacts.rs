//! Artifact manifest: what `make artifacts` produced and how to call it.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json`:
//!
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {"name": "expert_ffn.m32", "path": "hlo/expert_ffn_m32.hlo.txt",
//!      "inputs": [[32,128],[128,256],[256,128],[128,256]],
//!      "outputs": [[32,128]], "bucket_m": 32, "kind": "expert_ffn"}
//!   ]
//! }
//! ```

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    /// Input shapes (row-major dims).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    /// Token-count bucket this entry was compiled for (0 = n/a).
    pub bucket_m: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub root: PathBuf,
    pub entries: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Default artifacts directory (env EAC_MOE_ARTIFACTS or ./artifacts).
    pub fn default_root() -> PathBuf {
        std::env::var("EAC_MOE_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from("artifacts")
        })
    }

    pub fn present(root: &Path) -> bool {
        root.join("manifest.json").exists()
    }

    /// Load `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json", root.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut entries = Vec::new();
        let shape_list = |j: &Json| -> Vec<Vec<usize>> {
            j.as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|s| s.as_arr().unwrap_or(&[]).iter().filter_map(|d| d.as_usize()).collect())
                .collect()
        };
        for e in v.get("entries").and_then(|e| e.as_arr()).unwrap_or(&[]) {
            entries.push(ArtifactSpec {
                name: e.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                path: root.join(e.get("path").and_then(|x| x.as_str()).unwrap_or("")),
                kind: e.get("kind").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                inputs: e.get("inputs").map(&shape_list).unwrap_or_default(),
                outputs: e.get("outputs").map(&shape_list).unwrap_or_default(),
                bucket_m: e.get("bucket_m").and_then(|x| x.as_usize()).unwrap_or(0),
            });
        }
        Ok(ArtifactManifest { root: root.to_path_buf(), entries })
    }

    /// All entries of a kind, sorted by bucket size ascending.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self.entries.iter().filter(|e| e.kind == kind).collect();
        v.sort_by_key(|e| e.bucket_m);
        v
    }

    /// Smallest bucket of `kind` with bucket_m >= m.
    pub fn bucket_for(&self, kind: &str, m: usize) -> Option<&ArtifactSpec> {
        self.of_kind(kind).into_iter().find(|e| e.bucket_m >= m)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"entries":[
                {"name":"a.m8","path":"hlo/a8.hlo.txt","kind":"expert_ffn",
                 "inputs":[[8,16]],"outputs":[[8,16]],"bucket_m":8},
                {"name":"a.m32","path":"hlo/a32.hlo.txt","kind":"expert_ffn",
                 "inputs":[[32,16]],"outputs":[[32,16]],"bucket_m":32}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_bucket_lookup() {
        let dir = std::env::temp_dir().join("eac_manifest_test");
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.bucket_for("expert_ffn", 5).unwrap().bucket_m, 8);
        assert_eq!(m.bucket_for("expert_ffn", 9).unwrap().bucket_m, 32);
        assert_eq!(m.bucket_for("expert_ffn", 33).map(|e| e.bucket_m), None);
        assert!(m.by_name("a.m8").is_some());
        assert!(ArtifactManifest::present(&dir));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
