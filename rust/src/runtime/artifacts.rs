//! Artifact manifest: what `make artifacts` produced and how to call it.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json`:
//!
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {"name": "expert_ffn.m32", "path": "hlo/expert_ffn_m32.hlo.txt",
//!      "inputs": [[32,128],[128,256],[256,128],[128,256]],
//!      "outputs": [[32,128]], "bucket_m": 32, "kind": "expert_ffn"}
//!   ]
//! }
//! ```

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    /// Input shapes (row-major dims).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    /// Token-count bucket this entry was compiled for (0 = n/a).
    pub bucket_m: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub root: PathBuf,
    pub entries: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Default artifacts directory (env EAC_MOE_ARTIFACTS or ./artifacts).
    pub fn default_root() -> PathBuf {
        crate::util::env::artifacts_dir().unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn present(root: &Path) -> bool {
        root.join("manifest.json").exists()
    }

    /// Load `<root>/manifest.json`. Strict on identity: `name`, `path`,
    /// and `kind` are required per entry (an entry missing them is
    /// unaddressable, so defaulting to "" only deferred the failure to a
    /// confusing lookup miss). Shapes and `bucket_m` stay optional —
    /// absent means "not shape-bucketed".
    pub fn load(root: &Path) -> Result<Self> {
        let v = crate::util::json::load(&root.join("manifest.json"))?;
        let mut entries = Vec::new();
        let shape_list = |j: &Json| -> Vec<Vec<usize>> {
            j.as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|s| s.as_arr().unwrap_or(&[]).iter().filter_map(|d| d.as_usize()).collect())
                .collect()
        };
        for (i, e) in v.req_arr("entries").context("manifest")?.iter().enumerate() {
            let ctx = || format!("manifest entry {i}");
            entries.push(ArtifactSpec {
                name: e.req_str("name").with_context(ctx)?.to_string(),
                path: root.join(e.req_str("path").with_context(ctx)?),
                kind: e.req_str("kind").with_context(ctx)?.to_string(),
                inputs: e.get("inputs").map(&shape_list).unwrap_or_default(),
                outputs: e.get("outputs").map(&shape_list).unwrap_or_default(),
                bucket_m: e.get("bucket_m").and_then(|x| x.as_usize()).unwrap_or(0),
            });
        }
        Ok(ArtifactManifest { root: root.to_path_buf(), entries })
    }

    /// All entries of a kind, sorted by bucket size ascending.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self.entries.iter().filter(|e| e.kind == kind).collect();
        v.sort_by_key(|e| e.bucket_m);
        v
    }

    /// Smallest bucket of `kind` with bucket_m >= m.
    pub fn bucket_for(&self, kind: &str, m: usize) -> Option<&ArtifactSpec> {
        self.of_kind(kind).into_iter().find(|e| e.bucket_m >= m)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"entries":[
                {"name":"a.m8","path":"hlo/a8.hlo.txt","kind":"expert_ffn",
                 "inputs":[[8,16]],"outputs":[[8,16]],"bucket_m":8},
                {"name":"a.m32","path":"hlo/a32.hlo.txt","kind":"expert_ffn",
                 "inputs":[[32,16]],"outputs":[[32,16]],"bucket_m":32}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn corrupt_or_incomplete_manifest_is_an_error_not_a_default() {
        let dir = std::env::temp_dir()
            .join(format!("eac_manifest_strict_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Entry missing `kind`: must fail naming the entry and the key,
        // not load as kind "".
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"entries":[{"name":"a","path":"p"}]}"#,
        )
        .unwrap();
        let err = format!("{:#}", ArtifactManifest::load(&dir).unwrap_err());
        assert!(err.contains("entry 0") && err.contains("`kind`"), "got: {err}");
        // Unparseable JSON: must fail with the path, not panic.
        std::fs::write(dir.join("manifest.json"), "{oops").unwrap();
        let err = format!("{:#}", ArtifactManifest::load(&dir).unwrap_err());
        assert!(err.contains("manifest.json"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_and_bucket_lookup() {
        let dir = std::env::temp_dir().join("eac_manifest_test");
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.bucket_for("expert_ffn", 5).unwrap().bucket_m, 8);
        assert_eq!(m.bucket_for("expert_ffn", 9).unwrap().bucket_m, 32);
        assert_eq!(m.bucket_for("expert_ffn", 33).map(|e| e.bucket_m), None);
        assert!(m.by_name("a.m8").is_some());
        assert!(ArtifactManifest::present(&dir));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
