//! PJRT runtime: load AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Interchange is HLO **text** — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod client;
pub mod xla_stub;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use client::{Executable, RtInput, RuntimeClient};
