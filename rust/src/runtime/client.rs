//! PJRT client wrapper: compile-once / execute-many over HLO-text
//! artifacts, with f32 `Mat` in/out (adapted from /opt/xla-example/load_hlo).

use crate::runtime::artifacts::{ArtifactManifest, ArtifactSpec};
use crate::runtime::xla_stub as xla;
use crate::tensor::Mat;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// A compiled computation ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// An input value for mixed-dtype executions (the quantized-expert
/// artifacts take u8 code tensors alongside f32 scales/zeros).
pub enum RtInput<'a> {
    F32(&'a Mat),
    U8(&'a [u8]),
}

impl Executable {
    /// Execute with mixed f32/u8 inputs (shapes from the spec).
    pub fn run_mixed(&self, inputs: &[RtInput]) -> Result<Vec<Mat>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, shape) in inputs.iter().zip(&self.spec.inputs) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let want: usize = shape.iter().product();
            let lit = match inp {
                RtInput::F32(m) => {
                    anyhow::ensure!(m.data.len() == want, "{}: f32 input size mismatch", self.spec.name);
                    xla::Literal::vec1(&m.data).reshape(&dims)?
                }
                RtInput::U8(b) => {
                    anyhow::ensure!(b.len() == want, "{}: u8 input size mismatch", self.spec.name);
                    // vec1 has no u8 NativeType impl; build from raw bytes.
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        shape,
                        b,
                    )?
                }
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, shape) in tuple.into_iter().zip(&self.spec.outputs) {
            let data = lit.to_vec::<f32>()?;
            let (rows, cols) = shape_2d(shape);
            out.push(Mat::from_vec(rows, cols, data));
        }
        Ok(out)
    }

    /// Execute on f32 matrices. Inputs must match the spec's shapes;
    /// returns the tuple elements as matrices (aot.py lowers with
    /// return_tuple=True).
    pub fn run(&self, inputs: &[&Mat]) -> Result<Vec<Mat>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (m, shape) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                m.data.len() == want,
                "{}: input size {} != shape {:?}",
                self.spec.name,
                m.data.len(),
                shape
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(&m.data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, shape) in tuple.into_iter().zip(&self.spec.outputs) {
            let data = lit.to_vec::<f32>()?;
            let (rows, cols) = shape_2d(shape);
            out.push(Mat::from_vec(rows, cols, data));
        }
        Ok(out)
    }
}

fn shape_2d(shape: &[usize]) -> (usize, usize) {
    match shape.len() {
        0 => (1, 1),
        1 => (1, shape[0]),
        2 => (shape[0], shape[1]),
        _ => (shape[..shape.len() - 1].iter().product(), shape[shape.len() - 1]),
    }
}

/// Compile-once cache over a PJRT CPU client.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl RuntimeClient {
    /// Create a CPU PJRT client over the given artifact root.
    pub fn new(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(RuntimeClient { client, manifest, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable with the given name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = spec.path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let arc = std::sync::Arc::new(Executable { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Get the executable of `kind` for >= `m` rows (bucketed shapes).
    pub fn executable_for(&self, kind: &str, m: usize) -> Result<std::sync::Arc<Executable>> {
        let name = self
            .manifest
            .bucket_for(kind, m)
            .with_context(|| format!("no '{kind}' bucket for m={m}"))?
            .name
            .clone();
        self.executable(&name)
    }

    /// Number of compiled executables in the cache.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// Integration tests that require built artifacts live in
// rust/tests/runtime_artifacts.rs (gated on artifacts/ existing).
