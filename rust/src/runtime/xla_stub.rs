//! Build-time stub for the `xla` (xla_extension / PJRT) bindings.
//!
//! The offline build environment does not ship the `xla` crate, so this
//! module provides the exact API surface `runtime::client` and `main`
//! consume, with every runtime entry point reporting "unavailable".
//! [`PjRtClient::cpu`] always errors, so no other method is ever reached:
//! the PJRT integration tests self-gate on `artifacts/manifest.json` and
//! pass vacuously, and the serving/compression stack runs on the native
//! tensor path. To use real PJRT, replace the `use ... xla_stub as xla`
//! aliases with the real crate; the call sites are unchanged.

use std::fmt;

/// Stub error: carries the unavailability message.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla_extension runtime is not linked into this build (native fallback active)".into(),
    ))
}

/// Element dtypes the artifact path uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
}

/// Marker for dtypes convertible out of a [`Literal`].
pub trait NativeType {}
impl NativeType for f32 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// Host-side tensor value (stub: never holds data).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn create_from_shape_and_untyped_data<D: AsRef<[u8]>>(
        _ty: ElementType,
        _dims: &[usize],
        _data: D,
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the only constructor and it
/// always errors in the stub, so the handle is never observable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create a client");
        assert!(err.to_string().contains("not linked"));
    }
}
