//! Minimal dense-tensor substrate: row-major `f32` matrices, the blocked
//! matmul that carries the native hot path, and the nonlinearity/normalization
//! ops the MoE transformer needs.
//!
//! This is deliberately small — just what the model, quantizer and eval
//! stack use — but the matmul is cache-blocked and multi-threaded because
//! GPTQ and perplexity evaluation are GEMM-bound. All parallelism runs on
//! the persistent scoped worker pool in [`pool`] (no per-call thread
//! spawns); see that module for the sizing and determinism contract.

pub mod linalg;
pub mod matmul;
pub mod ops;
pub mod pool;
pub mod rng;
pub mod simd;

pub use matmul::{
    matmul, matmul_bias, matmul_bias_on, matmul_into, matmul_on, matmul_transb, matmul_transb_on,
};
pub use pool::ThreadPool;
pub use rng::Pcg64;

/// Row-major 2-D matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from existing data (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix with the given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Self {
        Mat { rows, cols, data: rng.gaussian_vec(rows * cols, std) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols, "Mat::at({r},{c}) out of {}x{}", self.rows, self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols, "Mat::at_mut({r},{c}) out of {}x{}", self.rows, self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows, "Mat::row({r}) out of {} rows", self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrow row `r` mutably.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows, "Mat::row_mut({r}) out of {} rows", self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        debug_assert!(self.data.len() == self.rows * self.cols);
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Select a subset of rows (gather).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean squared difference to another matrix of the same shape.
    pub fn mse(&self, other: &Mat) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_basics() {
        let mut m = Mat::zeros(2, 3);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn gather_rows_picks() {
        let m = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn mse_zero_on_self() {
        let m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert_eq!(m.mse(&m), 0.0);
    }
}
