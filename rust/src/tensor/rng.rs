//! Seeded PCG64 random number generator + distribution helpers.
//!
//! The offline registry has no `rand` crate, so we carry a small,
//! well-understood PRNG of our own. PCG-XSL-RR 128/64 (O'Neill 2014) — the
//! same generator family rand's `Pcg64` uses — gives 64-bit outputs with a
//! 128-bit state and excellent statistical quality for simulation work.

/// PCG-XSL-RR 128/64. Deterministic across platforms (pure integer math).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e39cb94b95bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair; we keep a
    /// simple non-cached version for determinism across call sites).
    pub fn gaussian(&mut self) -> f32 {
        let u1 = (1.0 - self.next_f64()) as f64; // avoid log(0)
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals scaled by `std`.
    pub fn gaussian_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() * std).collect()
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below_usize(weights.len().max(1));
        }
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w.max(0.0) as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a categorical distribution given logits (softmax sample).
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        let t = temperature.max(1e-6);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let probs: Vec<f32> = logits.iter().map(|&l| ((l - max) / t).exp()).collect();
        self.sample_weighted(&probs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::seeded(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seeded(3);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Pcg64::seeded(4);
        let w = [0.05f32, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert!(counts[1] > 1500, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
