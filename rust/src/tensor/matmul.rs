//! Cache-blocked, multi-threaded GEMM on row-major `f32` matrices.
//!
//! The native hot path (GPTQ Hessians, perplexity eval, the artifact-free
//! serving fallback) is GEMM-bound, so this is written for throughput:
//! k-panel blocking for L1/L2 reuse, inner loops routed through the
//! runtime-dispatched kernels in [`crate::tensor::simd`], and
//! row-parallelism over the persistent [`ThreadPool`] (no per-call thread
//! spawns). Every function has two forms: the plain name runs on
//! [`ThreadPool::global`], and the `_on` variant takes an explicit pool —
//! the model threads its own pool through so `EngineConfig::threads`
//! genuinely controls concurrency.
//!
//! Determinism contract: parallelism only ever partitions output *rows*
//! (or whole column panels), and every SIMD dispatch level executes the
//! same operation DAG (see `tensor/simd.rs`), so results are bit-identical
//! at every pool size and every dispatch level. Dense [`matmul`]
//! accumulates each element in ascending-k order exactly like the naive
//! triple loop; [`matmul_transb`] accumulates KC-panel [`simd::dot`]
//! partials in ascending-k panel order (the panel dot uses the fixed
//! 8-lane split documented in `tensor/simd.rs`, not sequential summation).

use super::pool::ThreadPool;
use super::{simd, Mat};

/// K-panel size (fits comfortably in L1 alongside the output strip).
const KC: usize = 256;
/// N-panel size.
const NC: usize = 512;
/// N-panel size for the transposed-B kernel: the B panel (`TRANSB_NC`
/// rows × `KC` cols of `b_t`) is reused across every output row a task
/// owns, so it is sized to sit in L2 (128 × 256 × 4 B = 128 KB).
const TRANSB_NC: usize = 128;

/// `C = A @ B` (rows_a x k) @ (k x cols_b).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    matmul_on(ThreadPool::global(), a, b)
}

/// [`matmul`] on an explicit pool.
pub fn matmul_on(pool: &ThreadPool, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into_on(pool, a, b, &mut c);
    c
}

/// `C = A @ B + bias` where `bias` broadcasts over rows.
pub fn matmul_bias(a: &Mat, b: &Mat, bias: &[f32]) -> Mat {
    matmul_bias_on(ThreadPool::global(), a, b, bias)
}

/// [`matmul_bias`] on an explicit pool, so callers under
/// `EngineConfig::threads` no longer fall back to the global pool. The
/// bias add happens after the pooled GEMM, per element, so results are
/// bit-identical across pool sizes (the GEMM already is).
pub fn matmul_bias_on(pool: &ThreadPool, a: &Mat, b: &Mat, bias: &[f32]) -> Mat {
    let mut c = matmul_on(pool, a, b);
    assert_eq!(bias.len(), c.cols);
    for r in 0..c.rows {
        let row = c.row_mut(r);
        for (x, &bv) in row.iter_mut().zip(bias) {
            *x += bv;
        }
    }
    c
}

/// `C = A @ B^T` — used when weights are stored out-feature-major (the
/// vocab-wide tied output head, per-head attention scores).
pub fn matmul_transb(a: &Mat, b_t: &Mat) -> Mat {
    matmul_transb_on(ThreadPool::global(), a, b_t)
}

/// [`matmul_transb`] on an explicit pool. K/N panel blocking mirrors
/// [`matmul_into_on`]: the `b_t` panel (`TRANSB_NC` rows × `KC` columns)
/// loads once per task and is reused across all of that task's output
/// rows — the old kernel re-streamed the whole `b_t` matrix (the entire
/// embedding table, for the output head) for every row of `a`. Each
/// element accumulates one [`simd::dot`] partial per K panel, in
/// ascending-k panel order, so the result is bit-identical to a reference
/// that sums panel dots the same way — at every pool size and dispatch
/// level.
///
/// Parallelization picks the ragged axis: tall outputs split by row (as
/// every GEMM here does); short-and-wide outputs — the decode-time output
/// head, `B rows × vocab` — split by *column panel* instead, each task
/// computing its columns into a private strip that is copied back
/// sequentially. Either way each element is produced whole by one task,
/// so outputs stay bit-identical at every pool size.
pub fn matmul_transb_on(pool: &ThreadPool, a: &Mat, b_t: &Mat) -> Mat {
    assert_eq!(a.cols, b_t.cols, "matmul_transb inner-dim mismatch");
    let m = a.rows;
    let n = b_t.rows;
    let mut c = Mat::zeros(m, n);
    if m < crate::tensor::pool::PAR_MIN_ROWS && n >= 2 * TRANSB_NC && pool.threads() > 1 {
        // Column-parallel path for decode-shaped outputs (m too small to
        // split by row, n wide enough to matter).
        let nchunks = pool.threads().min(n.div_ceil(TRANSB_NC));
        let chunk_cols = n.div_ceil(nchunks);
        // Both bounds clamp to n so a ragged tail can only shorten (or
        // empty) the last chunks, never underflow.
        let bounds = |ci: usize| ((ci * chunk_cols).min(n), ((ci + 1) * chunk_cols).min(n));
        // Pre-sized strips let the tasks fill them in place: the scope
        // barrier then guarantees every strip is complete with no
        // Option/unwrap needed on the join side.
        let mut strips: Vec<Vec<f32>> = (0..nchunks)
            .map(|ci| {
                let (j0, j1) = bounds(ci);
                vec![0f32; m * (j1 - j0)]
            })
            .collect();
        pool.scope(|s| {
            for (ci, strip) in strips.iter_mut().enumerate() {
                s.spawn(move || {
                    let (j0, j1) = bounds(ci);
                    transb_block(a, b_t, 0, m, j0, j1, strip);
                });
            }
        });
        for (ci, strip) in strips.into_iter().enumerate() {
            let (j0, j1) = bounds(ci);
            let w = j1 - j0;
            for r in 0..m {
                c.row_mut(r)[j0..j1].copy_from_slice(&strip[r * w..(r + 1) * w]);
            }
        }
        return c;
    }
    let body = |r0: usize, r1: usize, out: &mut [f32]| {
        transb_block(a, b_t, r0, r1, 0, n, out);
    };
    pool.run_rows(m, n, &mut c.data, &body);
    c
}

/// Blocked `A @ B^T` over the sub-rectangle rows `r0..r1` × columns
/// `j0..j1`, written into `out` (row-major, `j1 - j0` wide). One
/// implementation serves both the row-parallel and column-parallel
/// partitions, so the per-element chain of ascending-k panel
/// [`simd::dot`]s is identical everywhere — each element is bitwise
/// reproducible at every pool size and dispatch level.
fn transb_block(a: &Mat, b_t: &Mat, r0: usize, r1: usize, j0: usize, j1: usize, out: &mut [f32]) {
    let k = a.cols;
    let w = j1 - j0;
    debug_assert!(
        a.cols == b_t.cols && r1 <= a.rows && j1 <= b_t.rows && out.len() == (r1 - r0) * w,
        "transb_block shape contract"
    );
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for jb in (j0..j1).step_by(TRANSB_NC) {
            let jend = (jb + TRANSB_NC).min(j1);
            for r in r0..r1 {
                let arow = &a.row(r)[kb..kend];
                let crow = &mut out[(r - r0) * w + (jb - j0)..(r - r0) * w + (jend - j0)];
                for (cv, j) in crow.iter_mut().zip(jb..jend) {
                    *cv += simd::dot(arow, &b_t.row(j)[kb..kend]);
                }
            }
        }
    }
}

/// In-place `C = A @ B`; `c` must be pre-shaped (rows_a x cols_b).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    matmul_into_on(ThreadPool::global(), a, b, c)
}

/// [`matmul_into`] on an explicit pool.
pub fn matmul_into_on(pool: &ThreadPool, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    let k = a.cols;
    c.data.iter_mut().for_each(|x| *x = 0.0);
    let body = |r0: usize, r1: usize, out: &mut [f32]| {
        // i-k-j loop order with k/n panel blocking: B rows stream through
        // cache, C strip stays hot.
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for nb in (0..n).step_by(NC) {
                let nend = (nb + NC).min(n);
                for r in r0..r1 {
                    let arow = a.row(r);
                    let crow = &mut out[(r - r0) * n..(r - r0 + 1) * n];
                    for kk in kb..kend {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n + nb..kk * n + nend];
                        simd::axpy(&mut crow[nb..nend], av, brow);
                    }
                }
            }
        }
    };
    pool.run_rows(a.rows, n, &mut c.data, &body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for kk in 0..a.cols {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    /// Unblocked reference with the same per-element semantics as the
    /// production kernel: one `simd::dot` per KC panel, panels summed in
    /// ascending-k order. (The kernel's N/row blocking and parallelism
    /// must not change anything beyond this.)
    fn naive_transb(a: &Mat, b_t: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b_t.rows);
        for i in 0..a.rows {
            for j in 0..b_t.rows {
                let mut acc = 0.0;
                for kb in (0..a.cols).step_by(KC) {
                    let kend = (kb + KC).min(a.cols);
                    acc += simd::dot(&a.row(i)[kb..kend], &b_t.row(j)[kb..kend]);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (7, 13, 2), (16, 16, 16)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            for (x, y) in c.data.iter().zip(&r.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matches_naive_large_parallel() {
        let mut rng = Pcg64::seeded(12);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn transb_matches() {
        let mut rng = Pcg64::seeded(13);
        let a = Mat::randn(9, 21, 1.0, &mut rng);
        let b = Mat::randn(21, 6, 1.0, &mut rng);
        let c1 = matmul(&a, &b);
        let c2 = matmul_transb(&a, &b.transpose());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    /// The blocked transposed-B kernel is pinned *bitwise* to the
    /// unblocked panel-dot reference: N blocking, row partitioning and
    /// column partitioning change loop structure but every element is
    /// still the same ascending-k chain of panel dots, so no roundoff
    /// drift is tolerated. Shapes span partial K panels (k=300 > KC),
    /// partial N panels (n=300 > TRANSB_NC), the parallel row path (m=70 ≥
    /// PAR_MIN_ROWS), and degenerate edges.
    #[test]
    fn transb_blocked_bitwise_equals_naive() {
        let mut rng = Pcg64::seeded(15);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 300, 140),  // two K panels, ragged second
            (5, 64, 300),   // three N panels, ragged third
            (70, 257, 131), // parallel rows + ragged K and N panels
            (2, 70, 600),   // column-parallel path (decode head shape)
            (1, 128, 519),  // column-parallel, ragged last column chunk
        ] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b_t = Mat::randn(n, k, 1.0, &mut rng);
            let got = matmul_transb(&a, &b_t);
            let want = naive_transb(&a, &b_t);
            assert_eq!(got.data, want.data, "m={m} k={k} n={n}");
        }
    }

    /// ...and bit-identical across pool sizes, on both the row-parallel
    /// (tall) and column-parallel (decode-head-shaped) partitions.
    #[test]
    fn transb_bitwise_invariant_across_pool_sizes() {
        let mut rng = Pcg64::seeded(16);
        for &(m, k, n) in &[(96usize, 77usize, 50usize), (2, 77, 600), (1, 64, 519)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b_t = Mat::randn(n, k, 1.0, &mut rng);
            let p1 = ThreadPool::new(1);
            let base = matmul_transb_on(&p1, &a, &b_t);
            for threads in [2usize, 8] {
                let p = ThreadPool::new(threads);
                assert_eq!(
                    matmul_transb_on(&p, &a, &b_t).data,
                    base.data,
                    "m={m} n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn bias_broadcasts() {
        let a = Mat::from_vec(2, 2, vec![1., 0., 0., 1.]);
        let b = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let c = matmul_bias(&a, &b, &[10.0, 20.0]);
        assert_eq!(c.data, vec![11., 22., 13., 24.]);
    }

    /// `matmul_bias_on` is bit-identical across pool sizes and matches
    /// the global-pool `matmul_bias`.
    #[test]
    fn matmul_bias_bitwise_invariant_across_pool_sizes() {
        let mut rng = Pcg64::seeded(19);
        let a = Mat::randn(80, 33, 1.0, &mut rng);
        let b = Mat::randn(33, 47, 1.0, &mut rng);
        let bias: Vec<f32> = (0..47).map(|_| rng.gaussian()).collect();
        let base = matmul_bias_on(&ThreadPool::new(1), &a, &b, &bias);
        for threads in [2usize, 8] {
            let p = ThreadPool::new(threads);
            assert_eq!(matmul_bias_on(&p, &a, &b, &bias).data, base.data, "threads={threads}");
        }
        assert_eq!(matmul_bias(&a, &b, &bias).data, base.data);
    }

    /// Property: (A@B)@C == A@(B@C) within tolerance, over random shapes.
    #[test]
    fn prop_associativity() {
        let mut rng = Pcg64::seeded(14);
        for _ in 0..10 {
            let m = 1 + rng.below_usize(12);
            let k1 = 1 + rng.below_usize(12);
            let k2 = 1 + rng.below_usize(12);
            let n = 1 + rng.below_usize(12);
            let a = Mat::randn(m, k1, 0.5, &mut rng);
            let b = Mat::randn(k1, k2, 0.5, &mut rng);
            let c = Mat::randn(k2, n, 0.5, &mut rng);
            let l = matmul(&matmul(&a, &b), &c);
            let r = matmul(&a, &matmul(&b, &c));
            for (x, y) in l.data.iter().zip(&r.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    /// Dense matmul bit-identical across pool sizes (row partitioning
    /// never touches accumulation order).
    #[test]
    fn matmul_bitwise_invariant_across_pool_sizes() {
        let mut rng = Pcg64::seeded(18);
        let a = Mat::randn(80, 33, 1.0, &mut rng);
        let b = Mat::randn(33, 47, 1.0, &mut rng);
        let base = matmul_on(&ThreadPool::new(1), &a, &b);
        for threads in [2usize, 8] {
            let p = ThreadPool::new(threads);
            assert_eq!(matmul_on(&p, &a, &b).data, base.data, "threads={threads}");
        }
    }
}
