//! Cache-blocked, multi-threaded GEMM on row-major `f32` matrices.
//!
//! The native hot path (GPTQ Hessians, perplexity eval, the artifact-free
//! serving fallback) is GEMM-bound, so this is written for throughput:
//! k-panel blocking for L1/L2 reuse, 1x8 inner kernels that the compiler
//! auto-vectorizes, and row-parallelism over a scoped thread pool for large
//! outputs. No unsafe, no external deps.

use super::Mat;

/// Rows below this stay single-threaded (thread spawn isn't free).
const PAR_MIN_ROWS: usize = 64;
/// K-panel size (fits comfortably in L1 alongside the output strip).
const KC: usize = 256;
/// N-panel size.
const NC: usize = 512;

/// `C = A @ B` (rows_a x k) @ (k x cols_b).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A @ B + bias` where `bias` broadcasts over rows.
pub fn matmul_bias(a: &Mat, b: &Mat, bias: &[f32]) -> Mat {
    let mut c = matmul(a, b);
    assert_eq!(bias.len(), c.cols);
    for r in 0..c.rows {
        let row = c.row_mut(r);
        for (x, &bv) in row.iter_mut().zip(bias) {
            *x += bv;
        }
    }
    c
}

/// `C = A @ B^T` — used when weights are stored out-feature-major.
pub fn matmul_transb(a: &Mat, b_t: &Mat) -> Mat {
    assert_eq!(a.cols, b_t.cols, "matmul_transb inner-dim mismatch");
    let m = a.rows;
    let n = b_t.rows;
    let k = a.cols;
    let mut c = Mat::zeros(m, n);
    let body = |r0: usize, r1: usize, out: &mut [f32]| {
        for r in r0..r1 {
            let arow = a.row(r);
            let crow = &mut out[(r - r0) * n..(r - r0 + 1) * n];
            for j in 0..n {
                let brow = b_t.row(j);
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                crow[j] = acc;
            }
        }
    };
    run_row_parallel(m, n, &mut c.data, &body);
    c
}

/// In-place `C = A @ B`; `c` must be pre-shaped (rows_a x cols_b).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    let k = a.cols;
    c.data.iter_mut().for_each(|x| *x = 0.0);
    let body = |r0: usize, r1: usize, out: &mut [f32]| {
        // i-k-j loop order with k/n panel blocking: B rows stream through
        // cache, C strip stays hot.
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for nb in (0..n).step_by(NC) {
                let nend = (nb + NC).min(n);
                for r in r0..r1 {
                    let arow = a.row(r);
                    let crow = &mut out[(r - r0) * n..(r - r0 + 1) * n];
                    for kk in kb..kend {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n + nb..kk * n + nend];
                        let cslice = &mut crow[nb..nend];
                        for (cv, &bv) in cslice.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            }
        }
    };
    run_row_parallel(a.rows, n, &mut c.data, &body);
}

/// Split rows across scoped threads; each thread writes its own disjoint
/// slice of the output buffer. Shared with the fused dequant GEMM in
/// `quant::fused`, which parallelizes the same way.
pub(crate) fn run_row_parallel<F>(m: usize, n: usize, out: &mut [f32], body: &F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let nthreads = available_threads();
    if m < PAR_MIN_ROWS || nthreads <= 1 {
        body(0, m, out);
        return;
    }
    let nchunks = nthreads.min(m);
    let chunk = m.div_ceil(nchunks);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + chunk).min(m);
            let (mine, tail) = rest.split_at_mut((r1 - r0) * n);
            rest = tail;
            let start = r0;
            s.spawn(move || body(start, r1, mine));
            r0 = r1;
        }
    });
}

/// Number of worker threads to use (overridable via EAC_MOE_THREADS).
pub fn available_threads() -> usize {
    static CACHE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("EAC_MOE_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for kk in 0..a.cols {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (7, 13, 2), (16, 16, 16)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            for (x, y) in c.data.iter().zip(&r.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matches_naive_large_parallel() {
        let mut rng = Pcg64::seeded(12);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn transb_matches() {
        let mut rng = Pcg64::seeded(13);
        let a = Mat::randn(9, 21, 1.0, &mut rng);
        let b = Mat::randn(21, 6, 1.0, &mut rng);
        let c1 = matmul(&a, &b);
        let c2 = matmul_transb(&a, &b.transpose());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn bias_broadcasts() {
        let a = Mat::from_vec(2, 2, vec![1., 0., 0., 1.]);
        let b = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let c = matmul_bias(&a, &b, &[10.0, 20.0]);
        assert_eq!(c.data, vec![11., 22., 13., 24.]);
    }

    /// Property: (A@B)@C == A@(B@C) within tolerance, over random shapes.
    #[test]
    fn prop_associativity() {
        let mut rng = Pcg64::seeded(14);
        for _ in 0..10 {
            let m = 1 + rng.below_usize(12);
            let k1 = 1 + rng.below_usize(12);
            let k2 = 1 + rng.below_usize(12);
            let n = 1 + rng.below_usize(12);
            let a = Mat::randn(m, k1, 0.5, &mut rng);
            let b = Mat::randn(k1, k2, 0.5, &mut rng);
            let c = Mat::randn(k2, n, 0.5, &mut rng);
            let l = matmul(&matmul(&a, &b), &c);
            let r = matmul(&a, &matmul(&b, &c));
            for (x, y) in l.data.iter().zip(&r.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }
}
