//! Elementwise / reduction ops used by the transformer forward and the
//! compression pipeline: softmax, silu, rmsnorm, top-k, cross-entropy,
//! cosine similarity.

use super::Mat;

/// In-place numerically-stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum.max(f32::MIN_POSITIVE);
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Softmax over each row of a matrix, returning a new matrix.
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for r in 0..out.rows {
        softmax_inplace(out.row_mut(r));
    }
    out
}

/// log-softmax of one row, written into `out`.
pub fn log_softmax_into(xs: &[f32], out: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let logsum = xs.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x - logsum;
    }
}

/// SiLU activation x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// GeLU (tanh approximation).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

/// RMSNorm over each row: x / rms(x) * gain.
pub fn rmsnorm(m: &Mat, gain: &[f32], eps: f32) -> Mat {
    assert_eq!(gain.len(), m.cols);
    let mut out = Mat::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let row = m.row(r);
        let ms = row.iter().map(|x| x * x).sum::<f32>() / m.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(r);
        for ((o, &x), &g) in orow.iter_mut().zip(row).zip(gain) {
            *o = x * inv * g;
        }
    }
    out
}

/// Indices of the k largest values, in descending value order.
/// Ties broken by lower index first (deterministic).
///
/// The decode hot path calls this with k=1 on a `vocab`-long row every
/// step for every sequence; a full index sort there is O(V log V) of
/// wasted work. k=1 is a single max pass and k>1 partitions the top k to
/// the front (`select_nth_unstable_by`) before sorting only those k —
/// both pinned equal (including the lower-index tie-break) to the full
/// sort by `prop_topk_matches_full_sort`.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    if k == 0 {
        return Vec::new();
    }
    let cmp = |a: &usize, b: &usize| {
        xs[*b].partial_cmp(&xs[*a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(b))
    };
    if k == 1 {
        // Single-pass argmax; strict `>` keeps the first (lowest) index
        // on ties, matching the sort's tie-break.
        let mut best = 0usize;
        for (i, &x) in xs.iter().enumerate().skip(1) {
            if x > xs[best] {
                best = i;
            }
        }
        return vec![best];
    }
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx
}

/// Cross-entropy of target ids under row logits; returns mean NLL (nats).
pub fn cross_entropy(logits: &Mat, targets: &[u32]) -> f32 {
    assert_eq!(logits.rows, targets.len());
    let mut scratch = vec![0.0f32; logits.cols];
    let mut total = 0.0f64;
    for (r, &t) in targets.iter().enumerate() {
        log_softmax_into(logits.row(r), &mut scratch);
        total -= scratch[t as usize] as f64;
    }
    (total / targets.len().max(1) as f64) as f32
}

/// Cosine similarity of two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x * y) as f64).sum();
    let na: f64 = a.iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na * nb)) as f32
}

/// Elementwise a += b.
pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Elementwise a += s * b (axpy). Runs on the runtime-dispatched SIMD
/// kernel; all dispatch levels are bit-identical to the scalar loop.
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    assert_eq!(a.len(), b.len());
    crate::tensor::simd::axpy(a, s, b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut xs = vec![1000.0, 1001.0];
        softmax_inplace(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let xs = [0.3f32, -1.2, 2.5, 0.0];
        let mut ls = [0.0f32; 4];
        log_softmax_into(&xs, &mut ls);
        let mut sm = xs.to_vec();
        softmax_inplace(&mut sm);
        for (l, s) in ls.iter().zip(&sm) {
            assert!((l.exp() - s).abs() < 1e-5);
        }
    }

    #[test]
    fn topk_orders_and_breaks_ties() {
        let xs = [1.0f32, 5.0, 5.0, 0.0];
        assert_eq!(topk_indices(&xs, 3), vec![1, 2, 0]);
        assert_eq!(topk_indices(&xs, 10).len(), 4);
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let mut rng = Pcg64::seeded(7);
        let m = Mat::randn(3, 64, 2.0, &mut rng);
        let gain = vec![1.0; 64];
        let n = rmsnorm(&m, &gain, 1e-6);
        for r in 0..3 {
            let ms = n.row(r).iter().map(|x| x * x).sum::<f32>() / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms^2={ms}");
        }
    }

    #[test]
    fn cross_entropy_perfect_prediction_near_zero() {
        // One-hot-ish logits on the target -> tiny NLL.
        let mut logits = Mat::zeros(2, 4);
        *logits.at_mut(0, 1) = 50.0;
        *logits.at_mut(1, 3) = 50.0;
        let ce = cross_entropy(&logits, &[1, 3]);
        assert!(ce < 1e-3, "ce={ce}");
        // Uniform logits -> ln(4).
        let uni = Mat::zeros(2, 4);
        let ce_u = cross_entropy(&uni, &[0, 2]);
        assert!((ce_u - (4.0f32).ln()).abs() < 1e-4);
    }

    #[test]
    fn cosine_bounds_and_identity() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [-1.0f32, -2.0, -3.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        assert!((cosine(&a, &b) + 1.0).abs() < 1e-6);
    }

    /// Property: the partial-selection topk equals the full index sort it
    /// replaced, element for element (order and tie-breaking included),
    /// for every k — this is what pins the decode argmax optimization.
    #[test]
    fn prop_topk_matches_full_sort() {
        let reference = |xs: &[f32], k: usize| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..xs.len()).collect();
            idx.sort_by(|&a, &b| {
                xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
            });
            idx.truncate(k.min(xs.len()));
            idx
        };
        let mut rng = Pcg64::seeded(13);
        for case in 0..50 {
            let n = 1 + rng.below_usize(60);
            // Mix in heavy ties: quantize half the cases to few levels.
            let xs: Vec<f32> = (0..n)
                .map(|_| {
                    let v = rng.gaussian();
                    if case % 2 == 0 { (v * 2.0).round() / 2.0 } else { v }
                })
                .collect();
            for k in [0usize, 1, 2, n / 2, n.saturating_sub(1), n, n + 3] {
                assert_eq!(
                    topk_indices(&xs, k),
                    reference(&xs, k),
                    "n={n} k={k} xs={xs:?}"
                );
            }
        }
    }

    /// Property: topk of a permuted array returns the same value multiset.
    #[test]
    fn prop_topk_permutation_invariant() {
        let mut rng = Pcg64::seeded(8);
        for _ in 0..20 {
            let n = 4 + rng.below_usize(40);
            let k = 1 + rng.below_usize(n);
            let xs: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let ys: Vec<f32> = perm.iter().map(|&i| xs[i]).collect();
            let mut v1: Vec<f32> = topk_indices(&xs, k).iter().map(|&i| xs[i]).collect();
            let mut v2: Vec<f32> = topk_indices(&ys, k).iter().map(|&i| ys[i]).collect();
            v1.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v2.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(v1, v2);
        }
    }
}
