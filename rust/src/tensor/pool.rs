//! Persistent scoped worker pool — the parallelism substrate for every hot
//! path (row-parallel GEMMs, expert-level MoE dispatch, head-level
//! attention).
//!
//! The old `run_row_parallel` spawned fresh OS threads on every GEMM call,
//! which priced parallelism out of exactly the GEMMs decode is made of
//! (B-row projections, a handful of routed tokens per expert). This pool is
//! sized **once at construction** (no per-call spawns) and exposes a
//! crossbeam-style scoped-task API, so callers can fan borrowed work out
//! across long-lived workers:
//!
//! ```text
//! pool.scope(|s| {
//!     for chunk in out.chunks_mut(n) {
//!         s.spawn(move || fill(chunk));   // borrows OK: scope() joins all
//!     }
//! });                                      // tasks before returning
//! ```
//!
//! Design points:
//!
//! - **Scope barrier**: `scope` does not return (or unwind) until every
//!   task spawned inside it has finished. That barrier is what makes the
//!   lifetime erasure in `spawn` sound — a task can borrow stack data from
//!   the caller because the borrow provably outlives the task.
//! - **Helping**: a thread waiting on its scope pops and runs queued tasks
//!   (any scope's) instead of blocking. Nested scopes — an expert task
//!   whose inner GEMM row-parallelizes, a worker batch inside an engine
//!   worker — therefore cannot deadlock: whoever waits, works.
//! - **Panics propagate**: a panicking task is caught on the worker (the
//!   worker survives), recorded on its scope, and re-thrown from `scope` on
//!   the calling thread — same observable behavior as `std::thread::scope`.
//! - **Determinism**: the pool only affects *where* tasks run, never what
//!   they compute. All users partition output disjointly and keep
//!   per-element accumulation order fixed, so results are bit-identical at
//!   every pool size (pinned by `tests/thread_invariance.rs`).
//! - **`threads == 1` is truly sequential**: no worker threads exist and
//!   `spawn` runs the task inline, so a size-1 pool is an exact
//!   single-threaded execution (useful for tests and debugging).
//!
//! `EAC_MOE_THREADS` is read once, when the **global** pool is first
//! constructed ([`ThreadPool::global`]) — not latched by whichever GEMM
//! runs first, as the old `OnceLock` cache did. Code that needs a specific
//! size (tests, `EngineConfig::threads`) builds its own pool explicitly and
//! is immune to the environment entirely.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Row counts below this run inline in [`ThreadPool::run_rows`]: even with
/// persistent workers, handing out a task costs a queue round-trip, and a
/// few rows of GEMM are cheaper than that. Decode-sized GEMMs get their
/// parallelism from expert- and head-level tasks instead.
pub(crate) const PAR_MIN_ROWS: usize = 64;

/// A queued task. Lifetime-erased to `'static`; soundness comes from the
/// scope barrier (see [`PoolScope::spawn`]).
type Task = Box<dyn FnOnce() + Send + 'static>;

#[derive(Default)]
struct Queue {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    /// Signaled when a task is pushed or shutdown begins.
    available: Condvar,
}

/// Per-scope completion state: outstanding task count + first panic.
#[derive(Default)]
struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Persistent worker pool with scoped tasks. See the module docs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    start: std::sync::Once,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool that runs up to `threads` tasks concurrently
    /// (`threads - 1` dedicated workers; the thread calling `scope` is the
    /// last lane, since it helps while waiting). `threads` is clamped to at
    /// least 1; a size-1 pool runs everything inline. The size is fixed
    /// here, but the worker OS threads start lazily on the first queued
    /// task — a pool that is constructed and then shadowed (e.g. the
    /// global pool when `EngineConfig::threads` installs a dedicated one)
    /// costs nothing.
    pub fn new(threads: usize) -> Self {
        ThreadPool {
            shared: Arc::new(Shared {
                queue: Mutex::new(Queue::default()),
                available: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
            start: std::sync::Once::new(),
            threads: threads.max(1),
        }
    }

    /// Spawn the `threads - 1` worker threads, once, on first use.
    fn ensure_started(&self) {
        self.start.call_once(|| {
            let mut handles = self.handles.lock().unwrap();
            for i in 0..self.threads - 1 {
                let shared = self.shared.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("eac-moe-pool-{i}"))
                    .spawn(move || worker_loop(&shared));
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        // Degraded but correct: scope waiters help-execute
                        // queued tasks, so every scope still completes with
                        // fewer workers — even zero.
                        eprintln!(
                            "eac-moe pool: spawn worker {i} failed ({e}); \
                             continuing with {} workers",
                            handles.len()
                        );
                        break;
                    }
                }
            }
        });
    }

    /// The process-global pool, built on first use with
    /// [`threads_from_env`]. This is the pool behind the free `matmul`
    /// functions and `Model::new`.
    pub fn global() -> &'static Arc<ThreadPool> {
        static GLOBAL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(ThreadPool::new(threads_from_env())))
    }

    /// Concurrency of this pool (the constructor argument, clamped ≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` with a scope handle; every task spawned on the handle has
    /// completed by the time `scope` returns. If any task (or `f` itself)
    /// panicked, the panic is re-thrown here after all tasks finish.
    pub fn scope<'env, F, T>(&'env self, f: F) -> T
    where
        F: FnOnce(&PoolScope<'env>) -> T,
    {
        let state = Arc::new(ScopeState::default());
        let scope = PoolScope { pool: self, state: state.clone(), env: PhantomData };
        // Catch so an unwinding `f` still waits for already-spawned tasks —
        // they borrow the caller's stack and must not outlive it.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| f(&scope)));
        self.wait_scope(&state);
        let task_panic = state.panic.lock().unwrap().take();
        match result {
            Err(p) => std::panic::resume_unwind(p),
            Ok(r) => {
                if let Some(p) = task_panic {
                    std::panic::resume_unwind(p);
                }
                r
            }
        }
    }

    /// Split `m` rows of an `(m, n)` output across the pool; each task gets
    /// a disjoint `&mut` strip. `body(r0, r1, strip)` computes rows
    /// `r0..r1` into `strip`. Small outputs run inline (task handoff isn't
    /// free). Partitioning never changes per-element accumulation order —
    /// each row is computed whole by exactly one task — so results are
    /// bit-identical at every pool size.
    pub fn run_rows<F>(&self, m: usize, n: usize, out: &mut [f32], body: &F)
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        if m < PAR_MIN_ROWS || self.threads <= 1 {
            body(0, m, out);
            return;
        }
        let nchunks = self.threads.min(m);
        let chunk = m.div_ceil(nchunks);
        self.scope(|s| {
            let mut rest = out;
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + chunk).min(m);
                let (mine, tail) = rest.split_at_mut((r1 - r0) * n);
                rest = tail;
                let start = r0;
                s.spawn(move || body(start, r1, mine));
                r0 = r1;
            }
        });
    }

    fn push(&self, task: Task) {
        self.shared.queue.lock().unwrap().tasks.push_back(task);
        self.shared.available.notify_one();
    }

    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().tasks.pop_front()
    }

    /// Block until `state.pending == 0`, executing queued tasks while
    /// waiting ("helping"). Helping is what makes nested scopes safe: a
    /// worker waiting on an inner scope drains the queue instead of
    /// deadlocking on itself.
    fn wait_scope(&self, state: &ScopeState) {
        loop {
            if *state.pending.lock().unwrap() == 0 {
                return;
            }
            if let Some(task) = self.try_pop() {
                task();
                continue;
            }
            // Queue empty: our remaining tasks are running on other
            // threads (they were queued before this wait began and the pop
            // above would have found them otherwise). Sleep until one
            // completes.
            let mut pending = state.pending.lock().unwrap();
            while *pending != 0 {
                pending = state.done.wait(pending).unwrap();
            }
            return;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        let handles =
            std::mem::take(&mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads).finish()
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break t;
                }
                if q.shutdown {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        // Task wrappers catch their own panics (into their scope's state),
        // so the worker thread survives any task.
        task();
    }
}

/// Scoped spawn handle passed to the closure of [`ThreadPool::scope`].
/// `'env` is invariant (the `PhantomData`) so it cannot be shrunk to smuggle
/// shorter-lived borrows into tasks.
pub struct PoolScope<'env> {
    pool: &'env ThreadPool,
    state: Arc<ScopeState>,
    env: PhantomData<&'env mut &'env ()>,
}

impl<'env> PoolScope<'env> {
    /// Queue `f` on the pool. On a size-1 pool it runs inline, in spawn
    /// order — which is why sequential and parallel executions of the same
    /// scope are the same program, just scheduled differently.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        if self.pool.threads <= 1 {
            f();
            return;
        }
        self.pool.ensure_started();
        *self.state.pending.lock().unwrap() += 1;
        let state = self.state.clone();
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = state.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: `scope` blocks until `pending` reaches 0 — on success
        // *and* on unwind — so this closure (and everything it borrows,
        // which lives at least `'env`) is done executing before any
        // borrowed data can be invalidated. The transmute only erases the
        // lifetime; the fat-pointer layout is identical.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
        };
        self.pool.push(task);
    }
}

/// Pool size from the environment: `EAC_MOE_THREADS` if set and parseable
/// (clamped ≥ 1), else the machine's available parallelism. Read at pool
/// construction — constructing a pool is the only thing that latches it.
pub fn threads_from_env() -> usize {
    match crate::util::env::threads() {
        Some(n) => n.max(1),
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_all_tasks_with_borrows() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        pool.scope(|s| {
            for (i, slot) in out.iter_mut().enumerate() {
                s.spawn(move || *slot = i * i);
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn size_one_pool_runs_inline_in_order() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        assert!(pool.handles.lock().unwrap().is_empty());
        // Inline execution runs each task before `spawn` returns, so the
        // observed order is exactly spawn order.
        let order = Mutex::new(Vec::new());
        pool.scope(|s| {
            for i in 0..8 {
                let order = &order;
                s.spawn(move || order.lock().unwrap().push(i));
            }
        });
        assert_eq!(order.into_inner().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn workers_start_lazily() {
        // Constructing a pool costs no OS threads; they appear on the
        // first queued task (so a constructed-then-shadowed pool is free).
        let pool = ThreadPool::new(4);
        assert!(pool.handles.lock().unwrap().is_empty());
        pool.scope(|s| s.spawn(|| {}));
        assert_eq!(pool.handles.lock().unwrap().len(), 3);
    }

    #[test]
    fn nested_scopes_complete() {
        // An outer task fans out inner tasks on the same (small) pool; the
        // helping waiter must drain them rather than deadlock.
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let pool = &pool;
                let count = &count;
                s.spawn(move || {
                    pool.scope(|inner| {
                        for _ in 0..8 {
                            inner.spawn(|| {
                                count.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn task_panic_propagates_after_barrier() {
        let pool = ThreadPool::new(3);
        let done = AtomicUsize::new(0);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("task boom"));
                for _ in 0..8 {
                    s.spawn(|| {
                        done.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }));
        assert!(res.is_err(), "scope must re-throw the task panic");
        // Barrier held: the healthy tasks all finished despite the panic.
        assert_eq!(done.load(Ordering::Relaxed), 8);
        // ...and the pool is still usable afterwards (workers survived).
        let mut v = vec![0u8; 16];
        pool.scope(|s| {
            for slot in v.iter_mut() {
                s.spawn(move || *slot = 7);
            }
        });
        assert!(v.iter().all(|&x| x == 7));
    }

    #[test]
    fn run_rows_partitions_disjointly() {
        let pool = ThreadPool::new(4);
        let (m, n) = (130, 3);
        let mut out = vec![0f32; m * n];
        let body = |r0: usize, r1: usize, strip: &mut [f32]| {
            for r in r0..r1 {
                for c in 0..n {
                    strip[(r - r0) * n + c] = (r * n + c) as f32;
                }
            }
        };
        pool.run_rows(m, n, &mut out, &body);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
        // Small m runs inline through the same entry point.
        let mut small = vec![0f32; 5 * n];
        pool.run_rows(5, n, &mut small, &body);
        for (i, &v) in small.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn env_threads_clamped() {
        // Parse logic only (the env var itself is process-global state that
        // other tests may depend on, so don't set it here).
        assert_eq!("0".parse::<usize>().unwrap().max(1), 1);
        assert!(threads_from_env() >= 1);
    }
}
