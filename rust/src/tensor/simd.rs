//! Runtime-dispatched SIMD microkernels for the GEMM / dequant / attention
//! hot loops.
//!
//! # Dispatch order
//!
//! Every public kernel resolves its implementation per call, in this order:
//!
//! 1. a test/bench override installed with [`force`] (process-global);
//! 2. `EAC_MOE_NO_SIMD=1` in the environment (read once, at first use) —
//!    pins the scalar reference path;
//! 3. runtime CPU detection: AVX2 on `x86_64` (FMA ships on every AVX2
//!    part, but see below for why the kernels still don't emit it), NEON
//!    on `aarch64`;
//! 4. the scalar fallback, which is always compiled on every target.
//!
//! # The bitwise-invariance contract
//!
//! The repo pins outputs bit-identical across pool sizes, batch shapes,
//! expert budgets and prefill/decode replay — SIMD must not be the thing
//! that breaks that. So every kernel here is defined such that **all
//! dispatch levels produce bitwise-identical results**:
//!
//! - Elementwise kernels ([`axpy`], [`axpy_i8`], [`affine`],
//!   [`bytes_to_f32`]) vectorize over independent output elements using
//!   separate multiply and add instructions — never fused multiply-add.
//!   Each lane performs exactly the IEEE-754 operations the scalar loop
//!   performs (Rust/LLVM does not contract `a * b + c` by default), so the
//!   vector path is bit-identical to scalar *and* to the pre-SIMD code.
//! - Reduction kernels ([`dot`], [`dot_i8`]) cannot keep the old
//!   sequential summation order and still vectorize, so their summation
//!   order is *redefined* as a fixed 8-lane split: lane `l` accumulates
//!   elements `8j + l` sequentially, the 8 lane sums combine through the
//!   fixed tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` (what one
//!   `__m256` horizontal reduction does), and any tail elements are added
//!   sequentially after. The scalar, AVX2 and NEON implementations all
//!   execute that exact operation DAG, so they agree bitwise at every
//!   length — including lengths that are not multiples of the lane width.
//!
//! FMA is deliberately not used anywhere: fusing would change results
//! vs. the separate mul+add scalar reference (and `f32::mul_add` on the
//! scalar side would drop to a slow libm call on default x86-64 targets,
//! making `EAC_MOE_NO_SIMD=1` runs pathologically slow). The `no-fma`
//! xtask lint enforces this mechanically across the tree; if a pinned-DAG
//! variant ever legitimately needs a fused op, it goes inside an
//! allow-region in this file (the only file the linter permits one in).
//!
//! Under Miri the vector modules are compiled out (`cfg(miri)`) and
//! detection pins Scalar — vendor intrinsics aren't supported there, and
//! the scalar path is the semantic definition of every kernel anyway.
//!
//! # Why dequantization stays per-group
//!
//! [`affine`] corrects one *quantization group* at a time
//! (`(code - zero) * scale` with a single scale/zero pair), rather than
//! folding the correction into a whole-column or whole-tile kernel. That
//! keeps the dequant expression exactly where the packed format defines
//! it — per group — so a future mixed-precision allocator (GEMQ-style:
//! different bit-widths or group sizes per expert / per column block,
//! ROADMAP open item 1) can ride the same kernels unchanged: each group,
//! whatever its width or precision, is still one `affine` call over its
//! unpacked codes.
//!
//! # Call sites
//!
//! - `tensor/matmul.rs`: dense `matmul*` row-accumulate ([`axpy`]) and
//!   `matmul_transb*` per-panel dots ([`dot`]);
//! - `quant/fused.rs`: packed-GEMM strip consumer ([`axpy`]) and
//!   `unpack_tile`'s affine correction ([`affine`]) / 8-bit code widening
//!   ([`bytes_to_f32`]);
//! - `tensor/ops.rs`: the MoE scatter `axpy`;
//! - `model/forward.rs`: decode attention scores ([`dot`] / [`dot_i8`])
//!   and context accumulation ([`axpy`] / [`axpy_i8`]) — the `_i8`
//!   variants fuse int8 KV-cache dequantization into the attention reads.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A dispatch level. All variants exist on every target so tests can name
/// them portably; [`available`] reports which ones this host can run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar reference (always available).
    Scalar,
    /// 8-wide AVX2 path (`x86_64` with runtime `avx2`).
    Avx2,
    /// 4-wide NEON path (`aarch64`; NEON is baseline there).
    Neon,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }
}

/// 0 = no override; 1/2/3 = forced Scalar/Avx2/Neon.
static FORCED: AtomicU8 = AtomicU8::new(0);

fn detected() -> Kernel {
    static DETECTED: OnceLock<Kernel> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if crate::util::env::no_simd() {
            return Kernel::Scalar;
        }
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
        }
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    })
}

/// The dispatch level kernels currently resolve to (override > env >
/// detection).
pub fn active() -> Kernel {
    match FORCED.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        2 => Kernel::Avx2,
        3 => Kernel::Neon,
        _ => detected(),
    }
}

/// Install (or with `None`, clear) a process-global dispatch override.
/// Only levels reported by [`available`] may be forced. Because every
/// level is bitwise-identical, racing overrides from concurrent tests
/// cannot change any result — they only change which implementation runs.
pub fn force(k: Option<Kernel>) {
    let v = match k {
        None => 0,
        Some(Kernel::Scalar) => 1,
        Some(Kernel::Avx2) => 2,
        Some(Kernel::Neon) => 3,
    };
    FORCED.store(v, Ordering::Relaxed);
}

/// Dispatch levels this host can actually execute (Scalar always;
/// Avx2/Neon per runtime detection, independent of `EAC_MOE_NO_SIMD`).
pub fn available() -> Vec<Kernel> {
    let mut v = vec![Kernel::Scalar];
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Kernel::Avx2);
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            v.push(Kernel::Neon);
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Public kernels (per-call dispatch; the branch is a relaxed atomic load
// plus a predictable match — noise next to the vector work).
// ---------------------------------------------------------------------------

/// `out[i] += a * x[i]` — bitwise identical at every dispatch level.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    match active() {
        // SAFETY: Avx2 is active only after runtime detection confirmed
        // the `avx2` target feature (forcing is limited to [`available`]
        // levels), so the target_feature fn's precondition holds.
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::axpy(out, a, x) },
        // SAFETY: Neon is active only after runtime detection confirmed
        // the `neon` target feature (baseline on aarch64).
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::axpy(out, a, x) },
        _ => scalar::axpy(out, a, x),
    }
}

/// `out[i] += a * (x[i] as f32)` — the int8 KV context accumulate, with
/// dequantization fused into the read. Bitwise identical at every level.
#[inline]
pub fn axpy_i8(out: &mut [f32], a: f32, x: &[i8]) {
    debug_assert_eq!(out.len(), x.len());
    match active() {
        // SAFETY: Avx2 active ⇒ runtime detection confirmed the `avx2`
        // target feature (see [`axpy`]).
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::axpy_i8(out, a, x) },
        // SAFETY: Neon active ⇒ runtime detection confirmed `neon`.
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::axpy_i8(out, a, x) },
        _ => scalar::axpy_i8(out, a, x),
    }
}

/// `buf[i] = (buf[i] - zero) * scale` — the per-group dequant affine
/// correction. Bitwise identical at every level.
#[inline]
pub fn affine(buf: &mut [f32], zero: f32, scale: f32) {
    match active() {
        // SAFETY: Avx2 active ⇒ runtime detection confirmed the `avx2`
        // target feature (see [`axpy`]).
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::affine(buf, zero, scale) },
        // SAFETY: Neon active ⇒ runtime detection confirmed `neon`.
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::affine(buf, zero, scale) },
        _ => scalar::affine(buf, zero, scale),
    }
}

/// `dst[i] = src[i] as f32` — widening convert for 8-bit packed codes
/// (exact for all u8 values, so trivially bitwise at every level).
#[inline]
pub fn bytes_to_f32(src: &[u8], dst: &mut [f32]) {
    debug_assert!(dst.len() >= src.len());
    match active() {
        // SAFETY: Avx2 active ⇒ runtime detection confirmed the `avx2`
        // target feature (see [`axpy`]).
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::bytes_to_f32(src, dst) },
        // SAFETY: Neon active ⇒ runtime detection confirmed `neon`.
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::bytes_to_f32(src, dst) },
        _ => scalar::bytes_to_f32(src, dst),
    }
}

/// Dot product under the fixed 8-lane split summation order (see module
/// docs). Bitwise identical at every dispatch level and every length.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match active() {
        // SAFETY: Avx2 active ⇒ runtime detection confirmed the `avx2`
        // target feature (see [`axpy`]).
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::dot(a, b) },
        // SAFETY: Neon active ⇒ runtime detection confirmed `neon`.
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::dot(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// `Σ a[i] * (k[i] as f32)` under the same fixed summation order as
/// [`dot`] — the int8 KV attention score, dequant fused into the read
/// (the caller applies the per-head scale once on the result).
#[inline]
pub fn dot_i8(a: &[f32], k: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), k.len());
    match active() {
        // SAFETY: Avx2 active ⇒ runtime detection confirmed the `avx2`
        // target feature (see [`axpy`]).
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        Kernel::Avx2 => unsafe { avx2::dot_i8(a, k) },
        // SAFETY: Neon active ⇒ runtime detection confirmed `neon`.
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        Kernel::Neon => unsafe { neon::dot_i8(a, k) },
        _ => scalar::dot_i8(a, k),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference — the semantic definition of every kernel.
// ---------------------------------------------------------------------------

mod scalar {
    pub(super) fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        for (o, &v) in out.iter_mut().zip(x) {
            *o += a * v;
        }
    }

    pub(super) fn axpy_i8(out: &mut [f32], a: f32, x: &[i8]) {
        for (o, &v) in out.iter_mut().zip(x) {
            *o += a * v as f32;
        }
    }

    pub(super) fn affine(buf: &mut [f32], zero: f32, scale: f32) {
        for v in buf.iter_mut() {
            *v = (*v - zero) * scale;
        }
    }

    pub(super) fn bytes_to_f32(src: &[u8], dst: &mut [f32]) {
        for (d, &b) in dst.iter_mut().zip(src) {
            *d = b as f32;
        }
    }

    /// The 8-lane split + fixed reduction tree, in scalar form. This IS
    /// the definition the vector paths replicate.
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc = [0f32; 8];
        let mut i = 0;
        while i < n8 {
            for (l, s) in acc.iter_mut().enumerate() {
                *s += a[i + l] * b[i + l];
            }
            i += 8;
        }
        let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
        for j in n8..n {
            s += a[j] * b[j];
        }
        s
    }

    pub(super) fn dot_i8(a: &[f32], k: &[i8]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc = [0f32; 8];
        let mut i = 0;
        while i < n8 {
            for (l, s) in acc.iter_mut().enumerate() {
                *s += a[i + l] * k[i + l] as f32;
            }
            i += 8;
        }
        let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
        for j in n8..n {
            s += a[j] * k[j] as f32;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// AVX2 (x86_64) — same per-element / per-lane operations as scalar.
// The vector modules are compiled out under Miri (vendor intrinsics are
// unsupported there) and detection pins Scalar, so Miri runs exercise the
// scalar reference, which is the semantic definition anyway.
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", not(miri)))]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of [l0..l7] through the fixed tree
    /// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — the same DAG the scalar
    /// reference spells out.
    // Register-only, so safe under `target_feature` — callable without
    // `unsafe` from the avx2 fns below, which share the feature contract.
    #[inline]
    #[target_feature(enable = "avx2")]
    fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
        let s2 = _mm_add_ps(s, _mm_movehl_ps(s, s)); // lane0=(l0+l4)+(l2+l6), lane1=(l1+l5)+(l3+l7)
        _mm_cvtss_f32(_mm_add_ss(s2, _mm_movehdup_ps(s2)))
    }

    // SAFETY: contract — caller must have verified the `avx2` feature
    // (the dispatch match does). Loads/stores stay in bounds: the vector
    // loop covers indices < n8 ≤ len in whole 8-lane strips.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len();
        let n8 = n & !7;
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both slices (x.len() == out.len()
            // per all call sites), so the 8-lane load/store stay in bounds.
            unsafe {
                let vx = _mm256_loadu_ps(x.as_ptr().add(i));
                let vo = _mm256_loadu_ps(out.as_ptr().add(i));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vx)));
            }
            i += 8;
        }
        for j in n8..n {
            out[j] += a * x[j];
        }
    }

    /// Sign-extend 8 i8 codes to 8 f32 lanes (exact for |v| <= 127).
    // SAFETY: contract — `p` must be valid for reading 8 bytes
    // (`_mm_loadl_epi64` reads exactly 8) and `avx2` must be verified.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_i8_as_f32(p: *const i8) -> __m256 {
        // SAFETY: `p` is valid for 8 bytes per this fn's contract.
        let bytes = unsafe { _mm_loadl_epi64(p as *const __m128i) };
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes))
    }

    // SAFETY: contract — caller must have verified `avx2`. In-bounds: the
    // loop reads/writes 8-lane strips below n8 ≤ len of both slices
    // (out.len() == x.len() per the public wrapper's debug_assert and all
    // call sites).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_i8(out: &mut [f32], a: f32, x: &[i8]) {
        let n = out.len();
        let n8 = n & !7;
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both slices, so the 8-byte code
            // load and the 8-lane f32 load/store stay in bounds.
            unsafe {
                let vx = load_i8_as_f32(x.as_ptr().add(i));
                let vo = _mm256_loadu_ps(out.as_ptr().add(i));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vx)));
            }
            i += 8;
        }
        for j in n8..n {
            out[j] += a * x[j] as f32;
        }
    }

    // SAFETY: contract — caller must have verified `avx2`. In-bounds:
    // 8-lane strips below n8 ≤ len, scalar tail after.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn affine(buf: &mut [f32], zero: f32, scale: f32) {
        let n = buf.len();
        let n8 = n & !7;
        let vz = _mm256_set1_ps(zero);
        let vs = _mm256_set1_ps(scale);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ buf.len(), so load/store stay in bounds.
            unsafe {
                let v = _mm256_loadu_ps(buf.as_ptr().add(i));
                _mm256_storeu_ps(buf.as_mut_ptr().add(i), _mm256_mul_ps(_mm256_sub_ps(v, vz), vs));
            }
            i += 8;
        }
        for v in &mut buf[n8..] {
            *v = (*v - zero) * scale;
        }
    }

    // SAFETY: contract — caller must have verified `avx2`. In-bounds:
    // reads 8-byte strips below n8 ≤ src.len(); writes below n8 ≤
    // dst.len() (dst.len() >= src.len() per the public wrapper).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn bytes_to_f32(src: &[u8], dst: &mut [f32]) {
        let n = src.len();
        let n8 = n & !7;
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ src.len() ≤ dst.len(), so the 8-byte
            // load and 8-lane store stay in bounds.
            unsafe {
                let bytes = _mm_loadl_epi64(src.as_ptr().add(i) as *const __m128i);
                let v = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
            }
            i += 8;
        }
        for j in n8..n {
            dst[j] = src[j] as f32;
        }
    }

    // SAFETY: contract — caller must have verified `avx2`. In-bounds:
    // 8-lane strips below n8 ≤ len of both equal-length slices.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both equal-length slices.
            unsafe {
                let va = _mm256_loadu_ps(a.as_ptr().add(i));
                let vb = _mm256_loadu_ps(b.as_ptr().add(i));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            }
            i += 8;
        }
        let mut s = hsum(acc);
        for j in n8..n {
            s += a[j] * b[j];
        }
        s
    }

    // SAFETY: contract — caller must have verified `avx2`. In-bounds:
    // 8-lane strips below n8 ≤ len of both equal-length slices.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i8(a: &[f32], k: &[i8]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both equal-length slices.
            unsafe {
                let va = _mm256_loadu_ps(a.as_ptr().add(i));
                let vk = load_i8_as_f32(k.as_ptr().add(i));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vk));
            }
            i += 8;
        }
        let mut s = hsum(acc);
        for j in n8..n {
            s += a[j] * k[j] as f32;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64) — two q-register accumulators reproduce the 8-lane split;
// the final combine follows the same fixed tree as scalar/AVX2.
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "aarch64", not(miri)))]
mod neon {
    use std::arch::aarch64::*;

    // SAFETY: contract — caller must have verified the `neon` feature
    // (the dispatch match does). In-bounds: 4-lane strips below n4 ≤ len.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len();
        let n4 = n & !3;
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i < n4 {
            // SAFETY: i + 4 <= n4 ≤ len of both slices (x.len() == out.len()
            // per all call sites), so the 4-lane load/store stay in bounds.
            unsafe {
                let vx = vld1q_f32(x.as_ptr().add(i));
                let vo = vld1q_f32(out.as_ptr().add(i));
                vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(va, vx)));
            }
            i += 4;
        }
        for j in n4..n {
            out[j] += a * x[j];
        }
    }

    /// Sign-extend 8 i8 codes to two float32x4 registers (lanes 0-3, 4-7).
    // SAFETY: contract — `p` must be valid for reading 8 bytes (`vld1_s8`
    // reads exactly 8) and `neon` must be verified.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn load_i8_as_f32x2(p: *const i8) -> (float32x4_t, float32x4_t) {
        // SAFETY: `p` is valid for 8 bytes per this fn's contract.
        let wide = vmovl_s8(unsafe { vld1_s8(p) }); // 8 x i16
        let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(wide)));
        let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(wide)));
        (lo, hi)
    }

    // SAFETY: contract — caller must have verified `neon`. In-bounds:
    // 8-element strips below n8 ≤ len of both equal-length slices.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_i8(out: &mut [f32], a: f32, x: &[i8]) {
        let n = out.len();
        let n8 = n & !7;
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both slices, so the 8-byte code
            // load and both 4-lane f32 load/store pairs stay in bounds.
            unsafe {
                let (lo, hi) = load_i8_as_f32x2(x.as_ptr().add(i));
                let o0 = vld1q_f32(out.as_ptr().add(i));
                let o1 = vld1q_f32(out.as_ptr().add(i + 4));
                vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(o0, vmulq_f32(va, lo)));
                vst1q_f32(out.as_mut_ptr().add(i + 4), vaddq_f32(o1, vmulq_f32(va, hi)));
            }
            i += 8;
        }
        for j in n8..n {
            out[j] += a * x[j] as f32;
        }
    }

    // SAFETY: contract — caller must have verified `neon`. In-bounds:
    // 4-lane strips below n4 ≤ len, scalar tail after.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn affine(buf: &mut [f32], zero: f32, scale: f32) {
        let n = buf.len();
        let n4 = n & !3;
        let vz = vdupq_n_f32(zero);
        let vs = vdupq_n_f32(scale);
        let mut i = 0;
        while i < n4 {
            // SAFETY: i + 4 <= n4 ≤ buf.len(), so load/store stay in bounds.
            unsafe {
                let v = vld1q_f32(buf.as_ptr().add(i));
                vst1q_f32(buf.as_mut_ptr().add(i), vmulq_f32(vsubq_f32(v, vz), vs));
            }
            i += 4;
        }
        for v in &mut buf[n4..] {
            *v = (*v - zero) * scale;
        }
    }

    // SAFETY: contract — caller must have verified `neon`. In-bounds:
    // reads 8-byte strips below n8 ≤ src.len(); writes below n8 ≤
    // dst.len() (dst.len() >= src.len() per the public wrapper).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn bytes_to_f32(src: &[u8], dst: &mut [f32]) {
        let n = src.len();
        let n8 = n & !7;
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ src.len() ≤ dst.len(), so the 8-byte
            // load and both 4-lane stores stay in bounds.
            unsafe {
                let wide = vmovl_u8(vld1_u8(src.as_ptr().add(i)));
                let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
                let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
                vst1q_f32(dst.as_mut_ptr().add(i), lo);
                vst1q_f32(dst.as_mut_ptr().add(i + 4), hi);
            }
            i += 8;
        }
        for j in n8..n {
            dst[j] = src[j] as f32;
        }
    }

    /// Combine accumulators [l0..l3], [l4..l7] through the fixed tree.
    // Register-only, so safe under `target_feature` — callable without
    // `unsafe` from the neon fns below, which share the feature contract.
    #[inline]
    #[target_feature(enable = "neon")]
    fn combine(acc_lo: float32x4_t, acc_hi: float32x4_t) -> f32 {
        let s = vaddq_f32(acc_lo, acc_hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
        let t = vadd_f32(vget_low_f32(s), vget_high_f32(s)); // [(l0+l4)+(l2+l6), (l1+l5)+(l3+l7)]
        vget_lane_f32::<0>(t) + vget_lane_f32::<1>(t)
    }

    // SAFETY: contract — caller must have verified `neon`. In-bounds:
    // 8-element strips below n8 ≤ len of both equal-length slices.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both equal-length slices.
            unsafe {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                let b0 = vld1q_f32(b.as_ptr().add(i));
                let b1 = vld1q_f32(b.as_ptr().add(i + 4));
                acc_lo = vaddq_f32(acc_lo, vmulq_f32(a0, b0));
                acc_hi = vaddq_f32(acc_hi, vmulq_f32(a1, b1));
            }
            i += 8;
        }
        let mut s = combine(acc_lo, acc_hi);
        for j in n8..n {
            s += a[j] * b[j];
        }
        s
    }

    // SAFETY: contract — caller must have verified `neon`. In-bounds:
    // 8-element strips below n8 ≤ len of both equal-length slices.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_i8(a: &[f32], k: &[i8]) -> f32 {
        let n = a.len();
        let n8 = n & !7;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n8 {
            // SAFETY: i + 8 <= n8 ≤ len of both equal-length slices.
            unsafe {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                let (k0, k1) = load_i8_as_f32x2(k.as_ptr().add(i));
                acc_lo = vaddq_f32(acc_lo, vmulq_f32(a0, k0));
                acc_hi = vaddq_f32(acc_hi, vmulq_f32(a1, k1));
            }
            i += 8;
        }
        let mut s = combine(acc_lo, acc_hi);
        for j in n8..n {
            s += a[j] * k[j] as f32;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;
    use std::sync::Mutex;

    /// Serialize tests that install a forced dispatch level. (Racing
    /// forces cannot change results — all levels are bitwise equal — but
    /// serializing keeps each test actually exercising the level it
    /// names.)
    pub(crate) fn force_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn gauss(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian()).collect()
    }

    /// Lengths chosen to hit: empty, sub-lane, exact lane multiples, and
    /// odd tails around both the 4-wide and 8-wide boundaries.
    const LENGTHS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 100, 255, 256, 257];

    #[test]
    fn all_kernels_bitwise_equal_to_scalar_at_every_level() {
        let _g = force_lock();
        let mut rng = Pcg64::seeded(71);
        for &n in LENGTHS {
            let a = gauss(n, &mut rng);
            let b = gauss(n, &mut rng);
            let codes: Vec<i8> = (0..n).map(|_| (rng.below_usize(255) as i64 - 127) as i8).collect();
            let bytes: Vec<u8> = (0..n).map(|_| rng.below_usize(256) as u8).collect();
            let base = gauss(n, &mut rng);
            let (s, z) = (0.37f32, 3.0f32);
            // Scalar reference results.
            force(Some(Kernel::Scalar));
            let dot_ref = dot(&a, &b);
            let dot_i8_ref = dot_i8(&a, &codes);
            let mut axpy_ref = base.clone();
            axpy(&mut axpy_ref, 0.7, &a);
            let mut axpy_i8_ref = base.clone();
            axpy_i8(&mut axpy_i8_ref, 0.7, &codes);
            let mut aff_ref = base.clone();
            affine(&mut aff_ref, z, s);
            let mut b2f_ref = vec![0f32; n];
            bytes_to_f32(&bytes, &mut b2f_ref);
            for k in available() {
                force(Some(k));
                assert_eq!(dot(&a, &b).to_bits(), dot_ref.to_bits(), "dot n={n} k={k:?}");
                assert_eq!(dot_i8(&a, &codes).to_bits(), dot_i8_ref.to_bits(), "dot_i8 n={n} k={k:?}");
                let mut out = base.clone();
                axpy(&mut out, 0.7, &a);
                assert_eq!(out, axpy_ref, "axpy n={n} k={k:?}");
                let mut out = base.clone();
                axpy_i8(&mut out, 0.7, &codes);
                assert_eq!(out, axpy_i8_ref, "axpy_i8 n={n} k={k:?}");
                let mut out = base.clone();
                affine(&mut out, z, s);
                assert_eq!(out, aff_ref, "affine n={n} k={k:?}");
                let mut out = vec![0f32; n];
                bytes_to_f32(&bytes, &mut out);
                assert_eq!(out, b2f_ref, "bytes_to_f32 n={n} k={k:?}");
            }
            force(None);
        }
    }

    #[test]
    fn dot_close_to_sequential_reference() {
        // The split order is a *different* summation than sequential; it
        // must still agree to normal float tolerance.
        let _g = force_lock();
        force(None);
        let mut rng = Pcg64::seeded(72);
        for &n in &[1usize, 7, 64, 257, 1000] {
            let a = gauss(n, &mut rng);
            let b = gauss(n, &mut rng);
            let seq: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - seq).abs() <= 1e-3 * (1.0 + seq.abs()), "n={n}: {got} vs {seq}");
        }
    }

    #[test]
    fn affine_matches_pre_simd_expression() {
        // The affine kernel must reproduce `(v - zero) * scale` exactly —
        // this is the dequant expression fused.rs used before the SIMD
        // layer existed.
        let _g = force_lock();
        force(None);
        let mut rng = Pcg64::seeded(73);
        let vals = gauss(100, &mut rng);
        let mut got = vals.clone();
        affine(&mut got, 7.0, 0.021);
        for (g, v) in got.iter().zip(&vals) {
            assert_eq!(g.to_bits(), ((v - 7.0) * 0.021).to_bits());
        }
    }

    #[test]
    fn forced_level_is_reported_and_clearable() {
        let _g = force_lock();
        force(Some(Kernel::Scalar));
        assert_eq!(active(), Kernel::Scalar);
        force(None);
        assert_eq!(active(), detected());
        assert!(available().contains(&Kernel::Scalar));
    }
}
