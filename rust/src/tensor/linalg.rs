//! Small dense linear-algebra helpers: deterministic truncated SVD and
//! effective rank, used by expert merging (`prune::merge` factors each
//! absorbed expert's residual into a low-rank delta) and by the
//! pseudo-vs-native MoE analysis (`eval::expert_sim` ranks the router's
//! gate matrix). Calibration/analysis-time only — never on the serving
//! path.
//!
//! The SVD is computed from the Gram matrix of the smaller side
//! (`M·Mᵀ` when `rows <= cols`, else `Mᵀ·M`) via cyclic Jacobi rotations
//! with f64 internals. Jacobi is quadratically convergent, needs no
//! pivoting heuristics, and — crucially for this repo's bit-identity
//! discipline — is fully deterministic: fixed sweep order, fixed
//! accumulation order, no data-dependent branching beyond the scalar
//! rotation tests. The same input always factors to the same bits on
//! every pool size and SIMD level (it runs on neither).

use super::Mat;

/// Convergence threshold on the sum of squared off-diagonal entries,
/// relative to the trace norm; plus a hard sweep cap so a pathological
/// matrix terminates rather than spinning.
const JACOBI_MAX_SWEEPS: usize = 64;

/// Symmetric eigendecomposition of the `n×n` row-major matrix `a` by
/// cyclic Jacobi rotations, in place. Returns `(eigenvalues, v)` where
/// column `j` of the row-major `n×n` matrix `v` is the eigenvector for
/// `eigenvalues[j]`. Order is whatever the rotations leave; callers sort.
fn jacobi_eigh(a: &mut [f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    debug_assert_eq!(a.len(), n * n, "square matrix required");
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let scale: f64 = (0..n).map(|i| a[i * n + i].abs()).sum::<f64>().max(1e-300);
    for _sweep in 0..JACOBI_MAX_SWEEPS {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off <= (1e-26 * scale * scale).max(f64::MIN_POSITIVE) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq == 0.0 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (theta * theta + 1.0).sqrt())
                } else {
                    -1.0 / (-theta + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate columns p,q then rows p,q of `a`, and columns
                // p,q of the accumulated eigenvector matrix.
                for i in 0..n {
                    let aip = a[i * n + p];
                    let aiq = a[i * n + q];
                    a[i * n + p] = c * aip - s * aiq;
                    a[i * n + q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let api = a[p * n + i];
                    let aqi = a[q * n + i];
                    a[p * n + i] = c * api - s * aqi;
                    a[q * n + i] = s * api + c * aqi;
                }
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }
    let vals = (0..n).map(|i| a[i * n + i]).collect();
    (vals, v)
}

/// Gram matrix of the smaller side of `m`, in f64: `M·Mᵀ` (rows×rows)
/// when `rows <= cols`, else `Mᵀ·M` (cols×cols).
fn gram_small_side(m: &Mat) -> (Vec<f64>, usize) {
    let (rows, cols) = (m.rows, m.cols);
    let n = rows.min(cols);
    let mut g = vec![0f64; n * n];
    if rows <= cols {
        for i in 0..rows {
            for j in i..rows {
                let mut acc = 0f64;
                for t in 0..cols {
                    acc += m.at(i, t) as f64 * m.at(j, t) as f64;
                }
                g[i * n + j] = acc;
                g[j * n + i] = acc;
            }
        }
    } else {
        for i in 0..cols {
            for j in i..cols {
                let mut acc = 0f64;
                for t in 0..rows {
                    acc += m.at(t, i) as f64 * m.at(t, j) as f64;
                }
                g[i * n + j] = acc;
                g[j * n + i] = acc;
            }
        }
    }
    (g, n)
}

/// Eigenvalues of the small-side Gram matrix, sorted descending. These
/// are the squared singular values of `m`.
fn gram_eigvals_desc(m: &Mat) -> Vec<f64> {
    let (mut g, n) = gram_small_side(m);
    if n == 0 {
        return Vec::new();
    }
    let (vals, _) = jacobi_eigh(&mut g, n);
    let mut sorted = vals;
    sorted.sort_by(|a, b| b.total_cmp(a));
    sorted
}

/// Deterministic truncated SVD: returns `(u, v)` with `u` of shape
/// `(rows, r)` and `v` of shape `(r, cols)` such that `u @ v` is the best
/// rank-`r` approximation of `m`, where `r = min(rank, numerically
/// significant singular values)` but at least 1 (an all-zero `m` yields
/// zero factors of rank 1, so downstream GEMMs never see a 0-wide
/// matrix). The singular values are folded into the factors — callers
/// only ever multiply `u @ v`.
pub fn svd_truncated(m: &Mat, rank: usize) -> (Mat, Mat) {
    let (rows, cols) = (m.rows, m.cols);
    let want = rank.max(1);
    if rows == 0 || cols == 0 {
        return (Mat::zeros(rows, 1), Mat::zeros(1, cols));
    }
    let (mut g, n) = gram_small_side(m);
    let (vals, vecs) = jacobi_eigh(&mut g, n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| vals[j].total_cmp(&vals[i]));
    let lmax = vals[order[0]].max(0.0);
    // Keep eigen-directions whose λ clears both an absolute floor and a
    // relative one (λ = σ², so 1e-14·λmax ≈ 1e-7·σmax on σ).
    let kept: Vec<usize> = order
        .into_iter()
        .filter(|&i| vals[i] > (1e-14 * lmax).max(1e-24))
        .take(want)
        .collect();
    let r = kept.len();
    if r == 0 {
        return (Mat::zeros(rows, 1), Mat::zeros(1, cols));
    }
    let mut u = Mat::zeros(rows, r);
    let mut v = Mat::zeros(r, cols);
    if rows <= cols {
        // Eigenvectors of M·Mᵀ are the left singular vectors; the i-th
        // row of `v` is then uᵢᵀ·M (σ folded into v).
        for (ri, &ei) in kept.iter().enumerate() {
            for row in 0..rows {
                *u.at_mut(row, ri) = vecs[row * n + ei] as f32;
            }
            for col in 0..cols {
                let mut acc = 0f64;
                for row in 0..rows {
                    acc += vecs[row * n + ei] * m.at(row, col) as f64;
                }
                *v.at_mut(ri, col) = acc as f32;
            }
        }
    } else {
        // Eigenvectors of Mᵀ·M are the right singular vectors; the i-th
        // column of `u` is M·vᵢ (σ folded into u).
        for (ri, &ei) in kept.iter().enumerate() {
            for col in 0..cols {
                *v.at_mut(ri, col) = vecs[col * n + ei] as f32;
            }
            for row in 0..rows {
                let mut acc = 0f64;
                for col in 0..cols {
                    acc += m.at(row, col) as f64 * vecs[col * n + ei];
                }
                *u.at_mut(row, ri) = acc as f32;
            }
        }
    }
    (u, v)
}

/// Number of singular values exceeding `tol` times the largest — the
/// numerical rank at tolerance `tol`. Used to flag pseudo-MoE models: a
/// gate matrix whose effective rank is far below the expert count routes
/// in a low-dimensional subspace, i.e. its experts are not independently
/// addressed (SNIPPETS §3's gate-logit-rank diagnostic).
pub fn effective_rank(m: &Mat, tol: f32) -> usize {
    if m.rows == 0 || m.cols == 0 {
        return 0;
    }
    let vals = gram_eigvals_desc(m);
    let lmax = vals.first().copied().unwrap_or(0.0).max(0.0);
    if lmax <= 1e-24 {
        return 0;
    }
    let cut = (tol as f64) * (tol as f64) * lmax;
    vals.iter().filter(|&&l| l > cut.max(1e-24)).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.rows);
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0f64;
                for t in 0..a.cols {
                    acc += a.at(i, t) as f64 * b.at(t, j) as f64;
                }
                *out.at_mut(i, j) = acc as f32;
            }
        }
        out
    }

    fn max_abs_diff(a: &Mat, b: &Mat) -> f32 {
        a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    /// A rank-2 matrix is recovered exactly (to f32 noise) at rank 2,
    /// in both the rows<=cols and rows>cols Gram branches.
    #[test]
    fn exact_low_rank_recovery_both_branches() {
        let mut rng = Pcg64::seeded(41);
        for (rows, cols) in [(6usize, 10usize), (10, 6)] {
            let a = Mat::randn(rows, 2, 1.0, &mut rng);
            let b = Mat::randn(2, cols, 1.0, &mut rng);
            let m = matmul_naive(&a, &b);
            let (u, v) = svd_truncated(&m, 2);
            assert_eq!(u.rows, rows);
            assert_eq!(u.cols, 2, "{rows}x{cols}: rank-2 input keeps 2 directions");
            assert_eq!(v.cols, cols);
            let back = matmul_naive(&u, &v);
            let scale = m.data.iter().fold(0f32, |s, &x| s.max(x.abs())).max(1e-6);
            assert!(
                max_abs_diff(&m, &back) / scale < 1e-4,
                "{rows}x{cols}: reconstruction error {}",
                max_abs_diff(&m, &back) / scale
            );
        }
    }

    #[test]
    fn truncation_error_shrinks_with_rank() {
        let mut rng = Pcg64::seeded(42);
        let m = Mat::randn(8, 12, 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1usize, 2, 4, 8] {
            let (u, v) = svd_truncated(&m, r);
            let back = matmul_naive(&u, &v);
            let err: f32 = m
                .data
                .iter()
                .zip(&back.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt();
            assert!(err <= last + 1e-4, "rank {r}: error {err} grew past {last}");
            last = err;
        }
        // Full rank reconstructs the matrix (f32 noise floor).
        assert!(last / m.fro_norm().max(1e-6) < 1e-4, "full-rank residual {last}");
    }

    #[test]
    fn zero_matrix_yields_zero_rank_one_factors() {
        let m = Mat::zeros(5, 7);
        let (u, v) = svd_truncated(&m, 3);
        assert_eq!((u.rows, u.cols), (5, 1));
        assert_eq!((v.rows, v.cols), (1, 7));
        assert!(u.data.iter().all(|&x| x == 0.0));
        assert!(v.data.iter().all(|&x| x == 0.0));
        assert_eq!(effective_rank(&m, 1e-3), 0);
    }

    #[test]
    fn effective_rank_matches_construction() {
        let mut rng = Pcg64::seeded(43);
        let a = Mat::randn(9, 3, 1.0, &mut rng);
        let b = Mat::randn(3, 7, 1.0, &mut rng);
        let m = matmul_naive(&a, &b);
        assert_eq!(effective_rank(&m, 1e-3), 3);
        // A random dense matrix is (numerically) full rank.
        let full = Mat::randn(6, 11, 1.0, &mut rng);
        assert_eq!(effective_rank(&full, 1e-3), 6);
    }

    #[test]
    fn svd_is_deterministic() {
        let mut rng = Pcg64::seeded(44);
        let m = Mat::randn(7, 5, 1.0, &mut rng);
        let (u1, v1) = svd_truncated(&m, 3);
        let (u2, v2) = svd_truncated(&m, 3);
        assert_eq!(u1.data, u2.data);
        assert_eq!(v1.data, v2.data);
    }
}
