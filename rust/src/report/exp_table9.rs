//! Table 9 (A.3): the overfitting analysis of frequency-based
//! mixed-precision — PMQ allocations derived from five different
//! calibration sets, each evaluated on four task-family probes, vs QESC.

use super::exp_common::*;
use super::Table;
use crate::calib::qesc::qesc_compress;
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::data::corpus::{CorpusGen, TaskFamily, DATASETS};
use crate::data::tasks::table9_suite;
use crate::model::hooks::Hooks;
use crate::model::{Model, ZooModel};
use crate::util::json::Json;
use crate::Result;

/// Calibration streams: one per family + the balanced wiki mixture (C4's
/// role in the paper).
fn family_calib(family: Option<TaskFamily>, n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    match family {
        Some(f) => {
            let specs: Vec<_> = DATASETS.iter().filter(|d| d.family == f).collect();
            (0..n)
                .map(|i| CorpusGen::new(specs[i % specs.len()], seed + i as u64).sequence(len))
                .collect()
        }
        None => {
            let mut mix = crate::data::corpus::WikiMixture::new(seed);
            mix.sequences(n, len)
        }
    }
}

pub fn table9(scale: f64) -> Result<()> {
    let probes = table9_suite(n_items(scale), 59);
    let ctx = ExperimentContext::new(59, scale);
    let n_calib = ctx.calib.len();
    let len = ctx.calib[0].len();
    let mut table = Table::new(
        "Table 9 — PMQ calibration-set overfitting vs QESC (2.06-bit)",
        &["Model", "Method", "Calib set", "Hellaswag(QA)", "MathQA(Math)", "Lambada-fr(Fr)", "Conala(Code)"],
    );
    let mut json = Json::obj();
    for zoo in [ZooModel::MixtralMini, ZooModel::DeepseekMini] {
        let (fp, _) = load_or_init_model(zoo);
        // Baseline row.
        let base = crate::eval::eval_suite(&fp, &probes, Hooks::none);
        let mut row = vec![zoo.display().into(), "Baseline".into(), "None".into()];
        row.extend(base.tasks.iter().map(|t| format!("{:.2}", t.accuracy)));
        table.row(row);
        // PMQ with five calibration sets.
        let sets: [(&str, Option<TaskFamily>); 5] = [
            ("QA/CR", Some(TaskFamily::QaCr)),
            ("Math", Some(TaskFamily::Math)),
            ("French", Some(TaskFamily::French)),
            ("Code", Some(TaskFamily::Code)),
            ("C4(wiki)", None),
        ];
        for (name, family) in sets {
            let calib = family_calib(family, n_calib, len, 590);
            let cfg = method_config(zoo, QuantMethod::Pmq, BitSetting::B206);
            let (q, _) = qesc_compress(&fp, &calib, &cfg);
            let res = crate::eval::eval_suite(&q, &probes, Hooks::none);
            let mut row = vec!["".into(), "PMQ".into(), name.into()];
            row.extend(res.tasks.iter().map(|t| format!("{:.2}", t.accuracy)));
            table.row(row);
            let mut o = Json::obj();
            for t in &res.tasks {
                o.set(&t.name, Json::Num(t.accuracy as f64));
            }
            json.set(&format!("{}/pmq/{name}", zoo.key()), o);
        }
        // QESC row (wiki calibration, like the main results).
        let (q, _) = compress(&fp, zoo, QuantMethod::Qesc, BitSetting::B206, &ctx);
        let res = crate::eval::eval_suite(&q, &probes, Hooks::none);
        let mut row = vec!["".into(), "QESC".into(), "None(wiki)".into()];
        row.extend(res.tasks.iter().map(|t| format!("{:.2}", t.accuracy)));
        table.row(row);
        let mut o = Json::obj();
        for t in &res.tasks {
            o.set(&t.name, Json::Num(t.accuracy as f64));
        }
        json.set(&format!("{}/qesc", zoo.key()), o);
    }
    table.print();
    println!("(expected shape: each PMQ column peaks on its own calibration family and\n\
              degrades elsewhere — most visibly on Code; QESC is uniformly strong)");
    super::save_result("table9", &json)?;
    Ok(())
}

/// Challenging-task evaluation (Appendix A.2): GSM8K/HumanEval analogues.
pub fn challenging(scale: f64) -> Result<()> {
    let suite = crate::data::tasks::challenging_suite(n_items(scale), 61);
    let ctx = ExperimentContext::new(61, scale);
    let zoo = ZooModel::MixtralMini;
    let (fp, _) = load_or_init_model(zoo);
    let mut table = Table::new(
        "Table 8 (A.2) — challenging tasks (mixtral-mini)",
        &["Bits", "Method", "gsm8k", "humaneval"],
    );
    let base = crate::eval::eval_suite(&fp, &suite, Hooks::none);
    debug_assert!(base.tasks.len() >= 2, "challenging suite has two tasks");
    table.row(vec![
        "16.00".into(),
        "Full Precision".into(),
        format!("{:.2}", base.tasks[0].accuracy),
        format!("{:.2}", base.tasks[1].accuracy),
    ]);
    let mut json = Json::obj();
    for bits in BitSetting::ALL {
        for method in [QuantMethod::Gptq, QuantMethod::Qesc] {
            let (q, _) = compress(&fp, zoo, method, bits, &ctx);
            let res = crate::eval::eval_suite(&q, &suite, Hooks::none);
            table.row(vec![
                bits.label().into(),
                method.label().into(),
                format!("{:.2}", res.tasks[0].accuracy),
                format!("{:.2}", res.tasks[1].accuracy),
            ]);
            let mut o = Json::obj();
            o.set("gsm8k", Json::Num(res.tasks[0].accuracy as f64))
                .set("humaneval", Json::Num(res.tasks[1].accuracy as f64));
            json.set(&format!("{}/{}", bits.label(), method.label()), o);
        }
    }
    table.print();
    println!("(expected shape: challenging tasks degrade more than commonsense ones;\n\
              QESC > GPTQ at every setting)");
    super::save_result("table8", &json)?;
    Ok(())
}

#[allow(dead_code)]
fn _unused(_: &Model) {}
