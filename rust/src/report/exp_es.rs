//! Expert-selection analysis experiments: Fig 2 (task-typed similarity)
//! and the Fig 10/11/13 frequency dumps (A.11/A.12).

use super::Table;
use crate::coordinator::load_or_init_model;
use crate::data::corpus::DATASETS;
use crate::eval::es_analysis::{
    es_frequencies, es_similarity_matrix, intra_inter_summary, sparsity_stats, EsProfile,
};
use crate::model::ZooModel;
use crate::util::json::Json;
use crate::Result;

/// Fig 2: pairwise ES-frequency cosine similarity over the 19 datasets for
/// phi-mini and deepseek-mini (the paper's two panels).
pub fn fig2(scale: f64) -> Result<()> {
    let n_seqs = ((6.0 * scale).round() as usize).max(2);
    let mut json = Json::obj();
    for zoo in [ZooModel::PhiMini, ZooModel::DeepseekMini] {
        let (model, pretrained) = load_or_init_model(zoo);
        if !pretrained {
            println!("warning: {} not pretrained; Fig-2 structure needs `make artifacts`", zoo.key());
        }
        let profiles: Vec<EsProfile> =
            DATASETS.iter().map(|d| es_frequencies(&model, d, n_seqs, 96, 19)).collect();
        let sim = es_similarity_matrix(&profiles);
        let (intra, inter) = intra_inter_summary(&profiles, &sim);
        // Count high-similarity pairs (the paper highlights sim > 0.8).
        let mut intra_high = 0usize;
        let mut intra_total = 0usize;
        let mut inter_high = 0usize;
        let mut inter_total = 0usize;
        for i in 0..profiles.len() {
            for j in 0..i {
                let same = profiles[i].family == profiles[j].family;
                let high = sim[i][j] > 0.8;
                if same {
                    intra_total += 1;
                    intra_high += high as usize;
                } else {
                    inter_total += 1;
                    inter_high += high as usize;
                }
            }
        }
        let mut table = Table::new(
            &format!("Fig 2 — ES similarity, {}", zoo.display()),
            &["metric", "value"],
        );
        table.row(vec!["mean intra-family cosine".into(), format!("{intra:.3}")]);
        table.row(vec!["mean inter-family cosine".into(), format!("{inter:.3}")]);
        table.row(vec![
            "intra pairs with sim > 0.8".into(),
            format!("{intra_high}/{intra_total}"),
        ]);
        table.row(vec![
            "inter pairs with sim > 0.8".into(),
            format!("{inter_high}/{inter_total}"),
        ]);
        table.print();
        let mut o = Json::obj();
        o.set("intra_mean", Json::Num(intra as f64))
            .set("inter_mean", Json::Num(inter as f64))
            .set("intra_high", Json::from(intra_high))
            .set("intra_total", Json::from(intra_total))
            .set("inter_high", Json::from(inter_high))
            .set("inter_total", Json::from(inter_total));
        // Full matrix for plotting.
        o.set(
            "matrix",
            Json::Arr(
                sim.iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect()))
                    .collect(),
            ),
        );
        o.set(
            "datasets",
            Json::Arr(profiles.iter().map(|p| Json::from(p.dataset.clone())).collect()),
        );
        json.set(zoo.key(), o);
    }
    println!("(expected shape: intra-family similarity high (>0.8 pairs dominate),\n\
              inter-family low — the paper's central §3.3 observation)");
    super::save_result("fig2", &json)?;
    Ok(())
}

/// Fig 10/11/13 (A.11/A.12): per-layer ES frequency dumps + sparsity
/// summary, including mixtral-mini's weaker sparsity.
pub fn fig10(scale: f64) -> Result<()> {
    let n_seqs = ((6.0 * scale).round() as usize).max(2);
    let mut json = Json::obj();
    let mut table = Table::new(
        "Fig 10/11/13 — ES sparsity by model (balanced freq = 1/N)",
        &["Model", "dataset", "max freq", "min freq", "max/balanced"],
    );
    for zoo in [ZooModel::PhiMini, ZooModel::DeepseekMini, ZooModel::MixtralMini] {
        let (model, _) = load_or_init_model(zoo);
        let n = model.cfg().n_experts as f32;
        for ds in ["openbookqa", "humaneval"] {
            debug_assert!(crate::data::corpus::dataset(ds).is_some(), "unknown dataset {ds}");
            let Some(spec) = crate::data::corpus::dataset(ds) else { continue };
            let prof = es_frequencies(&model, spec, n_seqs, 96, 23);
            let stats = sparsity_stats(&prof);
            let mx = stats.iter().map(|s| s.0).fold(0.0f32, f32::max);
            let mn = stats.iter().map(|s| s.1).fold(1.0f32, f32::min);
            table.row(vec![
                zoo.display().into(),
                ds.into(),
                format!("{:.3}", mx),
                format!("{:.4}", mn),
                format!("{:.1}x", mx * n),
            ]);
            let mut o = Json::obj();
            o.set("max", Json::Num(mx as f64))
                .set("min", Json::Num(mn as f64))
                .set("ratio_to_balanced", Json::Num((mx * n) as f64));
            o.set(
                "per_layer",
                Json::Arr(
                    prof.per_layer
                        .iter()
                        .map(|l| Json::Arr(l.iter().map(|&v| Json::Num(v as f64)).collect()))
                        .collect(),
                ),
            );
            json.set(&format!("{}/{ds}", zoo.key()), o);
        }
    }
    table.print();
    println!("(expected shape: phi/deepseek strongly sparse — few experts far above\n\
              balanced; mixtral comparatively balanced, explaining its PESF(0.7)\n\
              sensitivity — Appendix A.12)");
    super::save_result("fig10", &json)?;
    Ok(())
}
