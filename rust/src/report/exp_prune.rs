//! Pruning experiments: Fig 7 (threshold sweep) and Table 3 (EES / ODP /
//! PESF comparison with measured speedups).

use super::exp_common::*;
use super::Table;
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::data::tasks::zero_shot_suite;
use crate::model::hooks::Hooks;
use crate::model::ZooModel;
use crate::prune::ees::{calibrate_ees_threshold, EesPruner};
use crate::prune::odp::OdpPruner;
use crate::prune::pesf::PesfConfig;
use crate::serve::PrunePolicy;
use crate::util::json::Json;
use crate::Result;

/// Fig 7: alpha sweep on deepseek-mini — accuracy, prune rate, latency.
pub fn fig7(scale: f64) -> Result<()> {
    let zoo = ZooModel::DeepseekMini;
    let (model, _) = load_or_init_model(zoo);
    let ctx = ExperimentContext::new(47, scale);
    let suite = zero_shot_suite(n_items(scale), 47);
    let (n_reqs, len) = serve_workload(scale);
    let base_latency =
        prefill_latency(crate::model::Model::new(model.weights.clone()), PrunePolicy::None, n_reqs, len);
    let mut table = Table::new(
        "Fig 7 — pruning threshold sweep (deepseek-mini)",
        &["alpha", "0-shot avg", "PPL", "prune rate", "relative latency"],
    );
    let mut json = Json::obj();
    for ai in 0..=9 {
        let alpha = ai as f32 * 0.1;
        let meas = if alpha == 0.0 {
            measure(&model, &ctx, &suite)
        } else {
            measure_pruned(&model, &ctx, &suite, alpha)
        };
        // Prune rate from one serving pass; latency via the median-of-trials
        // protocol (prefill_latency) to resist single-core noise.
        let policy = if alpha == 0.0 {
            PrunePolicy::None
        } else {
            PrunePolicy::Pesf(PesfConfig { alpha, ..Default::default() })
        };
        let engine = crate::serve::Engine::new(
            crate::model::Model::new(model.weights.clone()),
            crate::serve::EngineConfig { workers: 1, prune: policy, ..Default::default() },
        );
        let mut mix = crate::data::corpus::WikiMixture::new(98);
        let reqs: Vec<crate::serve::Request> =
            (0..n_reqs as u64).map(|i| crate::serve::Request::new(i, mix.sequence(len))).collect();
        let (_, metrics) = engine.serve(reqs);
        let lat = prefill_latency(
            crate::model::Model::new(model.weights.clone()),
            policy,
            n_reqs,
            len,
        );
        let rel_latency = lat / base_latency;
        table.row(vec![
            format!("{alpha:.1}"),
            format!("{:.2}", meas.suite.mean_accuracy()),
            format!("{:.2}", meas.ppl),
            format!("{:.1}%", metrics.mean_prune_rate * 100.0),
            format!("{:.2}", rel_latency),
        ]);
        let mut o = Json::obj();
        o.set("acc", Json::Num(meas.suite.mean_accuracy() as f64))
            .set("ppl", Json::Num(meas.ppl))
            .set("prune_rate", Json::Num(metrics.mean_prune_rate as f64))
            .set("rel_latency", Json::Num(rel_latency));
        json.set(&format!("alpha{ai}"), o);
    }
    table.print();
    println!("(expected shape: acc ~flat to α≈0.3, slow decline to α≈0.7, drop after;\n\
              prune rate and speedup grow monotonically — the two sweet spots)");
    super::save_result("fig7", &json)?;
    Ok(())
}

/// Table 3: EES / ODP / PESF(0.3) / PESF(0.7) across the zoo.
pub fn table3(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 43);
    let ctx = ExperimentContext::new(43, scale);
    let (n_reqs, len) = serve_workload(scale);
    let mut table = Table::new(
        "Table 3 — dynamic pruning comparison (0-shot avg / speedup)",
        &["Method", "Mixtral", "", "Phi3.5", "", "Deepseek", "", "Qwen1.5", ""],
    );
    table.row(vec![
        "".into(), "acc".into(), "spd".into(), "acc".into(), "spd".into(),
        "acc".into(), "spd".into(), "acc".into(), "spd".into(),
    ]);
    let mut json = Json::obj();
    let mut rows: Vec<Vec<String>> = vec![
        vec!["Baseline".into()],
        vec!["EES".into()],
        vec!["ODP".into()],
        vec!["PESF (a=0.3)".into()],
        vec!["PESF (a=0.7)".into()],
    ];
    for zoo in ZooModel::ALL {
        let (model, _) = load_or_init_model(zoo);
        let ees = EesPruner { threshold: calibrate_ees_threshold(&model, &ctx.calib) };
        let odp = OdpPruner::calibrate(&model, &ctx.calib, 0.8);
        let policies: Vec<(usize, PrunePolicy)> = vec![
            (0, PrunePolicy::None),
            (1, PrunePolicy::Ees(ees)),
            (2, PrunePolicy::Odp(odp)),
            (3, PrunePolicy::Pesf(PesfConfig { alpha: 0.3, ..Default::default() })),
            (4, PrunePolicy::Pesf(PesfConfig { alpha: 0.7, ..Default::default() })),
        ];
        let mut base_lat = 1.0f64;
        for (ri, policy) in policies {
            // Accuracy through eval hooks matching the policy.
            let acc = match policy {
                PrunePolicy::None => measure(&model, &ctx, &suite).suite.mean_accuracy(),
                PrunePolicy::Pesf(pc) => {
                    measure_pruned(&model, &ctx, &suite, pc.alpha).suite.mean_accuracy()
                }
                PrunePolicy::Ees(p) => {
                    crate::eval::eval_suite(&model, &suite, || Hooks {
                        selection_filter: Some(p.filter()),
                        ..Default::default()
                    })
                    .mean_accuracy()
                }
                PrunePolicy::Odp(p) => {
                    crate::eval::eval_suite(&model, &suite, || Hooks {
                        selection_filter: Some(p.filter()),
                        ..Default::default()
                    })
                    .mean_accuracy()
                }
            };
            let lat = prefill_latency(
                crate::model::Model::new(model.weights.clone()),
                policy,
                n_reqs,
                len,
            );
            if ri == 0 {
                base_lat = lat;
            }
            let speedup = base_lat / lat;
            debug_assert!(ri < rows.len(), "row {ri} out of {}", rows.len());
            rows[ri].push(format!("{acc:.2}"));
            rows[ri].push(format!("{speedup:.2}x"));
            let mut o = Json::obj();
            o.set("acc", Json::Num(acc as f64)).set("speedup", Json::Num(speedup));
            json.set(&format!("{}/{}", rows[ri][0], zoo.key()), o);
        }
    }
    for r in rows {
        table.row(r);
    }
    table.print();
    println!("(expected shape: PESF(0.3) ≥ EES/ODP on both acc and speedup;\n\
              PESF(0.7) trades acc for bigger speedups — worst on mixtral (weak\n\
              routing sparsity, Appendix A.12))");
    super::save_result("table3", &json)?;
    Ok(())
}
