//! Experiment drivers: one function per paper table/figure (DESIGN.md §4).
//! Each prints the paper-shaped rows and saves `results/<id>.json`.
//!
//! Run via `eac-moe experiment <id> [--scale S]` or `make experiments`.

use crate::Result;

/// Optional per-experiment inputs threaded from the CLI.
#[derive(Clone, Debug, Default)]
pub struct RunOpts {
    /// `experiment merge --from-analysis <json>`: derive the merge
    /// threshold sweep from a measured `analyze --expert-sim` result
    /// instead of the fixed default list.
    pub from_analysis: Option<std::path::PathBuf>,
}

/// Run one experiment id (or "all"). `scale` shrinks data volume (items,
/// sequences, request counts) for quick runs.
pub fn run(id: &str, scale: f64) -> Result<()> {
    run_opts(id, scale, &RunOpts::default())
}

/// [`run`] with explicit [`RunOpts`].
pub fn run_opts(id: &str, scale: f64, opts: &RunOpts) -> Result<()> {
    let t0 = std::time::Instant::now();
    match id {
        "fig2" => super::exp_es::fig2(scale)?,
        "fig10" | "fig11" | "fig13" => super::exp_es::fig10(scale)?,
        "table1" => super::exp_quant::table1(scale)?,
        "table2" => super::exp_quant::table2(scale)?,
        "fig4" => super::exp_quant::fig4(scale)?,
        "fig6" => super::exp_quant::fig6(scale)?,
        "table6" => super::exp_quant::table6(scale)?,
        "fig8" => super::exp_quant::fig8(scale)?,
        "fig9" => super::exp_quant::fig9(scale)?,
        "fig7" => super::exp_prune::fig7(scale)?,
        "table3" => super::exp_prune::table3(scale)?,
        "table4" | "fig1" => super::exp_e2e::table4(scale)?,
        "table5" => super::exp_e2e::table5(scale)?,
        "table7" => super::exp_e2e::table7(scale)?,
        "table8" | "challenging" => super::exp_table9::challenging(scale)?,
        "table9" => super::exp_table9::table9(scale)?,
        "merge" => match &opts.from_analysis {
            Some(path) => super::exp_merge::merge_table_from_analysis(scale, path)?,
            None => super::exp_merge::merge_table(scale)?,
        },
        "all" => {
            for id in [
                "fig2", "fig10", "table1", "fig4", "fig6", "table2", "fig7", "table3",
                "table4", "table5", "table6", "table7", "table8", "table9", "fig8", "fig9",
                "merge",
            ] {
                println!("\n################ experiment {id} ################");
                run_opts(id, scale, opts)?;
            }
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (see `eac-moe --help` for the list)"
        ),
    }
    if id != "all" {
        println!("[experiment {id} done in {:.1}s]", t0.elapsed().as_secs_f64());
    }
    Ok(())
}
