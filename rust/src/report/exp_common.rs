//! Shared infrastructure for the experiment drivers: method definitions
//! (GPTQ / BSP / PMQ / QESC at the paper's three bit settings), compression
//! dispatch, and the standard measurement bundle (PPL, 0-shot, latency).

use crate::calib::loss::LossType;
use crate::calib::qesc::{qesc_compress, CompressReport, QescConfig};
use crate::coordinator::ExperimentContext;
use crate::data::tasks::ZeroShotTask;
use crate::eval::zeroshot::SuiteResult;
use crate::model::hooks::Hooks;
use crate::model::{Model, ZooModel};
use crate::quant::alloc::Allocator;
use crate::serve::{Engine, EngineConfig, PrunePolicy, Request};

/// The paper's three average-bit settings (Appendix A.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitSetting {
    B206,
    B254,
    B303,
}

impl BitSetting {
    pub const ALL: [BitSetting; 3] = [BitSetting::B206, BitSetting::B254, BitSetting::B303];

    pub fn label(&self) -> &'static str {
        match self {
            BitSetting::B206 => "2.06",
            BitSetting::B254 => "2.54",
            BitSetting::B303 => "3.03",
        }
    }

    /// Uniform expert bits for methods without their own allocation.
    pub fn uniform_alloc(&self) -> Allocator {
        match self {
            BitSetting::B206 => Allocator::Uniform { bits: 2 },
            BitSetting::B254 => Allocator::HalfSplit { hi: 3, lo: 2 },
            BitSetting::B303 => Allocator::Uniform { bits: 3 },
        }
    }

    pub fn avg_expert_bits(&self) -> f64 {
        match self {
            BitSetting::B206 => 2.0,
            BitSetting::B254 => 2.5,
            BitSetting::B303 => 3.0,
        }
    }
}

/// Quantization methods compared in Table 2 / Appendix A.6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    Gptq,
    Bsp,
    Pmq,
    Qesc,
    /// QESC ablation: full-MSE calibration loss (Table 6).
    QescMse,
}

impl QuantMethod {
    pub fn label(&self) -> &'static str {
        match self {
            QuantMethod::Gptq => "GPTQ",
            QuantMethod::Bsp => "BSP",
            QuantMethod::Pmq => "PMQ",
            QuantMethod::Qesc => "QESC",
            QuantMethod::QescMse => "QESC(MSE)",
        }
    }
}

/// BSP's published allocation rules, transcribed from Appendix A.6.
pub fn bsp_allocator(zoo: ZooModel, bits: BitSetting) -> Allocator {
    let cfg = zoo.config();
    let has_shared = cfg.n_shared > 0;
    match (has_shared, bits) {
        // Mixtral/Phi: top-half experts hi, rest 2-bit.
        (false, BitSetting::B303) => {
            Allocator::Bsp { hi: 4, lo: 2, hi_count: cfg.n_experts / 2, shared: 8 }
        }
        (false, _) => Allocator::Bsp { hi: 3, lo: 2, hi_count: cfg.n_experts / 2, shared: 8 },
        // DeepSeek/Qwen: shared experts 8-bit; 3.03: 4-bit top third;
        // 2.54: 4-bit top tenth.
        (true, BitSetting::B303) => {
            Allocator::Bsp { hi: 4, lo: 2, hi_count: cfg.n_experts / 3, shared: 8 }
        }
        (true, _) => Allocator::Bsp { hi: 4, lo: 2, hi_count: cfg.n_experts / 10, shared: 8 },
    }
}

/// Build the QESC pipeline config for (method, bit setting, model).
pub fn method_config(zoo: ZooModel, method: QuantMethod, bits: BitSetting) -> QescConfig {
    let mcfg = zoo.config();
    let k = QescConfig::default_k(&mcfg);
    let base = QescConfig::qesc(3, k); // placeholder alloc replaced below
    match method {
        QuantMethod::Gptq => QescConfig {
            expert_alloc: bits.uniform_alloc(),
            calib_router: false,
            ..base
        },
        QuantMethod::Bsp => QescConfig {
            expert_alloc: bsp_allocator(zoo, bits),
            calib_router: false,
            ..base
        },
        QuantMethod::Pmq => QescConfig {
            expert_alloc: Allocator::Pmq { avg_bits: bits.avg_expert_bits(), shared: 3 },
            calib_router: false,
            ..base
        },
        QuantMethod::Qesc => QescConfig { expert_alloc: bits.uniform_alloc(), ..base },
        QuantMethod::QescMse => QescConfig {
            expert_alloc: bits.uniform_alloc(),
            loss: LossType::Mse,
            ..base
        },
    }
}

/// Compress a model with a method at a bit setting.
pub fn compress(
    model: &Model,
    zoo: ZooModel,
    method: QuantMethod,
    bits: BitSetting,
    ctx: &ExperimentContext,
) -> (Model, CompressReport) {
    let cfg = method_config(zoo, method, bits);
    qesc_compress(model, &ctx.calib, &cfg)
}

/// Standard measurement bundle.
pub struct Measured {
    pub ppl: f64,
    pub suite: SuiteResult,
}

pub fn measure(model: &Model, ctx: &ExperimentContext, suite: &[ZeroShotTask]) -> Measured {
    Measured {
        ppl: crate::eval::perplexity(model, &ctx.ppl_eval),
        suite: crate::eval::eval_suite(model, suite, Hooks::none),
    }
}

pub fn measure_pruned(
    model: &Model,
    ctx: &ExperimentContext,
    suite: &[ZeroShotTask],
    alpha: f32,
) -> Measured {
    let n_layers = model.cfg().n_layers;
    let hooks = move || Hooks { pesf_alpha: Some(alpha), ..Default::default() };
    let ppl = crate::eval::ppl::perplexity_with_hooks(model, &ctx.ppl_eval, hooks);
    let suite = crate::eval::eval_suite(model, suite, hooks);
    let _ = n_layers;
    Measured { ppl, suite }
}

/// Prefill latency of a batch through the serving engine (the paper's
/// Table-4 protocol: context latency for a batch of sequences). Runs a
/// warmup pass then several trials and returns the median per-request
/// prefill seconds (single-core wall-clock is noisy; median resists it).
pub fn prefill_latency(model: Model, prune: PrunePolicy, n_reqs: usize, len: usize) -> f64 {
    let engine = Engine::new(
        model,
        EngineConfig { workers: 1, prune, ..Default::default() },
    );
    let mut mix = crate::data::corpus::WikiMixture::new(97);
    let make_reqs = |mix: &mut crate::data::corpus::WikiMixture| -> Vec<Request> {
        (0..n_reqs as u64).map(|i| Request::new(i, mix.sequence(len))).collect()
    };
    engine.serve(make_reqs(&mut mix)); // warmup
    let mut medians = Vec::new();
    for _ in 0..3 {
        let (_, metrics) = engine.serve(make_reqs(&mut mix));
        medians.push(metrics.prefill.percentile_ms(0.5));
    }
    medians.sort_by(|a, b| a.total_cmp(b));
    medians[medians.len() / 2] / 1e3
}

/// Number of zero-shot items per task at a given scale.
pub fn n_items(scale: f64) -> usize {
    ((16.0 * scale).round() as usize).clamp(4, 64)
}

/// Serving workload size at a given scale.
pub fn serve_workload(scale: f64) -> (usize, usize) {
    let n = ((8.0 * scale).round() as usize).clamp(2, 16);
    let len = ((256.0 * scale.sqrt()).round() as usize).clamp(64, 512);
    (n, len)
}
