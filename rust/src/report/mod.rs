//! Paper-shaped reporting: fixed-width table printers, JSON result files
//! under `results/`, and the experiment drivers for every paper
//! table/figure.

pub mod exp_common;
pub mod exp_e2e;
pub mod exp_es;
pub mod exp_merge;
pub mod exp_prune;
pub mod exp_quant;
pub mod exp_table9;
pub mod experiments;

use crate::util::json::Json;
use std::path::Path;

/// A printable table with a title, headers, and string rows.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], out: &mut String| {
            for i in 0..ncol {
                out.push_str(&format!("{:<w$}  ", cells[i], w = widths[i]));
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }

    /// Convert to a JSON object for results/ files.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("title", Json::from(self.title.clone()));
        obj.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::from(h.clone())).collect()),
        );
        obj.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::from(c.clone())).collect()))
                    .collect(),
            ),
        );
        obj
    }
}

/// Write a JSON result document to `results/<name>.json` (the path rides
/// any error's context chain).
pub fn save_result(name: &str, json: &Json) -> crate::Result<()> {
    crate::util::json::save(&Path::new("results").join(format!("{name}.json")), json)
}

/// Format helpers used across experiment tables.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["model", "ppl"]);
        t.row(vec!["mixtral-mini".into(), "3.84".into()]);
        t.row(vec!["phi".into(), "4.1".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("mixtral-mini"));
        // Columns aligned: "ppl" header starts at same col in all lines.
        let lines: Vec<&str> = s.lines().collect();
        let hdr_pos = lines[1].find("ppl").unwrap();
        let row_pos = lines[3].find("3.84").unwrap();
        assert_eq!(hdr_pos, row_pos);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("T"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
