//! Quantization experiments: Table 1 (expert-shift 2×2), Table 2 (main
//! quantization comparison), Fig 4 (shift-rank analysis), Fig 6
//! (calibration reduces change rate), Fig 8 (K sweep), Fig 9 (MHSA bits),
//! Table 6 (loss ablation).

use super::exp_common::*;
use super::Table;
use crate::calib::loss::LossType;
use crate::calib::qesc::{qesc_compress, QescConfig};
use crate::calib::shift::{change_rates, mean_change_rates, shift_rank_analysis};
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::data::tasks::zero_shot_suite;
use crate::model::hooks::Hooks;
use crate::model::{Model, WeightMat, ZooModel};
use crate::quant::gptq::{GptqConfig, Hessian};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::Result;

/// Record selections + router logits of a model over sequences.
fn record_selections(
    model: &Model,
    seqs: &[Vec<u32>],
) -> (crate::model::hooks::SelectionRecord, Vec<Mat>) {
    let n_layers = model.cfg().n_layers;
    let mut all = crate::model::hooks::SelectionRecord::with_layers(n_layers);
    let mut logits: Vec<Mat> = vec![Mat::zeros(0, 0); n_layers];
    for seq in seqs {
        let h = Hooks {
            record_selections: Some(std::cell::RefCell::new(
                crate::model::hooks::SelectionRecord::with_layers(n_layers),
            )),
            capture_router_logits: Some(std::cell::RefCell::new(vec![None; n_layers])),
            ..Default::default()
        };
        model.forward_with_hooks(seq, &h);
        // Both cells were installed on the hooks literal just above.
        debug_assert!(
            h.record_selections.is_some() && h.capture_router_logits.is_some(),
            "hooks installed above"
        );
        let (Some(rec_cell), Some(logit_cell)) = (h.record_selections, h.capture_router_logits)
        else {
            continue;
        };
        let rec = rec_cell.into_inner();
        for li in 0..n_layers {
            all.layers[li].extend(rec.layers[li].iter().cloned());
        }
        for (li, m) in logit_cell.into_inner().into_iter().enumerate() {
            debug_assert!(m.is_some(), "layer {li} router logits captured");
            let Some(m) = m else { continue };
            if logits[li].rows == 0 {
                logits[li] = m;
            } else {
                logits[li].data.extend_from_slice(&m.data);
                logits[li].rows += m.rows;
            }
        }
    }
    (all, logits)
}

/// PPL with selections forced from a recorded stream, sequence by sequence.
fn ppl_forced(model: &Model, seqs: &[Vec<u32>], donor: &Model) -> f64 {
    let n_layers = model.cfg().n_layers;
    let mut total_nll = 0f64;
    let mut count = 0usize;
    let mut scratch = vec![0f32; model.cfg().vocab];
    for seq in seqs {
        let rec_hooks = Hooks::recording(n_layers);
        donor.forward_with_hooks(seq, &rec_hooks);
        let rec = rec_hooks.take_selections().unwrap_or_default();
        debug_assert!(!rec.layers.is_empty(), "recording hooks captured selections");
        let hooks = Hooks::forcing(rec);
        let logits = model.forward_with_hooks(seq, &hooks);
        for t in 0..seq.len() - 1 {
            crate::tensor::ops::log_softmax_into(logits.row(t), &mut scratch);
            total_nll -= scratch[seq[t + 1] as usize] as f64;
            count += 1;
        }
    }
    (total_nll / count.max(1) as f64).exp()
}

/// Table 1: the 2×2 {quantized} × {expert-shift} PPL decomposition.
pub fn table1(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(31, scale);
    let mut table = Table::new(
        "Table 1 — impact of quantization vs expert-shift on PPL",
        &["Model", "Quantized", "Expert-Shift", "PPL"],
    );
    let mut json = Json::obj();
    for zoo in [ZooModel::MixtralMini, ZooModel::DeepseekMini] {
        let (fp, _) = load_or_init_model(zoo);
        // 3-bit GPTQ (no router calibration): pure quantization error.
        let (q, _) = compress(&fp, zoo, QuantMethod::Gptq, BitSetting::B303, &ctx);
        // Rows: (quantized?, shift?) — shift is controlled by whose
        // selections drive the MoE layers.
        let ppl_fp = crate::eval::perplexity(&fp, &ctx.ppl_eval);
        let ppl_fp_shift = ppl_forced(&fp, &ctx.ppl_eval, &q); // fp weights, q selections
        let ppl_q_noshift = ppl_forced(&q, &ctx.ppl_eval, &fp); // q weights, fp selections
        let ppl_q = crate::eval::perplexity(&q, &ctx.ppl_eval);
        for (quant, shift, ppl) in [
            ("x", "x", ppl_fp),
            ("x", "yes", ppl_fp_shift),
            ("yes", "x", ppl_q_noshift),
            ("yes", "yes", ppl_q),
        ] {
            table.row(vec![
                zoo.display().into(),
                quant.into(),
                shift.into(),
                format!("{ppl:.3}"),
            ]);
        }
        let mut o = Json::obj();
        o.set("fp", Json::Num(ppl_fp))
            .set("fp_shift", Json::Num(ppl_fp_shift))
            .set("q_noshift", Json::Num(ppl_q_noshift))
            .set("q_shift", Json::Num(ppl_q));
        json.set(zoo.key(), o);
    }
    table.print();
    println!(
        "(expected shape: fp < fp+shift ≈ q+noshift < q+shift — both error sources\n\
         contribute, and removing shift from the quantized model recovers PPL)"
    );
    super::save_result("table1", &json)?;
    Ok(())
}

/// Table 2: GPTQ / PMQ / BSP / QESC × bit settings × models (PPL + 0-shot).
pub fn table2(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 41);
    let ctx = ExperimentContext::new(42, scale);
    let mut table = Table::new(
        "Table 2 — quantization comparison (PPL / 0-shot avg)",
        &["Bits", "Method", "Mixtral", "", "Phi3.5", "", "Deepseek", "", "Qwen1.5", ""],
    );
    table.row(vec![
        "".into(), "".into(), "PPL".into(), "acc".into(), "PPL".into(), "acc".into(),
        "PPL".into(), "acc".into(), "PPL".into(), "acc".into(),
    ]);
    let mut json = Json::obj();
    // Baseline row.
    let mut base_row = vec!["16.00".to_string(), "Baseline".to_string()];
    let mut models = Vec::new();
    for zoo in ZooModel::ALL {
        let (m, _) = load_or_init_model(zoo);
        let meas = measure(&m, &ctx, &suite);
        base_row.push(format!("{:.3}", meas.ppl));
        base_row.push(format!("{:.2}", meas.suite.mean_accuracy()));
        let mut o = Json::obj();
        o.set("ppl", Json::Num(meas.ppl))
            .set("acc", Json::Num(meas.suite.mean_accuracy() as f64));
        json.set(&format!("baseline/{}", zoo.key()), o);
        models.push((zoo, m));
    }
    table.row(base_row);
    // Paper's method availability per setting (PMQ 1.57–2.54, BSP 2.54–3.03).
    let methods_for = |bits: BitSetting| -> Vec<QuantMethod> {
        match bits {
            BitSetting::B206 => vec![QuantMethod::Gptq, QuantMethod::Pmq, QuantMethod::Qesc],
            BitSetting::B254 => {
                vec![QuantMethod::Gptq, QuantMethod::Bsp, QuantMethod::Pmq, QuantMethod::Qesc]
            }
            BitSetting::B303 => vec![QuantMethod::Gptq, QuantMethod::Bsp, QuantMethod::Qesc],
        }
    };
    for bits in BitSetting::ALL {
        for method in methods_for(bits) {
            let mut row = vec![bits.label().to_string(), method.label().to_string()];
            for (zoo, m) in &models {
                let (q, _) = compress(m, *zoo, method, bits, &ctx);
                let meas = measure(&q, &ctx, &suite);
                row.push(format!("{:.3}", meas.ppl));
                row.push(format!("{:.2}", meas.suite.mean_accuracy()));
                let mut o = Json::obj();
                o.set("ppl", Json::Num(meas.ppl))
                    .set("acc", Json::Num(meas.suite.mean_accuracy() as f64));
                json.set(&format!("{}/{}/{}", bits.label(), method.label(), zoo.key()), o);
            }
            table.row(row);
        }
    }
    table.print();
    println!("(expected shape: QESC best PPL+acc per (bits, model); gap widens at low bits)");
    super::save_result("table2", &json)?;
    Ok(())
}

/// Fig 4: shifted-expert rank distribution vs loss mass (deepseek, 2-bit).
pub fn fig4(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(44, scale);
    let zoo = ZooModel::DeepseekMini;
    let (fp, _) = load_or_init_model(zoo);
    let (q, _) = compress(&fp, zoo, QuantMethod::Gptq, BitSetting::B206, &ctx);
    let (_, fp_logits) = record_selections(&fp, &ctx.ppl_eval);
    let (_, q_logits) = record_selections(&q, &ctx.ppl_eval);
    // Concatenate layers for the aggregate curve.
    let k = fp.cfg().top_k;
    let n = fp.cfg().n_experts;
    let mut fp_all = Mat::zeros(0, n);
    let mut q_all = Mat::zeros(0, n);
    debug_assert!(
        fp_logits.len() == fp.cfg().n_layers && q_logits.len() == fp_logits.len(),
        "one captured logit matrix per layer"
    );
    for li in 0..fp.cfg().n_layers {
        fp_all.data.extend_from_slice(&fp_logits[li].data);
        fp_all.rows += fp_logits[li].rows;
        q_all.data.extend_from_slice(&q_logits[li].data);
        q_all.rows += q_logits[li].rows;
    }
    let pts = shift_rank_analysis(&fp_all, &q_all, k);
    let mut table = Table::new(
        "Fig 4 — shifted experts vs loss mass by probability rank (deepseek-mini, 2-bit)",
        &["top-R", "shifted experts within", "loss mass within"],
    );
    let mut json = Json::obj();
    for &r in &[k, 8, 12, 16, 24, 32, 48, n] {
        let p = &pts[r - 1];
        table.row(vec![
            format!("{r}"),
            format!("{:.1}%", p.shifted_within * 100.0),
            format!("{:.1}%", p.loss_within * 100.0),
        ]);
        let mut o = Json::obj();
        o.set("shifted_within", Json::Num(p.shifted_within as f64))
            .set("loss_within", Json::Num(p.loss_within as f64));
        json.set(&format!("top{r}"), o);
    }
    table.print();
    println!("(expected shape: shifted-expert mass concentrates at small R while the\n\
              MSE loss mass does not — the TopK-MSE motivation)");
    super::save_result("fig4", &json)?;
    Ok(())
}

/// Fig 6: per-layer change-rate reduction from router calibration.
pub fn fig6(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(46, scale);
    let zoo = ZooModel::DeepseekMini;
    let (fp, _) = load_or_init_model(zoo);
    let (gptq, _) = compress(&fp, zoo, QuantMethod::Gptq, BitSetting::B206, &ctx);
    let (qesc, _) = compress(&fp, zoo, QuantMethod::Qesc, BitSetting::B206, &ctx);
    let (rec_fp, _) = record_selections(&fp, &ctx.ppl_eval);
    let (rec_g, _) = record_selections(&gptq, &ctx.ppl_eval);
    let (rec_q, _) = record_selections(&qesc, &ctx.ppl_eval);
    let mut table = Table::new(
        "Fig 6 — expert-selection change rate before/after calibration (deepseek-mini, 2.06-bit)",
        &["layer", "all-changed (GPTQ→QESC)", "any-changed (GPTQ→QESC)", "half-changed (GPTQ→QESC)"],
    );
    let mut json = Json::obj();
    for li in 0..fp.cfg().n_layers {
        let cg = change_rates(&rec_fp, &rec_g, li);
        let cq = change_rates(&rec_fp, &rec_q, li);
        table.row(vec![
            format!("{li}"),
            format!("{:.1}% → {:.1}%", cg.all_changed * 100.0, cq.all_changed * 100.0),
            format!("{:.1}% → {:.1}%", cg.any_changed * 100.0, cq.any_changed * 100.0),
            format!("{:.1}% → {:.1}%", cg.half_changed * 100.0, cq.half_changed * 100.0),
        ]);
        let mut o = Json::obj();
        o.set("gptq_any", Json::Num(cg.any_changed as f64))
            .set("qesc_any", Json::Num(cq.any_changed as f64))
            .set("gptq_all", Json::Num(cg.all_changed as f64))
            .set("qesc_all", Json::Num(cq.all_changed as f64));
        json.set(&format!("layer{li}"), o);
    }
    let mg = mean_change_rates(&rec_fp, &rec_g);
    let mq = mean_change_rates(&rec_fp, &rec_q);
    table.row(vec![
        "MEAN".into(),
        format!("{:.1}% → {:.1}%", mg.all_changed * 100.0, mq.all_changed * 100.0),
        format!("{:.1}% → {:.1}%", mg.any_changed * 100.0, mq.any_changed * 100.0),
        format!("{:.1}% → {:.1}%", mg.half_changed * 100.0, mq.half_changed * 100.0),
    ]);
    table.print();
    println!("(expected shape: QESC reduces all three change rates at every layer)");
    super::save_result("fig6", &json)?;
    Ok(())
}

/// Table 6: MSE vs TopK-MSE ablation on the many-expert models (2.06-bit).
pub fn table6(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 66);
    let ctx = ExperimentContext::new(66, scale);
    let mut table = Table::new(
        "Table 6 — calibration loss ablation (2.06-bit)",
        &["Model", "Loss", "PPL", "0-shot avg"],
    );
    let mut json = Json::obj();
    for zoo in [ZooModel::PhiMini, ZooModel::DeepseekMini, ZooModel::QwenMini] {
        let (fp, _) = load_or_init_model(zoo);
        for method in [QuantMethod::QescMse, QuantMethod::Qesc] {
            let (q, _) = compress(&fp, zoo, method, BitSetting::B206, &ctx);
            let meas = measure(&q, &ctx, &suite);
            let loss_name = if method == QuantMethod::Qesc { "TopK-MSE" } else { "MSE" };
            table.row(vec![
                zoo.display().into(),
                loss_name.into(),
                format!("{:.3}", meas.ppl),
                format!("{:.2}", meas.suite.mean_accuracy()),
            ]);
            let mut o = Json::obj();
            o.set("ppl", Json::Num(meas.ppl))
                .set("acc", Json::Num(meas.suite.mean_accuracy() as f64));
            json.set(&format!("{}/{loss_name}", zoo.key()), o);
        }
    }
    table.print();
    println!("(expected shape: TopK-MSE ≥ MSE on both metrics for many-expert models)");
    super::save_result("table6", &json)?;
    Ok(())
}

/// Fig 8 (A.4): K-value sweep for TopK-MSE.
pub fn fig8(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 68);
    let ctx = ExperimentContext::new(68, scale);
    let mut table = Table::new(
        "Fig 8 — TopK-MSE K sweep, 0-shot avg at 2.06-bit",
        &["Model", "K", "acc"],
    );
    let mut json = Json::obj();
    let sweeps: [(ZooModel, &[usize]); 3] = [
        (ZooModel::PhiMini, &[2, 4, 8, 12, 16]),
        (ZooModel::DeepseekMini, &[6, 12, 20, 32, 64]),
        (ZooModel::QwenMini, &[4, 12, 20, 32, 60]),
    ];
    for (zoo, ks) in sweeps {
        let (fp, _) = load_or_init_model(zoo);
        for &k in ks {
            let cfg = QescConfig {
                expert_alloc: BitSetting::B206.uniform_alloc(),
                loss: LossType::TopkMse(k),
                ..QescConfig::qesc(2, k)
            };
            let (q, _) = qesc_compress(&fp, &ctx.calib, &cfg);
            let meas = measure(&q, &ctx, &suite);
            table.row(vec![zoo.display().into(), format!("{k}"), format!("{:.2}", meas.suite.mean_accuracy())]);
            json.set(&format!("{}/k{}", zoo.key(), k), Json::Num(meas.suite.mean_accuracy() as f64));
        }
    }
    table.print();
    println!("(expected shape: sweet spot at intermediate K; K=n_experts ≈ MSE is worse)");
    super::save_result("fig8", &json)?;
    Ok(())
}

/// Fig 9 (A.5): MHSA bit-width sweep vs change rate + PPL (mixtral-mini).
pub fn fig9(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(69, scale);
    let zoo = ZooModel::MixtralMini;
    let (fp, _) = load_or_init_model(zoo);
    let (rec_fp, _) = record_selections(&fp, &ctx.ppl_eval);
    let mut table = Table::new(
        "Fig 9 — MHSA quantization bit-width vs expert-shift (mixtral-mini, experts fp)",
        &["MHSA bits", "change-rate all", "change-rate any", "PPL"],
    );
    let mut json = Json::obj();
    for bits in [2u32, 3, 4, 5, 6, 8] {
        // Quantize MHSA only, layer by layer, with GPTQ on captured inputs.
        let mut q = Model::new(fp.weights.clone());
        for li in 0..fp.cfg().n_layers {
            let (mhsa_x, wo_x) = {
                let h = Hooks::capturing(fp.cfg().n_layers);
                for seq in &ctx.calib {
                    q.forward_with_hooks(seq, &h);
                }
                // Use the last capture (aggregating all would need appends;
                // the per-seq distribution is stationary enough here).
                let mh = h.capture_mhsa_inputs.as_ref().and_then(|c| c.borrow()[li].clone());
                let wo = h.capture_wo_inputs.as_ref().and_then(|c| c.borrow()[li].clone());
                debug_assert!(
                    mh.is_some() && wo.is_some(),
                    "capturing hooks filled layer {li}"
                );
                let (Some(mh), Some(wo)) = (mh, wo) else { continue };
                (mh, wo)
            };
            let gcfg = GptqConfig::new(bits, 128.min(fp.cfg().d_model));
            let mut h_in = Hessian::new(fp.cfg().d_model);
            h_in.update(&mhsa_x);
            let mut h_wo = Hessian::new(fp.cfg().d_model);
            h_wo.update(&wo_x);
            let l = &mut q.weights.layers[li];
            // Install packed weights: the sweep measures the served path.
            l.wq = WeightMat::from_quant(&l.wq.gptq_quantize(&h_in, gcfg));
            l.wk = WeightMat::from_quant(&l.wk.gptq_quantize(&h_in, gcfg));
            l.wv = WeightMat::from_quant(&l.wv.gptq_quantize(&h_in, gcfg));
            l.wo = WeightMat::from_quant(&l.wo.gptq_quantize(&h_wo, gcfg));
        }
        let (rec_q, _) = record_selections(&q, &ctx.ppl_eval);
        let cr = mean_change_rates(&rec_fp, &rec_q);
        let ppl = crate::eval::perplexity(&q, &ctx.ppl_eval);
        table.row(vec![
            format!("{bits}"),
            format!("{:.2}%", cr.all_changed * 100.0),
            format!("{:.2}%", cr.any_changed * 100.0),
            format!("{ppl:.3}"),
        ]);
        let mut o = Json::obj();
        o.set("all", Json::Num(cr.all_changed as f64))
            .set("any", Json::Num(cr.any_changed as f64))
            .set("ppl", Json::Num(ppl));
        json.set(&format!("bits{bits}"), o);
    }
    table.print();
    println!("(expected shape: steep change-rate/PPL drop 2→4 bits, flat 4→8 — the\n\
              rationale for 4-bit MHSA)");
    super::save_result("fig9", &json)?;
    Ok(())
}
