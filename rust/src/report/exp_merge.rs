//! Expert-merging experiment: Table-4-style rows across merge thresholds
//! {1.0, 0.9, 0.7} — expert count, routed-expert bytes, PPL delta, decode
//! throughput — the third compression axis next to QESC (bytes/expert)
//! and PESF (experts/task).
//!
//! Random-init experts are near-orthogonal, so nothing would merge at any
//! realistic threshold and the sweep would be vacuous; the driver first
//! synthesizes a redundant-expert workload
//! ([`crate::prune::merge::synthesize_mergeable_pairs`]) in which every
//! expert pair is ~99%-similar — the regime MC# observes in real
//! checkpoints. The threshold=1.0 row is the bit-identity contract: its
//! weights, expert count and PPL must equal the unmerged model exactly.

use super::exp_common::serve_workload;
use super::Table;
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::model::{Model, ZooModel};
use crate::prune::merge::{merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig};
use crate::serve::{Engine, EngineConfig, Request};
use crate::util::json::Json;
use crate::Result;

/// Default merge thresholds swept, high to low (1.0 = merge nothing).
pub const THRESHOLDS: [f32; 3] = [1.0, 0.9, 0.7];

/// Derive a threshold sweep from an `analyze --expert-sim` result
/// (`results/analyze_expert_sim.json`) instead of the fixed default:
/// the sweep always anchors at 1.0 (the bit-identity row), then adds a
/// tight threshold just under the largest measured off-diagonal
/// similarity (merges only the most redundant pairs) and a mid threshold
/// halfway to the mean similarity (merges the broader redundant mass).
/// Values snap down to a 0.05 grid and clamp to [0.05, 0.95]; duplicates
/// collapse, so near-orthogonal models yield a short sweep.
pub fn thresholds_from_analysis(doc: &Json) -> Result<Vec<f32>> {
    use anyhow::Context;
    let layers = doc.req_arr("layers")?;
    if layers.is_empty() {
        anyhow::bail!("analysis document has an empty `layers` array");
    }
    let mut max_sim = 0f64;
    let mut mean_sum = 0f64;
    for (i, l) in layers.iter().enumerate() {
        let mx = l.req_f64("max_offdiag_sim").with_context(|| format!("analysis layer #{i}"))?;
        let mn =
            l.req_f64("mean_offdiag_sim").with_context(|| format!("analysis layer #{i}"))?;
        if !mx.is_finite() || !mn.is_finite() {
            anyhow::bail!("analysis layer #{i}: non-finite similarity");
        }
        max_sim = max_sim.max(mx.clamp(0.0, 1.0));
        mean_sum += mn.clamp(0.0, 1.0);
    }
    let mean_sim = mean_sum / layers.len() as f64;
    let grid = |v: f64| (((v * 20.0).floor() / 20.0).clamp(0.05, 0.95)) as f32;
    let mut out = vec![1.0f32, grid(max_sim), grid((max_sim + mean_sim) / 2.0)];
    out.sort_by(|a, b| b.total_cmp(a));
    out.dedup();
    Ok(out)
}

/// Decode throughput of a model on a small decode-heavy workload
/// (warmup + median-of-3, the Table-4 protocol).
fn decode_tps(model: Model, n_reqs: usize, len: usize) -> f64 {
    let decode = (len / 8).clamp(4, 32);
    let dlen = len.min(model.cfg().max_seq.saturating_sub(decode)).max(8);
    let engine = Engine::new(model, EngineConfig { workers: 1, ..Default::default() });
    let mut mix = crate::data::corpus::WikiMixture::new(173);
    let make = |mix: &mut crate::data::corpus::WikiMixture| -> Vec<Request> {
        (0..n_reqs as u64)
            .map(|i| Request::new(i, mix.sequence(dlen)).with_decode(decode))
            .collect()
    };
    engine.serve(make(&mut mix)); // warmup
    let mut rates: Vec<f64> =
        (0..3).map(|_| engine.serve(make(&mut mix)).1.decode_tokens_per_sec()).collect();
    rates.sort_by(|a, b| a.total_cmp(b));
    rates[rates.len() / 2]
}

/// The merge-threshold sweep (`eac-moe experiment merge`) at the fixed
/// default thresholds.
pub fn merge_table(scale: f64) -> Result<()> {
    merge_table_with_thresholds(scale, &THRESHOLDS)
}

/// `eac-moe experiment merge --from-analysis <json>`: run the sweep at
/// thresholds derived from a measured expert-similarity analysis.
pub fn merge_table_from_analysis(scale: f64, path: &std::path::Path) -> Result<()> {
    let doc = crate::util::json::load(path)?;
    let thresholds = thresholds_from_analysis(&doc)?;
    println!(
        "[merge] thresholds derived from {}: {:?}",
        path.display(),
        thresholds
    );
    merge_table_with_thresholds(scale, &thresholds)
}

/// The merge-threshold sweep over an explicit threshold list (high to
/// low; a 1.0 entry is pinned bit-identical to the unmerged model).
pub fn merge_table_with_thresholds(scale: f64, thresholds: &[f32]) -> Result<()> {
    let ctx = ExperimentContext::new(59, scale);
    let (n_reqs, len) = serve_workload(scale);
    let mut table = Table::new(
        "Expert merging — threshold sweep (synthesized redundant experts)",
        &["Model", "Threshold", "Experts", "Routed MB", "PPL", "dPPL", "Decode tok/s"],
    );
    let mut json = Json::obj();
    for zoo in ZooModel::ALL {
        let (fp, _) = load_or_init_model(zoo);
        let mut base_w = fp.weights.clone();
        // The redundant-expert regime: expert 2i+1 ≈ expert 2i with ~5%
        // relative noise, so pairs sit near cosine 0.999 while cross-pair
        // similarity stays near 0 — thresholds 0.9/0.7 halve the experts.
        synthesize_mergeable_pairs(&mut base_w, 0.05, 71);
        let base = Model::new(base_w.clone());
        let ppl_base = crate::eval::perplexity(&base, &ctx.ppl_eval);
        let experts_base: usize = base_w.layers.iter().map(|l| l.n_routed()).sum();
        let mut o = Json::obj();
        for (row, &t) in thresholds.iter().enumerate() {
            let mut w = base_w.clone();
            let cfg = w.cfg.clone();
            let rep = merge_experts(
                &mut w,
                &uniform_frequencies(cfg.n_layers, cfg.n_experts),
                &MergeConfig::at_threshold(t),
            );
            let routed_mb = w.routed_expert_bytes() as f64 / 1e6;
            let model = Model::new(w);
            let ppl = crate::eval::perplexity(&model, &ctx.ppl_eval);
            if t >= 1.0 {
                // The contract the whole axis rests on: threshold 1.0
                // installs nothing, so the forward pass (and its PPL) is
                // bit-identical to the unmerged model.
                assert_eq!(rep.experts_after, experts_base, "threshold 1.0 must merge nothing");
                assert_eq!(ppl, ppl_base, "threshold 1.0 must be bit-identical");
            }
            let tps = decode_tps(model, n_reqs, len);
            table.row(vec![
                if row == 0 { zoo.display().into() } else { "".into() },
                format!("{t:.1}"),
                format!("{}", rep.experts_after),
                format!("{routed_mb:.2}"),
                format!("{ppl:.3}"),
                format!("{:+.3}", ppl - ppl_base),
                format!("{tps:.0}"),
            ]);
            let mut tj = Json::obj();
            tj.set("experts", Json::Num(rep.experts_after as f64))
                .set("experts_before", Json::Num(rep.experts_before as f64))
                .set("routed_mb", Json::Num(routed_mb))
                .set("ppl", Json::Num(ppl))
                .set("ppl_delta", Json::Num(ppl - ppl_base))
                .set("decode_tps", Json::Num(tps));
            o.set(&format!("threshold_{t:.2}"), tj);
        }
        json.set(zoo.key(), o);
    }
    table.print();
    println!(
        "(expected shape: threshold 1.0 reproduces the unmerged model exactly —\n\
          dPPL +0.000 by construction; 0.9/0.7 halve the expert count and routed\n\
          bytes on the synthesized pairs at a small dPPL, with decode tok/s flat\n\
          or better — fewer, hotter experts batch larger GEMMs)"
    );
    super::save_result("merge", &json)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shape of `analyze --expert-sim` output
    /// ([`crate::eval::expert_sim::ExpertSimReport::to_json`]), trimmed
    /// to the fields the derivation reads.
    fn fixture(layer_sims: &[(f64, f64)]) -> Json {
        let layers: Vec<String> = layer_sims
            .iter()
            .enumerate()
            .map(|(i, (mean, max))| {
                format!(
                    r#"{{"layer": {i}, "n_experts": 8, "mean_offdiag_sim": {mean},
                        "max_offdiag_sim": {max}, "mergeable_pairs_at_0.9": 0,
                        "mergeable_pairs_at_0.7": 0, "router_rank": 8,
                        "pseudo_moe": false}}"#
                )
            })
            .collect();
        let doc = format!(
            r#"{{"model": "tiny", "dataset": "wiki", "pseudo_moe": false,
                "layers": [{}]}}"#,
            layers.join(",")
        );
        Json::parse(&doc).unwrap()
    }

    #[test]
    fn redundant_analysis_yields_tight_and_mid_thresholds() {
        // Max similarity 0.99, mean 0.10 (the synthesized-pairs regime):
        // tight = floor(0.99 * 20)/20 = 0.95, mid = floor(0.545 * 20)/20
        // = 0.50, anchored at 1.0.
        let doc = fixture(&[(0.10, 0.99), (0.10, 0.98)]);
        assert_eq!(thresholds_from_analysis(&doc).unwrap(), vec![1.0, 0.95, 0.50]);
    }

    #[test]
    fn orthogonal_analysis_collapses_to_short_sweep() {
        // Near-orthogonal experts: both derived values clamp to the 0.05
        // floor and dedupe — the sweep is just the anchor + one row that
        // (correctly) still merges nothing on such a model.
        let doc = fixture(&[(0.0, 0.02), (0.01, 0.03)]);
        assert_eq!(thresholds_from_analysis(&doc).unwrap(), vec![1.0, 0.05]);
    }

    #[test]
    fn thresholds_are_sorted_desc_with_leading_anchor() {
        let doc = fixture(&[(0.4, 0.8)]);
        let ts = thresholds_from_analysis(&doc).unwrap();
        assert_eq!(ts.first().copied(), Some(1.0), "1.0 anchor always leads");
        assert!(ts.windows(2).all(|w| w[0] > w[1]), "strictly descending: {ts:?}");
        assert!(ts.iter().all(|&t| (0.05..=1.0).contains(&t)));
    }

    #[test]
    fn malformed_analysis_is_an_error_not_a_panic() {
        assert!(thresholds_from_analysis(&Json::obj()).is_err(), "missing layers");
        let empty = Json::parse(r#"{"layers": []}"#).unwrap();
        assert!(thresholds_from_analysis(&empty).is_err(), "empty layers");
        let missing_key =
            Json::parse(r#"{"layers": [{"mean_offdiag_sim": 0.1}]}"#).unwrap();
        let err = format!("{:#}", thresholds_from_analysis(&missing_key).unwrap_err());
        assert!(err.contains("layer #0"), "error names the layer: {err}");
    }
}
