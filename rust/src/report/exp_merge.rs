//! Expert-merging experiment: Table-4-style rows across merge thresholds
//! {1.0, 0.9, 0.7} — expert count, routed-expert bytes, PPL delta, decode
//! throughput — the third compression axis next to QESC (bytes/expert)
//! and PESF (experts/task).
//!
//! Random-init experts are near-orthogonal, so nothing would merge at any
//! realistic threshold and the sweep would be vacuous; the driver first
//! synthesizes a redundant-expert workload
//! ([`crate::prune::merge::synthesize_mergeable_pairs`]) in which every
//! expert pair is ~99%-similar — the regime MC# observes in real
//! checkpoints. The threshold=1.0 row is the bit-identity contract: its
//! weights, expert count and PPL must equal the unmerged model exactly.

use super::exp_common::serve_workload;
use super::Table;
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::model::{Model, ZooModel};
use crate::prune::merge::{merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig};
use crate::serve::{Engine, EngineConfig, Request};
use crate::util::json::Json;
use crate::Result;

/// Merge thresholds swept, high to low (1.0 = merge nothing).
pub const THRESHOLDS: [f32; 3] = [1.0, 0.9, 0.7];

/// Decode throughput of a model on a small decode-heavy workload
/// (warmup + median-of-3, the Table-4 protocol).
fn decode_tps(model: Model, n_reqs: usize, len: usize) -> f64 {
    let decode = (len / 8).clamp(4, 32);
    let dlen = len.min(model.cfg().max_seq.saturating_sub(decode)).max(8);
    let engine = Engine::new(model, EngineConfig { workers: 1, ..Default::default() });
    let mut mix = crate::data::corpus::WikiMixture::new(173);
    let make = |mix: &mut crate::data::corpus::WikiMixture| -> Vec<Request> {
        (0..n_reqs as u64)
            .map(|i| Request::new(i, mix.sequence(dlen)).with_decode(decode))
            .collect()
    };
    engine.serve(make(&mut mix)); // warmup
    let mut rates: Vec<f64> =
        (0..3).map(|_| engine.serve(make(&mut mix)).1.decode_tokens_per_sec()).collect();
    rates.sort_by(|a, b| a.total_cmp(b));
    rates[rates.len() / 2]
}

/// The merge-threshold sweep (`eac-moe experiment merge`).
pub fn merge_table(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(59, scale);
    let (n_reqs, len) = serve_workload(scale);
    let mut table = Table::new(
        "Expert merging — threshold sweep (synthesized redundant experts)",
        &["Model", "Threshold", "Experts", "Routed MB", "PPL", "dPPL", "Decode tok/s"],
    );
    let mut json = Json::obj();
    for zoo in ZooModel::ALL {
        let (fp, _) = load_or_init_model(zoo);
        let mut base_w = fp.weights.clone();
        // The redundant-expert regime: expert 2i+1 ≈ expert 2i with ~5%
        // relative noise, so pairs sit near cosine 0.999 while cross-pair
        // similarity stays near 0 — thresholds 0.9/0.7 halve the experts.
        synthesize_mergeable_pairs(&mut base_w, 0.05, 71);
        let base = Model::new(base_w.clone());
        let ppl_base = crate::eval::perplexity(&base, &ctx.ppl_eval);
        let experts_base: usize = base_w.layers.iter().map(|l| l.n_routed()).sum();
        let mut o = Json::obj();
        for (row, &t) in THRESHOLDS.iter().enumerate() {
            let mut w = base_w.clone();
            let cfg = w.cfg.clone();
            let rep = merge_experts(
                &mut w,
                &uniform_frequencies(cfg.n_layers, cfg.n_experts),
                &MergeConfig::at_threshold(t),
            );
            let routed_mb = w.routed_expert_bytes() as f64 / 1e6;
            let model = Model::new(w);
            let ppl = crate::eval::perplexity(&model, &ctx.ppl_eval);
            if t >= 1.0 {
                // The contract the whole axis rests on: threshold 1.0
                // installs nothing, so the forward pass (and its PPL) is
                // bit-identical to the unmerged model.
                assert_eq!(rep.experts_after, experts_base, "threshold 1.0 must merge nothing");
                assert_eq!(ppl, ppl_base, "threshold 1.0 must be bit-identical");
            }
            let tps = decode_tps(model, n_reqs, len);
            table.row(vec![
                if row == 0 { zoo.display().into() } else { "".into() },
                format!("{t:.1}"),
                format!("{}", rep.experts_after),
                format!("{routed_mb:.2}"),
                format!("{ppl:.3}"),
                format!("{:+.3}", ppl - ppl_base),
                format!("{tps:.0}"),
            ]);
            let mut tj = Json::obj();
            tj.set("experts", Json::Num(rep.experts_after as f64))
                .set("experts_before", Json::Num(rep.experts_before as f64))
                .set("routed_mb", Json::Num(routed_mb))
                .set("ppl", Json::Num(ppl))
                .set("ppl_delta", Json::Num(ppl - ppl_base))
                .set("decode_tps", Json::Num(tps));
            o.set(&format!("threshold_{t:.1}"), tj);
        }
        json.set(zoo.key(), o);
    }
    table.print();
    println!(
        "(expected shape: threshold 1.0 reproduces the unmerged model exactly —\n\
          dPPL +0.000 by construction; 0.9/0.7 halve the expert count and routed\n\
          bytes on the synthesized pairs at a small dPPL, with decode tok/s flat\n\
          or better — fewer, hotter experts batch larger GEMMs)"
    );
    super::save_result("merge", &json)?;
    Ok(())
}
