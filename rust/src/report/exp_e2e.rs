//! End-to-end compression experiments: Table 4 (QESC+PESF full pipeline),
//! Table 5 (vs MC-MoE), Table 7 (time split), Fig 1 (summary).

use super::exp_common::*;
use super::Table;
use crate::coordinator::{load_or_init_model, ExperimentContext};
use crate::data::tasks::zero_shot_suite;
use crate::model::ZooModel;
use crate::prune::odp::OdpPruner;
use crate::prune::pesf::PesfConfig;
use crate::serve::PrunePolicy;
use crate::util::json::Json;
use crate::Result;

/// Table 4 (+ the Fig 1 summary): Baseline vs QESC(3.03) vs QESC+PESF(0.3)
/// vs QESC under a 50% expert-memory budget: params, **resident vs on-disk
/// expert bytes** (so "budget held" and "model size" are separate
/// columns), accuracy, speedup — plus two decode rows per model that put
/// the KV-cache axis on the table: f32 KV (bit-identical baseline) vs
/// int8 KV (`--kv-bits 8`, per-head scales, ~4x smaller peak cache).
pub fn table4(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 54);
    let ctx = ExperimentContext::new(54, scale);
    let (n_reqs, len) = serve_workload(scale);
    let mut table = Table::new(
        "Table 4 — QESC(3.03-bit) + PESF(α=0.3) overall",
        &["Model", "Method", "Params(MB)", "Experts res(MB)", "Experts disk(MB)", "KV peak(MB)", "0-shot avg", "Speedup"],
    );
    let mut json = Json::obj();
    for zoo in ZooModel::ALL {
        let (fp, _) = load_or_init_model(zoo);
        // Measured resident bytes (Weights::storage_bytes), not a simulated
        // size: the compressed model actually holds packed codes.
        let fp_mb = fp.weights.storage_bytes() as f64 / 1e6;
        // The expert columns use the *routed-only* definition throughout —
        // the set a budget can manage — so the tiered row's numbers are
        // comparable to the resident rows (shared experts are pinned and
        // counted in Params(MB) instead).
        let fp_expert_mb = fp.weights.routed_expert_bytes() as f64 / 1e6;
        let (q, report) = compress(&fp, zoo, QuantMethod::Qesc, BitSetting::B303, &ctx);
        let q_mb = q.weights.storage_bytes() as f64 / 1e6;
        let expert_mb = q.weights.routed_expert_bytes() as f64 / 1e6;
        let base = measure(&fp, &ctx, &suite);
        let qesc = measure(&q, &ctx, &suite);
        let qp = measure_pruned(&q, &ctx, &suite, 0.3);
        let lat_base = prefill_latency(
            crate::model::Model::new(fp.weights.clone()),
            PrunePolicy::None,
            n_reqs,
            len,
        );
        // Same packed weights with and without PESF, so the speedup column
        // isolates the PESF gain; the packed/dense GEMM cost shows up in
        // the QESC row's own ratio instead of contaminating PESF's.
        let lat_q = prefill_latency(
            crate::model::Model::new(q.weights.clone()),
            PrunePolicy::None,
            n_reqs,
            len,
        );
        let lat_pesf = prefill_latency(
            crate::model::Model::new(q.weights.clone()),
            PrunePolicy::Pesf(PesfConfig { alpha: 0.3, ..Default::default() }),
            n_reqs,
            len,
        );
        let speedup_pesf = lat_q / lat_pesf;
        // Tiered serving: the same packed experts under a hard budget of
        // 50% of their bytes (outputs are bit-identical; only residency
        // changes). ServeMetrics supplies the measured "budget held" vs
        // "model size" numbers.
        let spill = std::env::temp_dir()
            .join(format!("eac_moe_table4_{}_{}.bin", zoo.key(), std::process::id()));
        let routed_total = q.weights.routed_expert_bytes();
        let budget = (routed_total / 2).max(q.weights.max_expert_bytes());
        let tiered = crate::model::Model::new(q.weights.clone()).into_tiered(budget, &spill)?;
        let tiered_engine = crate::serve::Engine::new(
            tiered,
            crate::serve::EngineConfig { workers: 1, ..Default::default() },
        );
        // Same measurement protocol as `prefill_latency` (warmup serve,
        // then median of 3), so this row's Speedup is comparable to the
        // others — the warmup also brings the cache to its steady state
        // instead of charging every cold-start load to the measurement.
        let mut mix = crate::data::corpus::WikiMixture::new(97);
        let make_reqs = |mix: &mut crate::data::corpus::WikiMixture| {
            (0..n_reqs as u64)
                .map(|i| crate::serve::Request::new(i, mix.sequence(len)))
                .collect::<Vec<crate::serve::Request>>()
        };
        tiered_engine.serve(make_reqs(&mut mix)); // warmup (cold loads)
        let mut trials = Vec::new();
        let mut tm = None;
        for _ in 0..3 {
            let (_, m) = tiered_engine.serve(make_reqs(&mut mix));
            trials.push(m.prefill.percentile_ms(0.5));
            tm = Some(m);
        }
        trials.sort_by(|a, b| a.total_cmp(b));
        let lat_tiered = trials[trials.len() / 2] / 1e3;
        debug_assert!(tm.is_some(), "three tiered trials ran");
        let Some(tm) = tm else { continue };
        let _ = std::fs::remove_file(&spill);
        // Decode rows: the same packed weights in a decode-heavy workload
        // at both KV precisions. The kv-f32 row is the decode baseline
        // (bit-identical serving, Speedup 1.00x by definition); the
        // kv-int8 row reports its peak-cache saving and decode tok/s
        // ratio. Prompts are capped so decode never truncates at max_seq.
        let decode = (len / 8).clamp(4, 32);
        let dlen = len.min(q.weights.cfg.max_seq.saturating_sub(decode)).max(8);
        let decode_run = |kv_bits: u8| -> crate::serve::ServeMetrics {
            let engine = crate::serve::Engine::new(
                crate::model::Model::new(q.weights.clone()),
                crate::serve::EngineConfig { workers: 1, kv_bits, ..Default::default() },
            );
            let mut mix = crate::data::corpus::WikiMixture::new(131);
            let make = |mix: &mut crate::data::corpus::WikiMixture| {
                (0..n_reqs as u64)
                    .map(|i| {
                        crate::serve::Request::new(i, mix.sequence(dlen)).with_decode(decode)
                    })
                    .collect::<Vec<crate::serve::Request>>()
            };
            engine.serve(make(&mut mix)); // warmup
            // Median-of-3 by decode throughput, same protocol shape as the
            // latency rows; the median run's metrics carry the peak bytes.
            let mut runs: Vec<crate::serve::ServeMetrics> =
                (0..3).map(|_| engine.serve(make(&mut mix)).1).collect();
            runs.sort_by(|a, b| {
                a.decode_tokens_per_sec().total_cmp(&b.decode_tokens_per_sec())
            });
            runs.swap_remove(1)
        };
        let kv32 = decode_run(32);
        let kv8 = decode_run(8);
        let kv32_tps = kv32.decode_tokens_per_sec();
        let kv8_tps = kv8.decode_tokens_per_sec();
        table.row(vec![zoo.display().into(), "Baseline".into(), format!("{fp_mb:.2}"), format!("{fp_expert_mb:.2}"), format!("{fp_expert_mb:.2}"), "-".into(), format!("{:.2}", base.suite.mean_accuracy()), "1.00x".into()]);
        table.row(vec!["".into(), "QESC".into(), format!("{q_mb:.2}"), format!("{expert_mb:.2}"), format!("{expert_mb:.2}"), "-".into(), format!("{:.2}", qesc.suite.mean_accuracy()), format!("{:.2}x", lat_base / lat_q)]);
        table.row(vec!["".into(), "QESC+PESF".into(), format!("{q_mb:.2}"), format!("{expert_mb:.2}"), format!("{expert_mb:.2}"), "-".into(), format!("{:.2}", qp.suite.mean_accuracy()), format!("{:.2}x", lat_base / lat_pesf)]);
        table.row(vec![
            "".into(),
            "QESC tiered@50%".into(),
            format!("{:.2}", tm.resident_weight_bytes as f64 / 1e6),
            // "Budget held": the store's high-water mark under the budget.
            format!("{:.2}", tm.peak_resident_expert_bytes as f64 / 1e6),
            // "Model size": the full on-disk expert set.
            format!("{:.2}", tm.total_expert_bytes as f64 / 1e6),
            "-".into(),
            // Bit-identical to QESC by the store's correctness contract.
            format!("{:.2}", qesc.suite.mean_accuracy()),
            format!("{:.2}x", lat_base / lat_tiered),
        ]);
        table.row(vec![
            "".into(),
            "QESC decode kv-f32".into(),
            format!("{q_mb:.2}"),
            format!("{expert_mb:.2}"),
            format!("{expert_mb:.2}"),
            format!("{:.2}", kv32.peak_kv_cache_bytes as f64 / 1e6),
            // f32 KV serving is bit-identical to the forward pass the
            // suite was scored on, so the QESC accuracy carries over.
            format!("{:.2}", qesc.suite.mean_accuracy()),
            "1.00x".into(),
        ]);
        table.row(vec![
            "".into(),
            "QESC decode kv-int8".into(),
            format!("{q_mb:.2}"),
            format!("{expert_mb:.2}"),
            format!("{expert_mb:.2}"),
            format!("{:.2}", kv8.peak_kv_cache_bytes as f64 / 1e6),
            // Tolerance-pinned, not re-scored: the int8 KV quality delta
            // is measured as a perplexity delta in bench_perf's kv_cache
            // section instead of a (noisier) small-suite accuracy rerun.
            "-".into(),
            format!("{:.2}x", kv8_tps / kv32_tps.max(1e-12)),
        ]);
        let mut o = Json::obj();
        o.set("fp_mb", Json::Num(fp_mb))
            .set("q_mb", Json::Num(q_mb))
            .set("q_expert_mb", Json::Num(expert_mb))
            .set("avg_expert_bits", Json::Num(report.avg_expert_bits))
            .set("compression", Json::Num(fp_mb / q_mb))
            .set("acc_base", Json::Num(base.suite.mean_accuracy() as f64))
            .set("acc_qesc", Json::Num(qesc.suite.mean_accuracy() as f64))
            .set("acc_qesc_pesf", Json::Num(qp.suite.mean_accuracy() as f64))
            // PESF gain isolated on the same packed weights.
            .set("speedup_pesf", Json::Num(speedup_pesf))
            // Cost of serving packed vs dense f32 on this CPU path (>1 =
            // slower; the fused GEMM targets ~1.5-2x of dense).
            .set("packed_over_dense_latency", Json::Num(lat_q / lat_base))
            // Tiered store at a 50% expert budget: budget held vs model
            // size, plus the traffic the budget induced.
            .set("tiered_budget_mb", Json::Num(budget as f64 / 1e6))
            .set("tiered_peak_resident_mb", Json::Num(tm.peak_resident_expert_bytes as f64 / 1e6))
            .set("tiered_disk_mb", Json::Num(tm.total_expert_bytes as f64 / 1e6))
            .set("tiered_hit_rate", Json::Num(tm.expert_hit_rate()))
            .set("tiered_evictions", Json::Num(tm.expert_evictions as f64))
            .set("tiered_load_stall_secs", Json::Num(tm.expert_load_stall_secs))
            .set("tiered_over_resident_latency", Json::Num(lat_tiered / lat_q))
            // KV-cache axis: peak resident cache bytes and decode
            // throughput at f32 vs int8 storage (same weights, same
            // workload; f32 is the bit-identical baseline).
            .set("kv32_peak_mb", Json::Num(kv32.peak_kv_cache_bytes as f64 / 1e6))
            .set("kv8_peak_mb", Json::Num(kv8.peak_kv_cache_bytes as f64 / 1e6))
            .set("kv32_decode_tps", Json::Num(kv32_tps))
            .set("kv8_decode_tps", Json::Num(kv8_tps))
            .set("ppl_base", Json::Num(base.ppl))
            .set("ppl_qesc", Json::Num(qesc.ppl));
        json.set(zoo.key(), o);
    }
    table.print();
    println!("(expected shape: large memory reduction vs the f32-resident baseline —\n\
              ~8-10x at 3.03-bit experts — at baseline accuracy within ~1 point;\n\
              PESF speeds up the packed model, while the packed GEMM itself costs\n\
              ~1.5-2x dense on CPU, so the Speedup column vs the f32 baseline can\n\
              sit below 1.00x — the isolated PESF gain is in speedup_pesf. The\n\
              tiered row holds ≤50% of the expert bytes resident with identical\n\
              outputs: 'Experts res' is the budget held, 'Experts disk' the model\n\
              size — the distinction challenge (1) is about. The decode rows add\n\
              the KV axis: kv-int8 should show ~4x smaller peak cache than kv-f32\n\
              at comparable decode tok/s)");
    super::save_result("table4", &json)?;
    Ok(())
}

/// Table 5: EAC-MoE vs MC-MoE (= PMQ mixed-precision + ODP pruning) on
/// mixtral-mini at the 2.06 and 2.54 settings.
pub fn table5(scale: f64) -> Result<()> {
    let suite = zero_shot_suite(n_items(scale), 55);
    let ctx = ExperimentContext::new(55, scale);
    let (n_reqs, len) = serve_workload(scale);
    let zoo = ZooModel::MixtralMini;
    let (fp, _) = load_or_init_model(zoo);
    let base = measure(&fp, &ctx, &suite);
    let lat_base = prefill_latency(
        crate::model::Model::new(fp.weights.clone()),
        PrunePolicy::None,
        n_reqs,
        len,
    );
    let mut table = Table::new(
        "Table 5 — vs MC-MoE (mixtral-mini)",
        &["Bits", "Method", "PPL", "0-shot avg", "Speedup"],
    );
    table.row(vec!["16.00".into(), "Baseline".into(), format!("{:.3}", base.ppl), format!("{:.2}", base.suite.mean_accuracy()), "1.00x".into()]);
    let mut json = Json::obj();
    for bits in [BitSetting::B206, BitSetting::B254] {
        // MC-MoE = PMQ quantization + ODP dynamic pruning.
        let (q_pmq, _) = compress(&fp, zoo, QuantMethod::Pmq, bits, &ctx);
        let odp = OdpPruner::calibrate(&q_pmq, &ctx.calib, 0.8);
        let mc_acc = crate::eval::eval_suite(&q_pmq, &suite, || crate::model::hooks::Hooks {
            selection_filter: Some(odp.filter()),
            ..Default::default()
        })
        .mean_accuracy();
        let mc_ppl = crate::eval::perplexity(&q_pmq, &ctx.ppl_eval);
        let mc_lat = prefill_latency(
            crate::model::Model::new(q_pmq.weights.clone()),
            PrunePolicy::Odp(odp),
            n_reqs,
            len,
        );
        // EAC-MoE = QESC + PESF(0.3).
        let (q_qesc, _) = compress(&fp, zoo, QuantMethod::Qesc, bits, &ctx);
        let eac = measure_pruned(&q_qesc, &ctx, &suite, 0.3);
        let eac_lat = prefill_latency(
            crate::model::Model::new(q_qesc.weights.clone()),
            PrunePolicy::Pesf(PesfConfig { alpha: 0.3, ..Default::default() }),
            n_reqs,
            len,
        );
        table.row(vec![bits.label().into(), "MC-MoE".into(), format!("{mc_ppl:.3}"), format!("{mc_acc:.2}"), format!("{:.2}x", lat_base / mc_lat)]);
        table.row(vec!["".into(), "EAC-MoE (ours)".into(), format!("{:.3}", eac.ppl), format!("{:.2}", eac.suite.mean_accuracy()), format!("{:.2}x", lat_base / eac_lat)]);
        let mut o = Json::obj();
        o.set("mcmoe_ppl", Json::Num(mc_ppl))
            .set("mcmoe_acc", Json::Num(mc_acc as f64))
            .set("mcmoe_speedup", Json::Num(lat_base / mc_lat))
            .set("eac_ppl", Json::Num(eac.ppl))
            .set("eac_acc", Json::Num(eac.suite.mean_accuracy() as f64))
            .set("eac_speedup", Json::Num(lat_base / eac_lat));
        json.set(bits.label(), o);
    }
    table.print();
    println!("(expected shape: EAC-MoE ≥ MC-MoE on PPL and accuracy at comparable or\n\
              better speedup)");
    super::save_result("table5", &json)?;
    Ok(())
}

/// Table 7 (A.1): time split between GPTQ and router calibration.
pub fn table7(scale: f64) -> Result<()> {
    let ctx = ExperimentContext::new(57, scale);
    let mut table = Table::new(
        "Table 7 — QESC time split",
        &["Model", "Step", "Time(s)", "Proportion"],
    );
    let mut json = Json::obj();
    for zoo in ZooModel::ALL {
        let (fp, _) = load_or_init_model(zoo);
        let (_, report) = compress(&fp, zoo, QuantMethod::Qesc, BitSetting::B303, &ctx);
        let total = report.gptq_secs + report.router_calib_secs;
        table.row(vec![
            zoo.display().into(),
            "GPTQ".into(),
            format!("{:.2}", report.gptq_secs),
            format!("{:.2}%", 100.0 * report.gptq_secs / total),
        ]);
        table.row(vec![
            "".into(),
            "Calibrating Router".into(),
            format!("{:.2}", report.router_calib_secs),
            format!("{:.2}%", 100.0 * report.router_calib_secs / total),
        ]);
        let mut o = Json::obj();
        o.set("gptq_secs", Json::Num(report.gptq_secs))
            .set("calib_secs", Json::Num(report.router_calib_secs))
            .set("calib_pct", Json::Num(100.0 * report.router_calib_secs / total));
        json.set(zoo.key(), o);
    }
    table.print();
    println!("(expected shape: router calibration is a small fraction of total time)");
    super::save_result("table7", &json)?;
    Ok(())
}
