//! Weight containers + initialization + binary IO.
//!
//! Layout matches `python/compile/pretrain.py`, which trains the miniature
//! models in JAX and saves them through the same `TensorFile` format
//! (see `util::binio` for the byte layout). Naming convention:
//!
//! ```text
//! embed                                (vocab, d_model)
//! final_norm                           (d_model,)
//! layer{i}.attn_norm / ffn_norm        (d_model,)
//! layer{i}.wq / wk / wv / wo           (d_model, d_model)
//! layer{i}.router                      (d_model, n_experts)
//! layer{i}.expert{e}.w1 / w3           (d_model, d_ff)
//! layer{i}.expert{e}.w2                (d_ff, d_model)
//! layer{i}.shared{s}.w1 / w2 / w3      same shapes
//! ```

use super::config::ModelConfig;
use crate::tensor::{Mat, Pcg64};
use crate::util::binio::TensorFile;
use anyhow::Result;
use std::path::Path;

/// One SwiGLU expert: out = (silu(x@w1) * (x@w3)) @ w2.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    pub w1: Mat, // (d_model, d_ff)
    pub w2: Mat, // (d_ff, d_model)
    pub w3: Mat, // (d_model, d_ff)
}

impl ExpertWeights {
    pub fn randn(cfg: &ModelConfig, rng: &mut Pcg64) -> Self {
        let s1 = (2.0 / cfg.d_model as f32).sqrt();
        let s2 = (2.0 / cfg.d_ff as f32).sqrt();
        ExpertWeights {
            w1: Mat::randn(cfg.d_model, cfg.d_ff, s1, rng),
            w2: Mat::randn(cfg.d_ff, cfg.d_model, s2, rng),
            w3: Mat::randn(cfg.d_model, cfg.d_ff, s1, rng),
        }
    }

    pub fn param_count(&self) -> usize {
        self.w1.data.len() + self.w2.data.len() + self.w3.data.len()
    }
}

/// One transformer layer.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub router: Mat, // (d_model, n_experts)
    pub experts: Vec<ExpertWeights>,
    pub shared: Vec<ExpertWeights>,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub embed: Mat, // (vocab, d_model); output head is tied (embed^T)
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl Weights {
    /// Random initialization (used in tests and before pretraining).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 100);
        let sd = (1.0 / cfg.d_model as f32).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; cfg.d_model],
                ffn_norm: vec![1.0; cfg.d_model],
                wq: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng),
                wk: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng),
                wv: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng),
                wo: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng),
                router: Mat::randn(cfg.d_model, cfg.n_experts, sd, &mut rng),
                experts: (0..cfg.n_experts).map(|_| ExpertWeights::randn(cfg, &mut rng)).collect(),
                shared: (0..cfg.n_shared).map(|_| ExpertWeights::randn(cfg, &mut rng)).collect(),
            })
            .collect();
        Weights {
            cfg: cfg.clone(),
            embed: Mat::randn(cfg.vocab, cfg.d_model, sd, &mut rng),
            final_norm: vec![1.0; cfg.d_model],
            layers,
        }
    }

    pub fn param_count(&self) -> usize {
        let mut n = self.embed.data.len() + self.final_norm.len();
        for l in &self.layers {
            n += l.attn_norm.len() + l.ffn_norm.len();
            n += l.wq.data.len() + l.wk.data.len() + l.wv.data.len() + l.wo.data.len();
            n += l.router.data.len();
            for e in l.experts.iter().chain(&l.shared) {
                n += e.param_count();
            }
        }
        n
    }

    /// Serialize into a TensorFile.
    pub fn to_tensor_file(&self) -> TensorFile {
        let mut tf = TensorFile::new();
        let c = &self.cfg;
        tf.put_u32(
            "config",
            vec![9],
            vec![
                c.n_layers as u32,
                c.d_model as u32,
                c.d_ff as u32,
                c.n_experts as u32,
                c.top_k as u32,
                c.n_shared as u32,
                c.n_heads as u32,
                c.vocab as u32,
                c.max_seq as u32,
            ],
        );
        tf.put_f32("embed", vec![c.vocab, c.d_model], self.embed.data.clone());
        tf.put_f32("final_norm", vec![c.d_model], self.final_norm.clone());
        for (i, l) in self.layers.iter().enumerate() {
            let p = format!("layer{i}");
            tf.put_f32(&format!("{p}.attn_norm"), vec![c.d_model], l.attn_norm.clone());
            tf.put_f32(&format!("{p}.ffn_norm"), vec![c.d_model], l.ffn_norm.clone());
            for (nm, m) in [("wq", &l.wq), ("wk", &l.wk), ("wv", &l.wv), ("wo", &l.wo)] {
                tf.put_f32(&format!("{p}.{nm}"), vec![m.rows, m.cols], m.data.clone());
            }
            tf.put_f32(&format!("{p}.router"), vec![c.d_model, c.n_experts], l.router.data.clone());
            for (e, ew) in l.experts.iter().enumerate() {
                let ep = format!("{p}.expert{e}");
                tf.put_f32(&format!("{ep}.w1"), vec![c.d_model, c.d_ff], ew.w1.data.clone());
                tf.put_f32(&format!("{ep}.w2"), vec![c.d_ff, c.d_model], ew.w2.data.clone());
                tf.put_f32(&format!("{ep}.w3"), vec![c.d_model, c.d_ff], ew.w3.data.clone());
            }
            for (s, ew) in l.shared.iter().enumerate() {
                let ep = format!("{p}.shared{s}");
                tf.put_f32(&format!("{ep}.w1"), vec![c.d_model, c.d_ff], ew.w1.data.clone());
                tf.put_f32(&format!("{ep}.w2"), vec![c.d_ff, c.d_model], ew.w2.data.clone());
                tf.put_f32(&format!("{ep}.w3"), vec![c.d_model, c.d_ff], ew.w3.data.clone());
            }
        }
        tf
    }

    /// Deserialize; `name` is stored in the returned config.
    pub fn from_tensor_file(tf: &TensorFile, name: &str) -> Result<Self> {
        let (_, c) = tf.get_u32("config")?;
        let cfg = ModelConfig {
            name: name.to_string(),
            n_layers: c[0] as usize,
            d_model: c[1] as usize,
            d_ff: c[2] as usize,
            n_experts: c[3] as usize,
            top_k: c[4] as usize,
            n_shared: c[5] as usize,
            n_heads: c[6] as usize,
            vocab: c[7] as usize,
            max_seq: c[8] as usize,
        };
        let mat = |nm: &str, r: usize, cc: usize| -> Result<Mat> {
            let (dims, d) = tf.get_f32(nm)?;
            anyhow::ensure!(dims == [r, cc], "{nm}: dims {dims:?} != [{r}, {cc}]");
            Ok(Mat::from_vec(r, cc, d.to_vec()))
        };
        let vecf = |nm: &str, n: usize| -> Result<Vec<f32>> {
            let (dims, d) = tf.get_f32(nm)?;
            anyhow::ensure!(dims == [n], "{nm}: bad dims {dims:?}");
            Ok(d.to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i}");
            let read_expert = |ep: &str| -> Result<ExpertWeights> {
                Ok(ExpertWeights {
                    w1: mat(&format!("{ep}.w1"), cfg.d_model, cfg.d_ff)?,
                    w2: mat(&format!("{ep}.w2"), cfg.d_ff, cfg.d_model)?,
                    w3: mat(&format!("{ep}.w3"), cfg.d_model, cfg.d_ff)?,
                })
            };
            layers.push(LayerWeights {
                attn_norm: vecf(&format!("{p}.attn_norm"), cfg.d_model)?,
                ffn_norm: vecf(&format!("{p}.ffn_norm"), cfg.d_model)?,
                wq: mat(&format!("{p}.wq"), cfg.d_model, cfg.d_model)?,
                wk: mat(&format!("{p}.wk"), cfg.d_model, cfg.d_model)?,
                wv: mat(&format!("{p}.wv"), cfg.d_model, cfg.d_model)?,
                wo: mat(&format!("{p}.wo"), cfg.d_model, cfg.d_model)?,
                router: mat(&format!("{p}.router"), cfg.d_model, cfg.n_experts)?,
                experts: (0..cfg.n_experts)
                    .map(|e| read_expert(&format!("{p}.expert{e}")))
                    .collect::<Result<_>>()?,
                shared: (0..cfg.n_shared)
                    .map(|s| read_expert(&format!("{p}.shared{s}")))
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Weights {
            embed: mat("embed", cfg.vocab, cfg.d_model)?,
            final_norm: vecf("final_norm", cfg.d_model)?,
            cfg,
            layers,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_tensor_file().save(path)
    }

    pub fn load(path: &Path, name: &str) -> Result<Self> {
        Self::from_tensor_file(&TensorFile::load(path)?, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ZooModel;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        }
    }

    #[test]
    fn init_matches_config_count() {
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 1);
        assert_eq!(w.param_count(), cfg.param_count());
    }

    #[test]
    fn tensor_file_roundtrip() {
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 7);
        let tf = w.to_tensor_file();
        let back = Weights::from_tensor_file(&tf, "tiny").unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back.embed, w.embed);
        assert_eq!(back.layers[1].router, w.layers[1].router);
        assert_eq!(back.layers[0].experts[3].w2, w.layers[0].experts[3].w2);
        assert_eq!(back.layers[1].shared[0].w1, w.layers[1].shared[0].w1);
    }

    #[test]
    fn zoo_configs_init() {
        // Smoke: all four zoo models initialize with consistent counts.
        for m in ZooModel::ALL {
            let cfg = m.config();
            let w = Weights::init(&cfg, 2);
            assert_eq!(w.param_count(), cfg.param_count(), "{}", cfg.name);
        }
    }
}
