//! Weight containers + initialization + binary IO.
//!
//! Every projection/expert matrix is a [`WeightMat`] — either `Dense` f32
//! or `Packed` sub-byte quantized storage ([`PackedMat`]) executed through
//! the fused dequant GEMM. This is what makes the served model's resident
//! memory match the paper's compression numbers: QESC emits `Packed`
//! experts and they stay packed through prefill/decode. The router (and
//! embeddings/norms) stay f32, per the paper (router is ~0.03% of params
//! and is the thing QESC calibrates).
//!
//! Layout matches `python/compile/pretrain.py`, which trains the miniature
//! models in JAX and saves them through the same `TensorFile` format
//! (see `util::binio` for the byte layout). Naming convention:
//!
//! ```text
//! embed                                (vocab, d_model)
//! final_norm                           (d_model,)
//! layer{i}.attn_norm / ffn_norm        (d_model,)
//! layer{i}.wq / wk / wv / wo           (d_model, d_model)
//! layer{i}.router                      (d_model, n_experts)
//! layer{i}.expert{e}.w1 / w3           (d_model, d_ff)
//! layer{i}.expert{e}.w2                (d_ff, d_model)
//! layer{i}.shared{s}.w1 / w2 / w3      same shapes
//! ```
//!
//! A `Dense` weight is one f32 entry under its plain name (unchanged from
//! the pre-quantized format, so Python-written checkpoints still load). A
//! `Packed` weight is four entries: `{name}.q.meta` (u32 `[bits,
//! group_size, rows, cols]`), `{name}.q.codes` (u8 packed bit-stream),
//! `{name}.q.scales` (f32 `(n_groups, cols)`) and `{name}.q.zeros`
//! (u8 `(n_groups, cols)` — zero-points are integers in `0..=qmax`).
//!
//! A layer whose experts were merged (`prune::merge`) adds two sidecar
//! entries — `layer{i}.remap` (u32 `[n_old]`, old expert id → merged id)
//! and `layer{i}.remap.meta` (u32 `[n_merged, reduce_code]`) — stores
//! only `n_merged` cluster bases under `layer{i}.expert{m}`, and stores
//! each absorbed expert's optional low-rank correction as six plain-f32
//! entries `layer{i}.delta{o}.w{1,2,3}.{u,v}`. The `config` entry keeps
//! the **original** expert count; the remap sidecar is what narrows the
//! routed width, so unmerged checkpoints are untouched byte-for-byte.

use super::config::ModelConfig;
use crate::quant::pack::PackedMat;
use crate::quant::quantizer::{GroupQuant, QuantConfig};
use crate::tensor::{Mat, Pcg64};
use crate::util::binio::{TensorFile, TensorSource};
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Polymorphic weight matrix: dense f32 or packed low-bit, with all
/// execution dispatched through [`WeightMat::matmul`].
#[derive(Clone, Debug, PartialEq)]
pub enum WeightMat {
    Dense(Mat),
    Packed(PackedMat),
}

impl WeightMat {
    pub fn rows(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.rows,
            WeightMat::Packed(p) => p.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.cols,
            WeightMat::Packed(p) => p.cols,
        }
    }

    /// Logical parameter count (independent of storage form).
    pub fn param_count(&self) -> usize {
        self.rows() * self.cols()
    }

    /// `x @ W`: dense GEMM or fused group-dequant GEMM, on the global pool.
    pub fn matmul(&self, x: &Mat) -> Mat {
        self.matmul_on(crate::tensor::ThreadPool::global(), x)
    }

    /// [`WeightMat::matmul`] on an explicit pool — the form the model's
    /// forward passes use, so `EngineConfig::threads` governs every GEMM.
    pub fn matmul_on(&self, pool: &crate::tensor::ThreadPool, x: &Mat) -> Mat {
        match self {
            WeightMat::Dense(m) => crate::tensor::matmul_on(pool, x, m),
            WeightMat::Packed(p) => crate::quant::fused::matmul_packed_on(pool, x, p),
        }
    }

    /// Actual resident bytes of this matrix (f32 data, or packed codes +
    /// scales + zeros).
    pub fn storage_bytes(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.data.len() * 4,
            WeightMat::Packed(p) => p.storage_bytes(),
        }
    }

    /// Effective code bit-width (32 for dense).
    pub fn bits(&self) -> u32 {
        match self {
            WeightMat::Dense(_) => 32,
            WeightMat::Packed(p) => p.cfg.bits,
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, WeightMat::Packed(_))
    }

    /// Materialize as dense f32 (calibration-time use: GPTQ reads the
    /// current weights through this; it is never on the serving path).
    pub fn to_dense(&self) -> Mat {
        match self {
            WeightMat::Dense(m) => m.clone(),
            WeightMat::Packed(p) => p.unpack().dequantize(),
        }
    }

    /// Pack a quantized matrix into its storage form.
    pub fn from_quant(gq: &GroupQuant) -> WeightMat {
        WeightMat::Packed(PackedMat::pack(gq))
    }

    /// GPTQ-quantize this matrix, borrowing the f32 data when it is
    /// already dense (the common calibration case) instead of cloning it.
    pub fn gptq_quantize(
        &self,
        hess: &crate::quant::gptq::Hessian,
        cfg: crate::quant::gptq::GptqConfig,
    ) -> GroupQuant {
        use crate::quant::gptq::gptq_quantize_mat;
        match self {
            WeightMat::Dense(m) => gptq_quantize_mat(m, hess, cfg),
            packed => gptq_quantize_mat(&packed.to_dense(), hess, cfg),
        }
    }

    /// Mean squared difference, materializing as needed (test/analysis
    /// helper).
    pub fn mse(&self, other: &WeightMat) -> f32 {
        self.to_dense().mse(&other.to_dense())
    }
}

impl From<Mat> for WeightMat {
    fn from(m: Mat) -> Self {
        WeightMat::Dense(m)
    }
}

/// One SwiGLU expert: out = (silu(x@w1) * (x@w3)) @ w2.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    pub w1: WeightMat, // (d_model, d_ff)
    pub w2: WeightMat, // (d_ff, d_model)
    pub w3: WeightMat, // (d_model, d_ff)
}

impl ExpertWeights {
    pub fn randn(cfg: &ModelConfig, rng: &mut Pcg64) -> Self {
        let s1 = (2.0 / cfg.d_model as f32).sqrt();
        let s2 = (2.0 / cfg.d_ff as f32).sqrt();
        ExpertWeights {
            w1: Mat::randn(cfg.d_model, cfg.d_ff, s1, rng).into(),
            w2: Mat::randn(cfg.d_ff, cfg.d_model, s2, rng).into(),
            w3: Mat::randn(cfg.d_model, cfg.d_ff, s1, rng).into(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.w1.param_count() + self.w2.param_count() + self.w3.param_count()
    }

    /// Resident bytes of the three matrices.
    pub fn storage_bytes(&self) -> usize {
        self.w1.storage_bytes() + self.w2.storage_bytes() + self.w3.storage_bytes()
    }

    /// All three matrices materialized dense and flattened into one vector
    /// (w1 ‖ w2 ‖ w3) — the representation expert-similarity analysis and
    /// the merge clustering compare with cosine. Calibration-time only.
    pub fn concat_dense(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.param_count());
        for w in [&self.w1, &self.w2, &self.w3] {
            v.extend(w.to_dense().data);
        }
        v
    }
}

/// How raw router logits of old expert ids that map to the same merged id
/// combine into the merged id's logit before softmax/top-k.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemapReduce {
    /// Merged logit = max over cluster members (default: a cluster is
    /// selected exactly when its strongest member would have been).
    Max,
    /// Merged logit = sum over cluster members.
    Sum,
}

impl RemapReduce {
    pub fn code(self) -> u32 {
        match self {
            RemapReduce::Max => 0,
            RemapReduce::Sum => 1,
        }
    }

    pub fn from_code(c: u32) -> Result<Self> {
        match c {
            0 => Ok(RemapReduce::Max),
            1 => Ok(RemapReduce::Sum),
            other => anyhow::bail!("remap reduce code {other} unknown (expected 0=max, 1=sum)"),
        }
    }
}

/// Per-layer router remap installed by `prune::merge::merge_experts`:
/// the router matrix keeps its original `n_old` columns, and this table
/// folds those logits down to `n_merged` cluster logits at forward time.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterRemap {
    /// `map[old_expert_id] = merged_id`, length = original expert count.
    pub map: Vec<u16>,
    /// Number of merged (cluster) experts; every `map` entry is below this.
    pub n_merged: usize,
    pub reduce: RemapReduce,
}

/// Low-rank correction for one absorbed expert: its original weights are
/// approximated as `base + u·v` per projection, so the forward pass
/// computes `x@(W + u·v) = x@W + (x@u)@v` exactly. Deltas are always
/// dense f32 (they are small — rank·(rows+cols) params — and packing
/// them would reintroduce the dequant error the delta exists to remove).
#[derive(Clone, Debug, PartialEq)]
pub struct ExpertDelta {
    pub u1: Mat, // (d_model, r1)
    pub v1: Mat, // (r1, d_ff)
    pub u2: Mat, // (d_ff, r2)
    pub v2: Mat, // (r2, d_model)
    pub u3: Mat, // (d_model, r3)
    pub v3: Mat, // (r3, d_ff)
}

impl ExpertDelta {
    pub fn param_count(&self) -> usize {
        [&self.u1, &self.v1, &self.u2, &self.v2, &self.u3, &self.v3]
            .iter()
            .map(|m| m.data.len())
            .sum()
    }

    /// Resident bytes (dense f32).
    pub fn storage_bytes(&self) -> usize {
        self.param_count() * 4
    }

    /// Largest of the three per-projection ranks.
    pub fn rank(&self) -> usize {
        self.u1.cols.max(self.u2.cols).max(self.u3.cols)
    }
}

/// One transformer layer.
///
/// Expert weights are held as `Arc<ExpertWeights>` **guard handles** and
/// the vectors are private: the forward pass no longer indexes a
/// materialized `Vec<ExpertWeights>` — it asks the model's
/// [`crate::model::store::ExpertStore`] for handles, which in `Tiered`
/// mode may load an expert from disk on demand. In that mode these
/// vectors are empty (only `shared` stays materialized — shared experts
/// run for every token, so tiering them would guarantee thrash) and the
/// store owns the single source of truth for routed experts.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub wq: WeightMat,
    pub wk: WeightMat,
    pub wv: WeightMat,
    pub wo: WeightMat,
    pub router: Mat, // (d_model, n_experts); stays f32 (paper Table 11)
    experts: Vec<Arc<ExpertWeights>>,
    shared: Vec<Arc<ExpertWeights>>,
    /// Installed by expert merging; `None` means the layer routes over its
    /// original experts and the merged forward path is never entered.
    remap: Option<RouterRemap>,
    /// Per-**old**-expert low-rank corrections (length = original expert
    /// count when a remap is installed and the deltas are resident; empty
    /// otherwise — in particular under a tiered store, where deltas are
    /// the eviction unit and live in the store, not here).
    deltas: Vec<Option<Arc<ExpertDelta>>>,
}

impl LayerWeights {
    /// Resident routed experts (empty under a tiered store).
    pub fn experts(&self) -> &[Arc<ExpertWeights>] {
        &self.experts
    }

    /// Shared (always-on) experts — resident in every store mode.
    pub fn shared(&self) -> &[Arc<ExpertWeights>] {
        &self.shared
    }

    /// Guard handle to one resident routed expert (cheap `Arc` clone).
    pub fn expert_arc(&self, e: usize) -> Arc<ExpertWeights> {
        debug_assert!(e < self.experts.len(), "expert {e} out of {}", self.experts.len());
        self.experts[e].clone()
    }

    /// Mutable access for the calibration pipeline (GPTQ writes packed
    /// forms in place). Copy-on-write: if a forward pass still holds a
    /// guard handle to this expert, the mutation clones instead of racing.
    pub fn expert_mut(&mut self, e: usize) -> &mut ExpertWeights {
        debug_assert!(e < self.experts.len(), "expert {e} out of {}", self.experts.len());
        Arc::make_mut(&mut self.experts[e])
    }

    /// Mutable access to one shared expert (same CoW semantics).
    pub fn shared_expert_mut(&mut self, s: usize) -> &mut ExpertWeights {
        debug_assert!(s < self.shared.len(), "shared expert {s} out of {}", self.shared.len());
        Arc::make_mut(&mut self.shared[s])
    }

    /// Replace the shared-expert set (tests/ablations).
    pub fn set_shared(&mut self, shared: Vec<ExpertWeights>) {
        self.shared = shared.into_iter().map(Arc::new).collect();
    }

    /// The router remap installed by expert merging, if any.
    pub fn remap(&self) -> Option<&RouterRemap> {
        self.remap.as_ref()
    }

    /// Width of the routed expert set this layer actually dispatches over:
    /// `n_merged` after merging, else the router's column count. This is
    /// the width selection records, PESF masks and `MoeLayerOut` use.
    pub fn n_routed(&self) -> usize {
        match &self.remap {
            Some(rm) => rm.n_merged,
            None => self.router.cols,
        }
    }

    /// Resident per-old-expert merge deltas (empty when unmerged or when
    /// a tiered store owns the deltas).
    pub fn deltas(&self) -> &[Option<Arc<ExpertDelta>>] {
        &self.deltas
    }

    /// Guard handle to the resident delta for old expert `o`, if one
    /// exists (cheap `Arc` clone; `None` for exact-by-base members, out of
    /// range ids, and tiered skeletons).
    pub fn delta_arc(&self, o: usize) -> Option<Arc<ExpertDelta>> {
        self.deltas.get(o).and_then(|d| d.clone())
    }

    /// Install a merge: replace the routed expert set with `bases`
    /// (indexed by merged id), record `deltas` (indexed by old id) and the
    /// remap table. The router matrix is left untouched — logits are
    /// reduced at forward time, so the transform is reversible in spirit
    /// and serialization keeps the original gate.
    pub fn install_merge(
        &mut self,
        remap: RouterRemap,
        bases: Vec<Arc<ExpertWeights>>,
        deltas: Vec<Option<ExpertDelta>>,
    ) {
        assert_eq!(remap.map.len(), self.router.cols, "remap width != router width");
        assert_eq!(bases.len(), remap.n_merged, "one base per merged id");
        assert_eq!(deltas.len(), remap.map.len(), "one delta slot per old id");
        assert!(
            remap.map.iter().all(|&m| (m as usize) < remap.n_merged),
            "remap target out of range"
        );
        self.experts = bases;
        self.deltas = deltas.into_iter().map(|d| d.map(Arc::new)).collect();
        self.remap = Some(remap);
    }
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub embed: Mat, // (vocab, d_model); output head is tied (embed^T)
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl Weights {
    /// Random initialization (used in tests and before pretraining).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 100);
        let sd = (1.0 / cfg.d_model as f32).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; cfg.d_model],
                ffn_norm: vec![1.0; cfg.d_model],
                wq: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng).into(),
                wk: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng).into(),
                wv: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng).into(),
                wo: Mat::randn(cfg.d_model, cfg.d_model, sd, &mut rng).into(),
                router: Mat::randn(cfg.d_model, cfg.n_experts, sd, &mut rng),
                experts: (0..cfg.n_experts)
                    .map(|_| Arc::new(ExpertWeights::randn(cfg, &mut rng)))
                    .collect(),
                shared: (0..cfg.n_shared)
                    .map(|_| Arc::new(ExpertWeights::randn(cfg, &mut rng)))
                    .collect(),
                remap: None,
                deltas: Vec::new(),
            })
            .collect();
        Weights {
            cfg: cfg.clone(),
            embed: Mat::randn(cfg.vocab, cfg.d_model, sd, &mut rng),
            final_norm: vec![1.0; cfg.d_model],
            layers,
        }
    }

    pub fn param_count(&self) -> usize {
        let mut n = self.embed.data.len() + self.final_norm.len();
        for l in &self.layers {
            n += l.attn_norm.len() + l.ffn_norm.len();
            n += l.wq.param_count() + l.wk.param_count() + l.wv.param_count() + l.wo.param_count();
            n += l.router.data.len();
            for e in l.experts.iter().chain(&l.shared) {
                n += e.param_count();
            }
            for d in l.deltas.iter().flatten() {
                n += d.param_count();
            }
        }
        n
    }

    /// True resident bytes of the model as served: f32 for embeddings,
    /// norms and routers, plus each [`WeightMat`]'s actual storage. For an
    /// all-dense model this equals `param_count() * 4`; after QESC it is
    /// the real compressed footprint (codes + scales + zeros).
    pub fn storage_bytes(&self) -> usize {
        let mut n = (self.embed.data.len() + self.final_norm.len()) * 4;
        for l in &self.layers {
            n += (l.attn_norm.len() + l.ffn_norm.len() + l.router.data.len()) * 4;
            n += l.wq.storage_bytes()
                + l.wk.storage_bytes()
                + l.wv.storage_bytes()
                + l.wo.storage_bytes();
            for e in l.experts.iter().chain(&l.shared) {
                n += e.storage_bytes();
            }
            for d in l.deltas.iter().flatten() {
                n += d.storage_bytes();
            }
        }
        n
    }

    /// Resident bytes of routed + shared expert weights only (the paper's
    /// headline memory axis), including any resident merge deltas.
    pub fn expert_storage_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.experts
                    .iter()
                    .chain(&l.shared)
                    .map(|e| e.storage_bytes())
                    .sum::<usize>()
                    + l.deltas.iter().flatten().map(|d| d.storage_bytes()).sum::<usize>()
            })
            .sum()
    }

    /// Resident bytes of **routed** experts only — the set a tiered
    /// [`crate::model::store::ExpertStore`] manages (shared experts are
    /// always-on and stay pinned outside the budget). For merged layers
    /// this counts cluster bases **and** per-old-expert deltas: it is the
    /// full routed footprint the "total" of every budget fraction and
    /// store stat is measured against. Use
    /// [`Weights::expert_storage_bytes`] when shared experts should count.
    pub fn routed_expert_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.experts.iter().map(|e| e.storage_bytes()).sum::<usize>()
                    + l.deltas.iter().flatten().map(|d| d.storage_bytes()).sum::<usize>()
            })
            .sum()
    }

    /// Storage bytes of the largest single **tierable unit** — the
    /// smallest feasible byte budget for a tiered
    /// [`crate::model::store::ExpertStore`] over these weights. For an
    /// unmerged layer the unit is a routed expert; for a merged layer the
    /// cluster bases stay resident and only per-old-expert deltas tier,
    /// so the unit is a delta (0 if the layer has none).
    pub fn max_expert_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                if l.remap.is_some() {
                    l.deltas.iter().flatten().map(|d| d.storage_bytes()).max().unwrap_or(0)
                } else {
                    l.experts.iter().map(|e| e.storage_bytes()).max().unwrap_or(0)
                }
            })
            .max()
            .unwrap_or(0)
    }

    /// RTN-quantize + pack every routed/shared expert in place (uncalibrated
    /// helper for benches/tests; QESC is the calibrated path).
    pub fn pack_experts_rtn(&mut self, bits: u32, group_size: usize) {
        for l in &mut self.layers {
            for e in l.experts.iter_mut().chain(l.shared.iter_mut()) {
                let e = Arc::make_mut(e);
                for w in [&mut e.w1, &mut e.w2, &mut e.w3] {
                    let gs = if group_size == 0 { 0 } else { group_size.min(w.rows()) };
                    let gq = GroupQuant::quantize(&w.to_dense(), QuantConfig::new(bits, gs));
                    *w = WeightMat::from_quant(&gq);
                }
            }
        }
    }

    /// Serialize into a TensorFile.
    pub fn to_tensor_file(&self) -> TensorFile {
        let mut tf = TensorFile::new();
        let c = &self.cfg;
        tf.put_u32(
            "config",
            vec![9],
            vec![
                c.n_layers as u32,
                c.d_model as u32,
                c.d_ff as u32,
                c.n_experts as u32,
                c.top_k as u32,
                c.n_shared as u32,
                c.n_heads as u32,
                c.vocab as u32,
                c.max_seq as u32,
            ],
        );
        tf.put_f32("embed", vec![c.vocab, c.d_model], self.embed.data.clone());
        tf.put_f32("final_norm", vec![c.d_model], self.final_norm.clone());
        for (i, l) in self.layers.iter().enumerate() {
            let p = format!("layer{i}");
            tf.put_f32(&format!("{p}.attn_norm"), vec![c.d_model], l.attn_norm.clone());
            tf.put_f32(&format!("{p}.ffn_norm"), vec![c.d_model], l.ffn_norm.clone());
            for (nm, m) in [("wq", &l.wq), ("wk", &l.wk), ("wv", &l.wv), ("wo", &l.wo)] {
                put_weight(&mut tf, &format!("{p}.{nm}"), m);
            }
            tf.put_f32(&format!("{p}.router"), vec![c.d_model, c.n_experts], l.router.data.clone());
            if let Some(rm) = &l.remap {
                tf.put_u32(
                    &format!("{p}.remap"),
                    vec![rm.map.len()],
                    rm.map.iter().map(|&m| m as u32).collect(),
                );
                tf.put_u32(
                    &format!("{p}.remap.meta"),
                    vec![2],
                    vec![rm.n_merged as u32, rm.reduce.code()],
                );
            }
            for (o, d) in l.deltas.iter().enumerate() {
                if let Some(d) = d {
                    put_delta(&mut tf, &format!("{p}.delta{o}"), d);
                }
            }
            for (e, ew) in l.experts.iter().enumerate() {
                let ep = format!("{p}.expert{e}");
                put_weight(&mut tf, &format!("{ep}.w1"), &ew.w1);
                put_weight(&mut tf, &format!("{ep}.w2"), &ew.w2);
                put_weight(&mut tf, &format!("{ep}.w3"), &ew.w3);
            }
            for (s, ew) in l.shared.iter().enumerate() {
                let ep = format!("{p}.shared{s}");
                put_weight(&mut tf, &format!("{ep}.w1"), &ew.w1);
                put_weight(&mut tf, &format!("{ep}.w2"), &ew.w2);
                put_weight(&mut tf, &format!("{ep}.w3"), &ew.w3);
            }
        }
        tf
    }

    /// Deserialize; `name` is stored in the returned config.
    pub fn from_tensor_file(tf: &TensorFile, name: &str) -> Result<Self> {
        Self::from_source(tf, name, true)
    }

    /// Deserialize from any [`TensorSource`] (a fully resident
    /// [`TensorFile`] or an indexed on-disk reader). With `load_experts =
    /// false`, routed expert tensors are **skipped** and the returned
    /// weights hold empty expert vectors — the skeleton a tiered
    /// [`crate::model::store::ExpertStore`] wraps, loading experts by byte
    /// range on demand. Shared experts are always loaded (they run for
    /// every token and stay resident in every store mode).
    pub fn from_source<S: TensorSource>(src: &S, name: &str, load_experts: bool) -> Result<Self> {
        let (_, c) = src.fetch_u32("config")?;
        anyhow::ensure!(c.len() == 9, "config: expected 9 fields, got {}", c.len());
        let cfg = ModelConfig {
            name: name.to_string(),
            n_layers: c[0] as usize,
            d_model: c[1] as usize,
            d_ff: c[2] as usize,
            n_experts: c[3] as usize,
            top_k: c[4] as usize,
            n_shared: c[5] as usize,
            n_heads: c[6] as usize,
            vocab: c[7] as usize,
            max_seq: c[8] as usize,
        };
        let mat = |nm: &str, r: usize, cc: usize| -> Result<Mat> {
            let (dims, d) = src.fetch_f32(nm)?;
            anyhow::ensure!(dims == [r, cc], "{nm}: dims {dims:?} != [{r}, {cc}]");
            Ok(Mat::from_vec(r, cc, d))
        };
        let vecf = |nm: &str, n: usize| -> Result<Vec<f32>> {
            let (dims, d) = src.fetch_f32(nm)?;
            anyhow::ensure!(dims == [n], "{nm}: bad dims {dims:?}");
            Ok(d)
        };
        let weight = |nm: &str, r: usize, cc: usize| -> Result<WeightMat> {
            get_weight(src, nm, r, cc)
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i}");
            let remap = read_remap(src, &p, cfg.n_experts)?;
            let n_routed = remap.as_ref().map_or(cfg.n_experts, |rm| rm.n_merged);
            // Merged layers keep their cluster bases resident in every
            // store mode (only deltas tier), so bases load even for the
            // tiered skeleton; unmerged routed experts are skipped there.
            let experts = if load_experts || remap.is_some() {
                (0..n_routed)
                    .map(|e| -> Result<Arc<ExpertWeights>> {
                        Ok(Arc::new(read_expert_from(src, &format!("{p}.expert{e}"), &cfg)?))
                    })
                    .collect::<Result<_>>()?
            } else {
                Vec::new()
            };
            let deltas = if remap.is_some() && load_experts {
                (0..cfg.n_experts)
                    .map(|o| -> Result<Option<Arc<ExpertDelta>>> {
                        let dp = format!("{p}.delta{o}");
                        if src.contains(&format!("{dp}.w1.u")) {
                            Ok(Some(Arc::new(read_delta_from(src, &dp, &cfg)?)))
                        } else {
                            Ok(None)
                        }
                    })
                    .collect::<Result<_>>()?
            } else {
                Vec::new()
            };
            layers.push(LayerWeights {
                attn_norm: vecf(&format!("{p}.attn_norm"), cfg.d_model)?,
                ffn_norm: vecf(&format!("{p}.ffn_norm"), cfg.d_model)?,
                wq: weight(&format!("{p}.wq"), cfg.d_model, cfg.d_model)?,
                wk: weight(&format!("{p}.wk"), cfg.d_model, cfg.d_model)?,
                wv: weight(&format!("{p}.wv"), cfg.d_model, cfg.d_model)?,
                wo: weight(&format!("{p}.wo"), cfg.d_model, cfg.d_model)?,
                router: mat(&format!("{p}.router"), cfg.d_model, cfg.n_experts)?,
                experts,
                shared: (0..cfg.n_shared)
                    .map(|s| -> Result<Arc<ExpertWeights>> {
                        Ok(Arc::new(read_expert_from(src, &format!("{p}.shared{s}"), &cfg)?))
                    })
                    .collect::<Result<_>>()?,
                remap,
                deltas,
            });
        }
        Ok(Weights {
            embed: mat("embed", cfg.vocab, cfg.d_model)?,
            final_norm: vecf("final_norm", cfg.d_model)?,
            cfg,
            layers,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_tensor_file().save(path)
    }

    pub fn load(path: &Path, name: &str) -> Result<Self> {
        Self::from_tensor_file(&TensorFile::load(path)?, name)
    }
}

/// Write one [`WeightMat`]: dense as a plain f32 entry, packed as the
/// `.q.meta/.q.codes/.q.scales/.q.zeros` quartet.
fn put_weight(tf: &mut TensorFile, name: &str, w: &WeightMat) {
    match w {
        WeightMat::Dense(m) => tf.put_f32(name, vec![m.rows, m.cols], m.data.clone()),
        WeightMat::Packed(p) => {
            tf.put_u32(
                &format!("{name}.q.meta"),
                vec![4],
                vec![p.cfg.bits, p.cfg.group_size as u32, p.rows as u32, p.cols as u32],
            );
            tf.put_u8(&format!("{name}.q.codes"), vec![p.packed.len()], p.packed.clone());
            let ng = p.cfg.n_groups(p.rows);
            tf.put_f32(&format!("{name}.q.scales"), vec![ng, p.cols], p.scales.clone());
            tf.put_u8(&format!("{name}.q.zeros"), vec![ng, p.cols], p.zeros.clone());
        }
    }
}

/// Read one [`WeightMat`] from any [`TensorSource`], detecting packed
/// storage by the presence of the `.q.meta` entry; otherwise falls back to
/// the legacy plain-f32 layout. A `.q.meta` entry whose sidecar tensors
/// (`.q.codes/.q.scales/.q.zeros`) are absent or malformed is a contextful
/// error naming the missing tensor — never a panic or silent garbage.
pub(crate) fn get_weight<S: TensorSource>(
    src: &S,
    name: &str,
    rows: usize,
    cols: usize,
) -> Result<WeightMat> {
    let meta_name = format!("{name}.q.meta");
    if !src.contains(&meta_name) {
        let (dims, d) = src.fetch_f32(name)?;
        anyhow::ensure!(dims == [rows, cols], "{name}: dims {dims:?} != [{rows}, {cols}]");
        return Ok(WeightMat::Dense(Mat::from_vec(rows, cols, d)));
    }
    let (mdims, meta) = src.fetch_u32(&meta_name)?;
    anyhow::ensure!(mdims == [4], "{meta_name}: bad dims {mdims:?}");
    let bits = meta[0];
    let group_size = meta[1] as usize;
    anyhow::ensure!((2..=8).contains(&bits), "{name}: unsupported bit-width {bits}");
    anyhow::ensure!(
        meta[2] as usize == rows && meta[3] as usize == cols,
        "{name}: packed shape {}x{} != expected {rows}x{cols}",
        meta[2],
        meta[3]
    );
    let cfg = QuantConfig::new(bits, group_size);
    let (_, codes) = src.fetch_u8(&format!("{name}.q.codes"))?;
    let want = PackedMat::col_bytes(rows, bits) * cols;
    anyhow::ensure!(codes.len() == want, "{name}.q.codes: {} bytes != {want}", codes.len());
    let ng = cfg.n_groups(rows);
    let (sdims, scales) = src.fetch_f32(&format!("{name}.q.scales"))?;
    anyhow::ensure!(sdims == [ng, cols], "{name}.q.scales: bad dims {sdims:?}");
    let (zdims, zeros) = src.fetch_u8(&format!("{name}.q.zeros"))?;
    anyhow::ensure!(zdims == [ng, cols], "{name}.q.zeros: bad dims {zdims:?}");
    Ok(WeightMat::Packed(PackedMat { cfg, rows, cols, packed: codes, scales, zeros }))
}

/// Read one expert (w1/w2/w3, dense or packed) from a [`TensorSource`] by
/// its tensor-name prefix (`layer{i}.expert{e}` / `layer{i}.shared{s}`).
/// This is the tiered store's on-demand load path and the eager loader's
/// shared implementation — one decode path, so a disk-loaded expert is
/// byte-for-byte the expert the eager path would have built.
pub(crate) fn read_expert_from<S: TensorSource>(
    src: &S,
    prefix: &str,
    cfg: &ModelConfig,
) -> Result<ExpertWeights> {
    Ok(ExpertWeights {
        w1: get_weight(src, &format!("{prefix}.w1"), cfg.d_model, cfg.d_ff)?,
        w2: get_weight(src, &format!("{prefix}.w2"), cfg.d_ff, cfg.d_model)?,
        w3: get_weight(src, &format!("{prefix}.w3"), cfg.d_model, cfg.d_ff)?,
    })
}

/// Write one [`ExpertDelta`] as six plain-f32 entries under `prefix`.
fn put_delta(tf: &mut TensorFile, prefix: &str, d: &ExpertDelta) {
    for (nm, m) in [
        ("w1.u", &d.u1),
        ("w1.v", &d.v1),
        ("w2.u", &d.u2),
        ("w2.v", &d.v2),
        ("w3.u", &d.u3),
        ("w3.v", &d.v3),
    ] {
        tf.put_f32(&format!("{prefix}.{nm}"), vec![m.rows, m.cols], m.data.clone());
    }
}

/// Read one merge delta (`layer{i}.delta{o}`) from a [`TensorSource`].
/// Like [`read_expert_from`], this is both the eager loader and the
/// tiered store's on-demand path — one decode path, bit-identical loads.
pub(crate) fn read_delta_from<S: TensorSource>(
    src: &S,
    prefix: &str,
    cfg: &ModelConfig,
) -> Result<ExpertDelta> {
    let pair = |nm: &str, urows: usize, vcols: usize| -> Result<(Mat, Mat)> {
        let (ud, u) = src.fetch_f32(&format!("{prefix}.{nm}.u"))?;
        anyhow::ensure!(
            ud.len() == 2 && ud[0] == urows,
            "{prefix}.{nm}.u: dims {ud:?} incompatible with {urows} rows"
        );
        let r = ud[1];
        let (vd, v) = src.fetch_f32(&format!("{prefix}.{nm}.v"))?;
        anyhow::ensure!(
            vd == [r, vcols],
            "{prefix}.{nm}.v: dims {vd:?} != [{r}, {vcols}] (rank mismatch with .u)"
        );
        Ok((Mat::from_vec(urows, r, u), Mat::from_vec(r, vcols, v)))
    };
    let (u1, v1) = pair("w1", cfg.d_model, cfg.d_ff)?;
    let (u2, v2) = pair("w2", cfg.d_ff, cfg.d_model)?;
    let (u3, v3) = pair("w3", cfg.d_model, cfg.d_ff)?;
    Ok(ExpertDelta { u1, v1, u2, v2, u3, v3 })
}

/// Read the optional router remap sidecar for one layer prefix. Returns
/// `Ok(None)` when the layer is unmerged (no `.remap` entry).
fn read_remap<S: TensorSource>(
    src: &S,
    layer_prefix: &str,
    n_old: usize,
) -> Result<Option<RouterRemap>> {
    let name = format!("{layer_prefix}.remap");
    if !src.contains(&name) {
        return Ok(None);
    }
    let (dims, raw) = src.fetch_u32(&name)?;
    anyhow::ensure!(dims == [n_old], "{name}: dims {dims:?} != [{n_old}]");
    let (mdims, meta) = src.fetch_u32(&format!("{name}.meta"))?;
    anyhow::ensure!(mdims == [2], "{name}.meta: bad dims {mdims:?}");
    let n_merged = meta[0] as usize;
    anyhow::ensure!(
        n_merged >= 1 && n_merged <= n_old,
        "{name}.meta: n_merged {n_merged} outside 1..={n_old}"
    );
    let map = raw
        .iter()
        .map(|&m| -> Result<u16> {
            anyhow::ensure!((m as usize) < n_merged, "{name}: target {m} >= n_merged {n_merged}");
            Ok(m as u16)
        })
        .collect::<Result<Vec<u16>>>()?;
    Ok(Some(RouterRemap { map, n_merged, reduce: RemapReduce::from_code(meta[1])? }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ZooModel;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        }
    }

    #[test]
    fn init_matches_config_count() {
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 1);
        assert_eq!(w.param_count(), cfg.param_count());
    }

    #[test]
    fn tensor_file_roundtrip() {
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 7);
        let tf = w.to_tensor_file();
        let back = Weights::from_tensor_file(&tf, "tiny").unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back.embed, w.embed);
        assert_eq!(back.layers[1].router, w.layers[1].router);
        assert_eq!(back.layers[0].experts[3].w2, w.layers[0].experts[3].w2);
        assert_eq!(back.layers[1].shared[0].w1, w.layers[1].shared[0].w1);
    }

    #[test]
    fn tensor_file_roundtrip_packed() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 8);
        w.pack_experts_rtn(4, 16);
        let tf = w.to_tensor_file();
        let back = Weights::from_tensor_file(&tf, "tiny").unwrap();
        // Packed payloads survive byte-for-byte and storage accounting agrees.
        assert_eq!(back.layers[0].experts[0].w1, w.layers[0].experts[0].w1);
        assert_eq!(back.layers[1].shared[0].w2, w.layers[1].shared[0].w2);
        assert_eq!(back.storage_bytes(), w.storage_bytes());
        assert!(back.layers[0].experts[0].w1.is_packed());
        // Attention stays dense through the same roundtrip.
        assert!(!back.layers[0].wq.is_packed());
    }

    #[test]
    fn zoo_configs_init() {
        // Smoke: all four zoo models initialize with consistent counts.
        for m in ZooModel::ALL {
            let cfg = m.config();
            let w = Weights::init(&cfg, 2);
            assert_eq!(w.param_count(), cfg.param_count(), "{}", cfg.name);
        }
    }

    /// Acceptance: a packed 4-bit model reports resident expert bytes of
    /// roughly bits/8 × params (+ scale/zero overhead), not the f32 size.
    #[test]
    fn packed_expert_storage_is_real() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 9);
        let expert_params = cfg.expert_param_count();
        assert_eq!(w.storage_bytes(), w.param_count() * 4);
        assert_eq!(w.expert_storage_bytes(), expert_params * 4);
        w.pack_experts_rtn(4, 16);
        // Parameters are unchanged; only the storage form shrank.
        assert_eq!(w.param_count(), cfg.param_count());
        let packed = w.expert_storage_bytes();
        // Codes alone are bits/8 per param; scales+zeros add 5 bytes per
        // 16-row group. Must be far below f32 and at least the code floor.
        let code_floor = expert_params / 2; // 4 bits = 0.5 B/param
        assert!(packed >= code_floor, "packed={packed} floor={code_floor}");
        // One byte per param bounds codes+overhead from above here (= f32/4).
        assert!(packed < expert_params, "packed={packed} not < {expert_params}");
        // Non-expert tensors are still f32.
        let non_expert = w.storage_bytes() - packed;
        assert_eq!(non_expert, (w.param_count() - expert_params) * 4);
    }

    /// A merged layer's remap table, cluster bases and low-rank deltas
    /// survive a TensorFile roundtrip byte-for-byte, and the skeleton
    /// loader (`load_experts = false`) still materializes the bases while
    /// leaving the deltas to the tiered store.
    #[test]
    fn tensor_file_roundtrip_merged() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 11);
        let mut rng = Pcg64::seeded(12);
        // Merge experts {0,1} and {2,3} of layer 0 into two bases, with a
        // rank-1 delta on old ids 1 and 3.
        let bases =
            vec![w.layers[0].expert_arc(0), w.layers[0].expert_arc(2)];
        let mk_delta = |rng: &mut Pcg64| ExpertDelta {
            u1: Mat::randn(cfg.d_model, 1, 1.0, rng),
            v1: Mat::randn(1, cfg.d_ff, 1.0, rng),
            u2: Mat::randn(cfg.d_ff, 1, 1.0, rng),
            v2: Mat::randn(1, cfg.d_model, 1.0, rng),
            u3: Mat::randn(cfg.d_model, 1, 1.0, rng),
            v3: Mat::randn(1, cfg.d_ff, 1.0, rng),
        };
        let deltas = vec![None, Some(mk_delta(&mut rng)), None, Some(mk_delta(&mut rng))];
        let remap =
            RouterRemap { map: vec![0, 0, 1, 1], n_merged: 2, reduce: RemapReduce::Max };
        w.layers[0].install_merge(remap.clone(), bases, deltas);
        assert_eq!(w.layers[0].n_routed(), 2);
        assert_eq!(w.layers[1].n_routed(), cfg.n_experts);

        let tf = w.to_tensor_file();
        let back = Weights::from_tensor_file(&tf, "tiny").unwrap();
        assert_eq!(back.layers[0].remap(), Some(&remap));
        assert_eq!(back.layers[0].experts().len(), 2);
        assert_eq!(back.layers[0].experts()[1].w1, w.layers[0].experts()[1].w1);
        assert_eq!(back.layers[0].deltas().len(), cfg.n_experts);
        assert!(back.layers[0].deltas()[0].is_none());
        assert_eq!(
            back.layers[0].delta_arc(3).unwrap().u2,
            w.layers[0].delta_arc(3).unwrap().u2
        );
        assert!(back.layers[1].remap().is_none());
        assert_eq!(back.routed_expert_bytes(), w.routed_expert_bytes());
        // max_expert_bytes for layer 0 is now the largest delta, which is
        // far smaller than a full expert (layer 1's unit).
        let delta_bytes = w.layers[0].delta_arc(1).unwrap().storage_bytes();
        let expert_bytes = w.layers[1].experts()[0].storage_bytes();
        assert!(delta_bytes < expert_bytes);
        assert_eq!(w.max_expert_bytes(), expert_bytes);

        // Skeleton load: bases resident for the merged layer, routed
        // experts dropped for the unmerged one, deltas left to the store.
        let skel = Weights::from_source(&tf, "tiny", false).unwrap();
        assert_eq!(skel.layers[0].experts().len(), 2);
        assert!(skel.layers[0].deltas().is_empty());
        assert!(skel.layers[1].experts().is_empty());
    }

    /// Packed and dense forms compute the same product through the
    /// WeightMat dispatch (the dequantized values, exactly).
    #[test]
    fn weightmat_dispatch_consistent() {
        let mut rng = Pcg64::seeded(17);
        let m = Mat::randn(24, 12, 1.0, &mut rng);
        let x = Mat::randn(3, 24, 1.0, &mut rng);
        let gq = GroupQuant::quantize(&m, QuantConfig::new(4, 8));
        let packed = WeightMat::from_quant(&gq);
        let dense_of_packed = WeightMat::Dense(packed.to_dense());
        let a = packed.matmul(&x);
        let b = dense_of_packed.matmul(&x);
        for (u, v) in a.data.iter().zip(&b.data) {
            assert!((u - v).abs() <= 1e-5, "{u} vs {v}");
        }
        assert_eq!(packed.rows(), 24);
        assert_eq!(packed.cols(), 12);
        assert_eq!(packed.bits(), 4);
        // Group size 8 carries heavy scale/zero overhead (5 B per 8-row
        // group/column), so the bound here is /3, not the asymptotic /8.
        assert!(packed.storage_bytes() < dense_of_packed.storage_bytes() / 3);
    }
}
