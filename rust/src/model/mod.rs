//! Native MoE transformer: model-zoo configs, weight containers with binary
//! IO shared with the Python pretraining path, and a forward pass with the
//! hooks the compression pipeline needs (expert-selection recording, forced
//! selection for the Table-1 expert-shift experiment, per-layer activation
//! capture for GPTQ).

pub mod config;
pub mod forward;
pub mod hooks;
pub mod store;
pub mod weights;

pub use config::{ModelConfig, ZooModel};
pub use forward::{expert_forward, expert_forward_on, KvCache, KvPrecision, Model, MoeLayerOut};
pub use hooks::{FilterDropStats, ForcedSelections, Hooks, SelectionRecord, SeqExpertMask};
pub use store::{ExpertStore, ExpertStoreStats, TieredStore};
pub use weights::{
    ExpertDelta, ExpertWeights, LayerWeights, RemapReduce, RouterRemap, WeightMat, Weights,
};
