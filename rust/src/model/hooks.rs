//! Forward-pass hooks used by the compression pipeline and the paper's
//! analysis experiments:
//!
//! * [`SelectionRecord`] — record which experts the router selected for each
//!   token (ES-frequency analysis, Fig 2/10/11/13; PESF statistics).
//! * [`ForcedSelections`] — override the router's selection with a recorded
//!   one (the Table-1 "quantized but without expert-shift" 2×2 experiment).
//! * activation capture — stash per-layer MHSA/expert inputs for GPTQ's
//!   Hessian accumulation and router-calibration targets.

use crate::tensor::Mat;
use std::cell::RefCell;
use std::sync::Arc;

/// One sequence's `layer × expert` prune mask (true = skip the expert),
/// shared between the engine's per-sequence PESF state and the per-step
/// decode hooks without copying.
pub type SeqExpertMask = Arc<Vec<Vec<bool>>>;

/// One token's routing decision in one layer.
#[derive(Clone, Debug, PartialEq)]
pub struct TokenSelection {
    /// Chosen expert ids (descending score order), length top_k.
    pub experts: Vec<u16>,
    /// Softmax scores of the chosen experts (same order, unnormalized by
    /// the top-k renormalization).
    pub scores: Vec<f32>,
}

/// All routing decisions for a forward pass: `records[layer][token]`.
#[derive(Clone, Debug, Default)]
pub struct SelectionRecord {
    pub layers: Vec<Vec<TokenSelection>>,
}

impl SelectionRecord {
    pub fn with_layers(n: usize) -> Self {
        SelectionRecord { layers: vec![Vec::new(); n] }
    }

    /// Per-expert selection counts for one layer.
    pub fn counts(&self, layer: usize, n_experts: usize) -> Vec<u64> {
        debug_assert!(layer < self.layers.len(), "layer {layer} out of {}", self.layers.len());
        let mut c = vec![0u64; n_experts];
        for t in &self.layers[layer] {
            for &e in &t.experts {
                c[e as usize] += 1;
            }
        }
        c
    }

    /// Normalized selection frequency P(m, d) for one layer (paper Eq. 3).
    pub fn frequency(&self, layer: usize, n_experts: usize) -> Vec<f32> {
        let c = self.counts(layer, n_experts);
        let total: u64 = c.iter().sum();
        if total == 0 {
            return vec![0.0; n_experts];
        }
        c.iter().map(|&x| x as f32 / total as f32).collect()
    }

    /// All layers' frequencies flattened into one vector P(d) (Eq. 3/4).
    pub fn flat_frequency(&self, n_experts: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.layers.len() * n_experts);
        for l in 0..self.layers.len() {
            out.extend(self.frequency(l, n_experts));
        }
        out
    }

    pub fn n_tokens(&self, layer: usize) -> usize {
        self.layers[layer].len()
    }

    /// One token's selections across all layers: `out[layer]` = experts
    /// chosen for token `t` in that layer. Used by the engine to feed a
    /// decode step's routing into the per-sequence PESF rolling window
    /// (in a batched decode record, token index == batch row).
    pub fn token_experts(&self, t: usize) -> Vec<Vec<u16>> {
        debug_assert!(self.layers.iter().all(|l| t < l.len()), "token {t} missing from a layer record");
        self.layers.iter().map(|l| l[t].experts.clone()).collect()
    }
}

/// Running count of expert slots dropped by [`Hooks::selection_filter`]
/// (see [`Hooks::filter_drops`]): `dropped / seen` is the fraction of
/// router-selected expert executions the filter actually skipped.
#[derive(Clone, Copy, Debug, Default)]
pub struct FilterDropStats {
    /// Expert slots selected by the router before filtering.
    pub seen: u64,
    /// Expert slots the filter removed.
    pub dropped: u64,
}

impl FilterDropStats {
    pub fn rate(&self) -> f32 {
        if self.seen == 0 {
            0.0
        } else {
            self.dropped as f32 / self.seen as f32
        }
    }
}

/// Forced routing: replay `records[layer][token]` instead of computing
/// the router's own top-k. Built from a [`SelectionRecord`].
#[derive(Clone, Debug)]
pub struct ForcedSelections {
    pub record: SelectionRecord,
}

/// What to capture during a forward pass. All fields are optional; the
/// default captures nothing and adds no overhead.
#[derive(Default)]
pub struct Hooks {
    /// If set, fill with routing decisions per layer.
    pub record_selections: Option<RefCell<SelectionRecord>>,
    /// If set, use these selections instead of the router's.
    pub force_selections: Option<ForcedSelections>,
    /// If set, capture the (normed) input to each layer's MHSA block:
    /// `mhsa_inputs[layer]` has one row per token.
    pub capture_mhsa_inputs: Option<RefCell<Vec<Option<Mat>>>>,
    /// If set, capture the attention context fed to each layer's `wo`
    /// projection (GPTQ needs wo's own input distribution).
    pub capture_wo_inputs: Option<RefCell<Vec<Option<Mat>>>>,
    /// If set, capture the (normed) input to each layer's MoE block.
    pub capture_moe_inputs: Option<RefCell<Vec<Option<Mat>>>>,
    /// If set, capture full router logits per layer (rows = tokens).
    pub capture_router_logits: Option<RefCell<Vec<Option<Mat>>>>,
    /// If set (layer -> mask of experts to SKIP), prune at inference
    /// (PESF applies this per-sequence; see `prune::pesf`).
    pub expert_mask: Option<Vec<Vec<bool>>>,
    /// If set, per-row expert prune masks: `seq_expert_masks[row]` is that
    /// row's `layer × expert` mask, or `None` for an unpruned row. Length
    /// must equal the number of rows in the forward. This is how the
    /// serving engine carries each sequence's PESF mask through
    /// [`crate::model::Model::decode_step_batch`], where row `b` is
    /// sequence `b` — mixed batches of pruned and unpruned sequences are
    /// expressed as `Some`/`None` rows. OR-combined with `expert_mask` and
    /// the single-pass `pesf_alpha` mask.
    pub seq_expert_masks: Option<Vec<Option<SeqExpertMask>>>,
    /// If set, invoked per token after top-k selection and before expert
    /// dispatch; may drop entries from the selection (EES/ODP pruning).
    /// Arguments: layer index, token index, token's MoE-input row.
    pub selection_filter: Option<SelectionFilter>,
    /// If set alongside `selection_filter`, accumulates how many selected
    /// expert slots the filter dropped vs how many it saw — the actual
    /// EES/ODP prune rate (the engine used to report 0.0 for both).
    pub filter_drops: Option<RefCell<FilterDropStats>>,
    /// PESF (paper Eq. 6), single-pass: within each MoE layer, after the
    /// router has scored every token but before expert dispatch, prune
    /// experts selected fewer than `(l*K/N) * alpha` times for this
    /// sequence. This is why PESF costs one counting pass and no extra
    /// forward (Appendix A.1).
    pub pesf_alpha: Option<f32>,
    /// If set alongside `pesf_alpha`, records per-layer pruned-expert
    /// counts for reporting.
    pub pesf_pruned: Option<RefCell<Vec<usize>>>,
}

/// Per-token selection rewriter (see [`Hooks::selection_filter`]).
pub type SelectionFilter = Box<dyn Fn(usize, usize, &[f32], &mut TokenSelection)>;

impl Hooks {
    pub fn none() -> Self {
        Hooks::default()
    }

    /// Hooks that record selections for `n_layers`.
    pub fn recording(n_layers: usize) -> Self {
        Hooks {
            record_selections: Some(RefCell::new(SelectionRecord::with_layers(n_layers))),
            ..Default::default()
        }
    }

    /// Hooks that force the given selections.
    pub fn forcing(record: SelectionRecord) -> Self {
        Hooks { force_selections: Some(ForcedSelections { record }), ..Default::default() }
    }

    /// Hooks that capture all calibration activations.
    pub fn capturing(n_layers: usize) -> Self {
        Hooks {
            capture_mhsa_inputs: Some(RefCell::new(vec![None; n_layers])),
            capture_wo_inputs: Some(RefCell::new(vec![None; n_layers])),
            capture_moe_inputs: Some(RefCell::new(vec![None; n_layers])),
            capture_router_logits: Some(RefCell::new(vec![None; n_layers])),
            ..Default::default()
        }
    }

    /// Hooks carrying one prune mask per batch row (None = unpruned row) —
    /// the decode-time PESF entry point.
    pub fn with_seq_masks(masks: Vec<Option<SeqExpertMask>>) -> Self {
        Hooks { seq_expert_masks: Some(masks), ..Default::default() }
    }

    /// Take the recorded selections out of the hook.
    pub fn take_selections(self) -> Option<SelectionRecord> {
        self.record_selections.map(|r| r.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_normalizes() {
        let mut rec = SelectionRecord::with_layers(1);
        rec.layers[0].push(TokenSelection { experts: vec![0, 2], scores: vec![0.6, 0.3] });
        rec.layers[0].push(TokenSelection { experts: vec![2, 3], scores: vec![0.5, 0.2] });
        let f = rec.frequency(0, 4);
        assert_eq!(f, vec![0.25, 0.0, 0.5, 0.25]);
        assert!((f.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn flat_frequency_concatenates() {
        let mut rec = SelectionRecord::with_layers(2);
        rec.layers[0].push(TokenSelection { experts: vec![0], scores: vec![1.0] });
        rec.layers[1].push(TokenSelection { experts: vec![1], scores: vec![1.0] });
        let f = rec.flat_frequency(2);
        assert_eq!(f, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn empty_layer_frequency_is_zero() {
        let rec = SelectionRecord::with_layers(1);
        assert_eq!(rec.frequency(0, 3), vec![0.0; 3]);
    }

    #[test]
    fn token_experts_is_layer_major() {
        let mut rec = SelectionRecord::with_layers(2);
        rec.layers[0].push(TokenSelection { experts: vec![0, 2], scores: vec![0.6, 0.3] });
        rec.layers[0].push(TokenSelection { experts: vec![1], scores: vec![0.9] });
        rec.layers[1].push(TokenSelection { experts: vec![3], scores: vec![0.8] });
        rec.layers[1].push(TokenSelection { experts: vec![0, 1], scores: vec![0.5, 0.4] });
        assert_eq!(rec.token_experts(0), vec![vec![0, 2], vec![3]]);
        assert_eq!(rec.token_experts(1), vec![vec![1], vec![0, 1]]);
    }

    #[test]
    fn filter_drop_rate() {
        let s = FilterDropStats { seen: 8, dropped: 2 };
        assert!((s.rate() - 0.25).abs() < 1e-6);
        assert_eq!(FilterDropStats::default().rate(), 0.0);
    }
}
