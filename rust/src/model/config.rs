//! Model-zoo configurations: architecture-faithful miniatures of the four
//! MoE LLMs evaluated in the paper (DESIGN.md §2).

/// Architecture of one MoE transformer.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// Number of routed experts per MoE layer.
    pub n_experts: usize,
    /// Experts selected per token.
    pub top_k: usize,
    /// Always-active shared experts (DeepSeek/Qwen style); 0 for Mixtral/Phi.
    pub n_shared: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + attention + routers + experts).
    pub fn param_count(&self) -> usize {
        let emb = self.vocab * self.d_model; // tied in/out embedding
        let attn = 4 * self.d_model * self.d_model; // q,k,v,o
        let norms = 2 * self.d_model;
        let router = self.d_model * self.n_experts;
        let expert = 3 * self.d_model * self.d_ff; // w1, w2, w3 (SwiGLU)
        let per_layer = attn + norms + router + (self.n_experts + self.n_shared) * expert;
        emb + self.n_layers * per_layer + self.d_model // final norm
    }

    /// Parameter count of all experts only (what QESC quantizes at low bit).
    pub fn expert_param_count(&self) -> usize {
        self.n_layers * (self.n_experts + self.n_shared) * 3 * self.d_model * self.d_ff
    }

    /// Parameter count of MHSA (quantized at 4 bit in the paper).
    pub fn mhsa_param_count(&self) -> usize {
        self.n_layers * 4 * self.d_model * self.d_model
    }

    /// Router parameters (kept full-precision, ~0.03% of total — Table 11).
    pub fn router_param_count(&self) -> usize {
        self.n_layers * self.d_model * self.n_experts
    }

    /// Parameter counts for `quant::alloc::model_average_bits` — built here
    /// so `quant` never needs to look upward at `ModelConfig`.
    pub fn bit_dims(&self) -> crate::quant::alloc::BitDims {
        crate::quant::alloc::BitDims {
            n_layers: self.n_layers,
            expert_params: 3 * self.d_model * self.d_ff,
            mhsa_params: self.mhsa_param_count(),
            router_params: self.router_param_count(),
        }
    }
}

/// The four miniature models mirroring the paper's zoo (Table/DESIGN §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ZooModel {
    /// Mixtral-8x7B proxy: 8 experts, top-2, no shared.
    MixtralMini,
    /// Phi3.5-moe proxy: 16 experts, top-2.
    PhiMini,
    /// Deepseek-moe-16b proxy: 64 experts, top-6, 2 shared.
    DeepseekMini,
    /// Qwen1.5-MoE-A2.7B proxy: 60 experts, top-4, 4 shared.
    QwenMini,
}

impl ZooModel {
    pub const ALL: [ZooModel; 4] =
        [ZooModel::MixtralMini, ZooModel::PhiMini, ZooModel::DeepseekMini, ZooModel::QwenMini];

    pub fn key(&self) -> &'static str {
        match self {
            ZooModel::MixtralMini => "mixtral-mini",
            ZooModel::PhiMini => "phi-mini",
            ZooModel::DeepseekMini => "deepseek-mini",
            ZooModel::QwenMini => "qwen-mini",
        }
    }

    /// Display name used in paper-style tables.
    pub fn display(&self) -> &'static str {
        match self {
            ZooModel::MixtralMini => "Mixtral-8x7B (mini)",
            ZooModel::PhiMini => "Phi3.5-moe (mini)",
            ZooModel::DeepseekMini => "Deepseek-moe-16b (mini)",
            ZooModel::QwenMini => "Qwen1.5-MoE-A2.7B (mini)",
        }
    }

    pub fn from_key(key: &str) -> Option<ZooModel> {
        ZooModel::ALL.iter().copied().find(|m| m.key() == key)
    }

    pub fn config(&self) -> ModelConfig {
        match self {
            ZooModel::MixtralMini => ModelConfig {
                name: self.key().into(),
                n_layers: 4,
                d_model: 128,
                d_ff: 256,
                n_experts: 8,
                top_k: 2,
                n_shared: 0,
                n_heads: 4,
                vocab: 512,
                max_seq: 512,
            },
            ZooModel::PhiMini => ModelConfig {
                name: self.key().into(),
                n_layers: 4,
                d_model: 128,
                d_ff: 224,
                n_experts: 16,
                top_k: 2,
                n_shared: 0,
                n_heads: 4,
                vocab: 512,
                max_seq: 512,
            },
            ZooModel::DeepseekMini => ModelConfig {
                name: self.key().into(),
                n_layers: 4,
                d_model: 128,
                d_ff: 64,
                n_experts: 64,
                top_k: 6,
                n_shared: 2,
                n_heads: 4,
                vocab: 512,
                max_seq: 512,
            },
            ZooModel::QwenMini => ModelConfig {
                name: self.key().into(),
                n_layers: 4,
                d_model: 128,
                d_ff: 64,
                n_experts: 60,
                top_k: 4,
                n_shared: 4,
                n_heads: 4,
                vocab: 512,
                max_seq: 512,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_shapes_match_paper_ratios() {
        let ds = ZooModel::DeepseekMini.config();
        assert_eq!(ds.n_experts, 64);
        assert_eq!(ds.top_k, 6);
        assert_eq!(ds.n_shared, 2);
        let qw = ZooModel::QwenMini.config();
        assert_eq!(qw.n_experts, 60);
        assert_eq!(qw.n_shared, 4);
    }

    #[test]
    fn experts_dominate_params() {
        // Paper Table 11: experts are ~97% of non-embedding params. Our minis
        // are smaller so the ratio is lower, but experts must still dominate.
        for m in ZooModel::ALL {
            let c = m.config();
            let non_emb = c.param_count() - c.vocab * c.d_model;
            let frac = c.expert_param_count() as f64 / non_emb as f64;
            assert!(frac > 0.65, "{}: expert frac {frac}", c.name);
            let router_frac = c.router_param_count() as f64 / non_emb as f64;
            assert!(router_frac < 0.02, "{}: router frac {router_frac}", c.name);
        }
    }

    #[test]
    fn key_roundtrip() {
        for m in ZooModel::ALL {
            assert_eq!(ZooModel::from_key(m.key()), Some(m));
        }
        assert_eq!(ZooModel::from_key("nope"), None);
    }

    #[test]
    fn head_dim_divides() {
        for m in ZooModel::ALL {
            let c = m.config();
            assert_eq!(c.head_dim() * c.n_heads, c.d_model);
        }
    }
}
