//! Memory-budgeted tiered expert storage — the paper's challenge (1) made
//! operational.
//!
//! EAC-MoE opens with the observation that MoE serving is gated by the
//! "substantial GPU memory consumption to load all experts": the experts
//! are ~95% of the parameters, yet each token runs only `top_k` of them.
//! Quantization (QESC) shrinks the *bytes per expert*; this module manages
//! the other axis — *which experts are resident at all*. An
//! [`ExpertStore`] mediates every routed-expert access in the forward
//! pass:
//!
//! * [`ExpertStore::Resident`] — all experts live in
//!   [`crate::model::Weights`], accesses are `Arc` clones. This is the
//!   historical behavior and the default for [`crate::model::Model::new`].
//! * [`ExpertStore::Tiered`] — packed experts stay **on disk** (via the
//!   byte-range [`IndexedTensorFile`] reader) and are loaded on demand
//!   into a cache bounded by a **hard byte budget**, evicting by
//!   selection-frequency-weighted LRU.
//!
//! ## Why eviction reuses the PESF signal, not plain LRU
//!
//! PESF (paper Eq. 6) prunes an expert when its *selection count* over the
//! recent token stream falls below `α · l·K/N` — the router's own
//! selection frequencies are the paper's measure of how much an expert
//! matters to the current workload. Mixture Compressor (arXiv 2410.06270)
//! and MC# (arXiv 2510.10962) draw the same conclusion for static
//! compression: per-expert significance ∝ routing frequency. The tiered
//! store feeds the **same counts** (how many tokens each expert was
//! routed, accumulated from the routing decisions the forward pass already
//! computes) into its eviction policy: the victim is the resident expert
//! with the lowest selection count, ties broken by least-recent use.
//! Plain LRU would treat a once-touched cold expert and a consistently hot
//! expert that happened to skip one batch as equals; frequency-weighting
//! keeps the experts the router actually concentrates on (the skewed
//! distribution PESF exploits) resident, so the hit rate tracks routing
//! skew rather than batch order. Counts are aged (halved periodically) so
//! the frequency reflects the recent workload, like PESF's rolling window
//! rather than an all-time census.
//!
//! ## Correctness contract
//!
//! Tiering changes **when** an expert's bytes are resident, never its
//! math: a loaded expert is decoded by the same
//! [`crate::model::weights::read_expert_from`] path the eager loader uses,
//! so outputs are bit-identical at every budget and pool size (pinned by
//! `tests/expert_store.rs` across budget fractions {100%, 50%,
//! smallest-that-fits} × pool sizes {1, 4}). The budget is enforced
//! *inside* the store lock — the cache never holds more than
//! `budget_bytes` — while callers keep experts alive through their
//! `Arc<ExpertWeights>` guard handles for exactly the duration of the
//! layer's GEMMs. Disk reads happen *outside* the lock (an in-flight set
//! plus condvar deduplicates concurrent loads of the same expert), so one
//! worker's miss never serializes another worker's cache hits. Shared
//! (always-on) experts are pinned resident outside the store: they run
//! for every token, so tiering them buys nothing and would thrash the
//! cache.

use super::config::ModelConfig;
use super::forward::Model;
use super::weights::{read_delta_from, read_expert_from, ExpertDelta, ExpertWeights, Weights};
use crate::tensor::pool::ThreadPool;
use crate::util::binio::IndexedTensorFile;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Halve every selection count after this many store batches, so the
/// frequency signal tracks the recent workload (PESF's rolling-window
/// idea) instead of an all-time census.
const AGE_EVERY_TICKS: u64 = 4096;

/// Snapshot of the store's accounting, surfaced through
/// [`crate::serve::ServeMetrics`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExpertStoreStats {
    /// Batch fetches answered from the cache.
    pub hits: u64,
    /// Fetches that had to load from disk.
    pub misses: u64,
    /// Residents dropped to keep the cache under budget.
    pub evictions: u64,
    /// Wall-clock spent blocked on on-demand expert loads.
    pub load_stall_secs: f64,
    /// Bytes of routed experts currently cached (≤ `budget_bytes`).
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` (also ≤ `budget_bytes`).
    pub peak_resident_bytes: usize,
    /// On-disk bytes of the full routed-expert set.
    pub total_bytes: usize,
    /// Hard cache budget; 0 means unbudgeted (fully resident store).
    pub budget_bytes: usize,
}

impl ExpertStoreStats {
    /// Fraction of expert fetches served without touching disk.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            return 1.0;
        }
        self.hits as f64 / n as f64
    }
}

/// How the model's routed experts are stored and fetched.
pub enum ExpertStore {
    /// Every expert materialized in [`Weights`]; fetches are `Arc` clones.
    Resident,
    /// Experts on disk, cached under a byte budget.
    Tiered(TieredStore),
}

impl ExpertStore {
    pub fn is_tiered(&self) -> bool {
        matches!(self, ExpertStore::Tiered(_))
    }
}

/// On-disk location + size of one tierable unit's tensors (a routed
/// expert, or one merged layer's per-old-expert delta).
struct ExpertSpec {
    /// Tensor-name prefix (`layer{i}.expert{e}` / `layer{i}.delta{o}`).
    prefix: String,
    /// Payload bytes across its tensors (codes+scales+zeros for packed,
    /// plain f32 for dense/deltas) — equals the loaded unit's
    /// `storage_bytes`, so budget accounting is exact.
    bytes: usize,
}

/// One layer's tierable units. A layer is either unmerged (every routed
/// expert tiers) or merged (cluster bases stay resident in [`Weights`];
/// only the per-**old**-expert low-rank deltas tier — `None` where the
/// checkpoint has no delta, i.e. the base alone is that member).
enum LayerSpecs {
    Experts(Vec<ExpertSpec>),
    Deltas(Vec<Option<ExpertSpec>>),
}

/// A cached tierable unit. The key space `(layer, id)` is shared safely:
/// a layer is either unmerged (ids are expert ids, units are `Expert`) or
/// merged (ids are old expert ids, units are `Delta`) — never both.
#[derive(Clone)]
enum Unit {
    Expert(Arc<ExpertWeights>),
    Delta(Arc<ExpertDelta>),
}

struct CacheEntry {
    u: Unit,
    bytes: usize,
    last_tick: u64,
}

struct Inner {
    /// `(layer, expert)` → resident entry. BTreeMap so eviction
    /// tie-breaking is deterministic.
    cache: BTreeMap<(u32, u32), CacheEntry>,
    /// Keys some thread is currently loading *outside* the lock — other
    /// threads wanting the same expert wait on [`TieredStore::loaded`]
    /// instead of duplicating the disk read.
    loading: std::collections::BTreeSet<(u32, u32)>,
    /// Selection counts per (layer, expert) — the Eq. 6 signal, fed from
    /// the routing decisions of every forward pass, aged periodically.
    freq: Vec<Vec<u64>>,
    tick: u64,
    resident: usize,
    peak_resident: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    stall_secs: f64,
}

/// The disk-backed, budget-bounded expert cache.
pub struct TieredStore {
    file: IndexedTensorFile,
    cfg: ModelConfig,
    budget: usize,
    specs: Vec<LayerSpecs>,
    total_bytes: usize,
    max_expert_bytes: usize,
    inner: Mutex<Inner>,
    /// Signalled whenever a load finishes (success or failure), so threads
    /// waiting for an in-flight expert re-check the cache.
    loaded: std::sync::Condvar,
    /// Set by [`Model::into_tiered`] only: the spill checkpoint this store
    /// created for itself, removed on [`Drop`]. `None` for
    /// [`Model::open_tiered`] — that checkpoint belongs to the caller.
    owned_spill: Option<std::path::PathBuf>,
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        // Portable cleanup of an into_tiered spill: the eager unlink the
        // callers attempt only works while-open on unix; here the fd is
        // gone on every platform.
        if let Some(p) = &self.owned_spill {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl TieredStore {
    /// Build the store over an already-opened indexed checkpoint, with the
    /// (skeleton-)loaded `weights` determining each layer's tierable unit:
    /// routed experts for unmerged layers, per-old-expert merge deltas for
    /// merged ones (whose cluster bases stay resident in `weights`).
    /// Validates up front that every unit's tensors are present in the
    /// index (a packed expert missing a `.q.codes`/`.q.scales`/`.q.zeros`
    /// sidecar is an error *here*, not a mid-serve panic) and that the
    /// budget can hold at least the largest single unit.
    pub fn new(file: IndexedTensorFile, weights: &Weights, budget_bytes: usize) -> Result<Self> {
        let cfg = &weights.cfg;
        let mut specs = Vec::with_capacity(cfg.n_layers);
        let mut total = 0usize;
        let mut max_expert = 0usize;
        for li in 0..cfg.n_layers {
            if weights.layers[li].remap().is_some() {
                let mut layer = Vec::with_capacity(cfg.n_experts);
                for o in 0..cfg.n_experts {
                    let prefix = format!("layer{li}.delta{o}");
                    if !file.index.contains_key(&format!("{prefix}.w1.u")) {
                        // No delta for this old id: its cluster base alone
                        // is the member — nothing to tier.
                        layer.push(None);
                        continue;
                    }
                    let mut bytes = 0usize;
                    for t in ["w1.u", "w1.v", "w2.u", "w2.v", "w3.u", "w3.v"] {
                        bytes += file.entry_bytes(&format!("{prefix}.{t}")).with_context(|| {
                            format!("merge delta '{prefix}': missing low-rank factor tensor")
                        })?;
                    }
                    total += bytes;
                    max_expert = max_expert.max(bytes);
                    layer.push(Some(ExpertSpec { prefix, bytes }));
                }
                specs.push(LayerSpecs::Deltas(layer));
                continue;
            }
            let mut layer = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                let prefix = format!("layer{li}.expert{e}");
                let mut bytes = 0usize;
                for w in ["w1", "w2", "w3"] {
                    let name = format!("{prefix}.{w}");
                    if file.index.contains_key(&name) {
                        bytes += file.entry_bytes(&name)?;
                    } else if file.index.contains_key(&format!("{name}.q.meta")) {
                        for side in ["q.codes", "q.scales", "q.zeros"] {
                            bytes += file.entry_bytes(&format!("{name}.{side}")).with_context(
                                || format!("expert '{prefix}': missing packed sidecar tensor"),
                            )?;
                        }
                    } else {
                        anyhow::bail!(
                            "expert tensor '{name}' missing from {} (neither dense nor packed)",
                            file.path().display()
                        );
                    }
                }
                total += bytes;
                max_expert = max_expert.max(bytes);
                layer.push(ExpertSpec { prefix, bytes });
            }
            specs.push(LayerSpecs::Experts(layer));
        }
        anyhow::ensure!(
            budget_bytes >= max_expert,
            "expert budget {budget_bytes} B cannot hold the largest expert ({max_expert} B); \
             the smallest feasible budget for this model is {:.3} MB",
            max_expert as f64 / 1e6
        );
        Ok(TieredStore {
            file,
            cfg: cfg.clone(),
            budget: budget_bytes,
            specs,
            total_bytes: total,
            max_expert_bytes: max_expert,
            owned_spill: None,
            inner: Mutex::new(Inner {
                cache: BTreeMap::new(),
                loading: std::collections::BTreeSet::new(),
                freq: vec![vec![0; cfg.n_experts]; cfg.n_layers],
                tick: 0,
                resident: 0,
                peak_resident: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                stall_secs: 0.0,
            }),
            loaded: std::sync::Condvar::new(),
        })
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    /// Smallest budget [`TieredStore::new`] accepts for this checkpoint.
    pub fn max_expert_bytes(&self) -> usize {
        self.max_expert_bytes
    }

    /// Fetch guard handles for one **unmerged** layer's about-to-run
    /// experts, loading misses from disk and evicting to budget. `wants`
    /// is `(expert, routed_token_count)` — the token counts are the same
    /// selection-frequency signal PESF thresholds (Eq. 6's counts) and
    /// feed the eviction policy. Call once per MoE layer, *before* the
    /// expert GEMMs: the router's top-k has just determined exactly which
    /// experts run, so this is the router-score-driven prefetch point.
    pub fn fetch(&self, layer: usize, wants: &[(usize, usize)]) -> Result<Vec<Arc<ExpertWeights>>> {
        debug_assert!(layer < self.specs.len(), "layer {layer} out of {}", self.specs.len());
        anyhow::ensure!(
            matches!(self.specs.get(layer), Some(LayerSpecs::Experts(_))),
            "layer {layer} is merged; its tierable units are deltas (use fetch_deltas)"
        );
        let units = self.fetch_units(layer, wants)?;
        units
            .into_iter()
            .map(|u| match u {
                Some(Unit::Expert(w)) => Ok(w),
                _ => anyhow::bail!("internal: non-expert unit cached under unmerged layer {layer}"),
            })
            .collect()
    }

    /// Fetch guard handles for one **merged** layer's about-to-run deltas,
    /// by old expert id. `None` entries mean the checkpoint has no delta
    /// for that member (the cluster base alone serves it) — not an error.
    /// Same budget/eviction/frequency machinery as [`TieredStore::fetch`];
    /// the token counts feed the per-old-id frequency signal.
    pub fn fetch_deltas(
        &self,
        layer: usize,
        wants: &[(usize, usize)],
    ) -> Result<Vec<Option<Arc<ExpertDelta>>>> {
        debug_assert!(layer < self.specs.len(), "layer {layer} out of {}", self.specs.len());
        anyhow::ensure!(
            matches!(self.specs.get(layer), Some(LayerSpecs::Deltas(_))),
            "layer {layer} is not merged; it has no tiered deltas (use fetch)"
        );
        let units = self.fetch_units(layer, wants)?;
        units
            .into_iter()
            .map(|u| match u {
                None => Ok(None),
                Some(Unit::Delta(d)) => Ok(Some(d)),
                Some(Unit::Expert(_)) => {
                    anyhow::bail!("internal: expert unit cached under merged layer {layer}")
                }
            })
            .collect()
    }

    /// Shared fetch core over tierable units (experts or deltas). Returns
    /// one entry per want: `Some(unit)`, or `None` for a merged-layer id
    /// with no delta spec (still counted into the frequency signal — the
    /// router routed tokens there).
    fn fetch_units(&self, layer: usize, wants: &[(usize, usize)]) -> Result<Vec<Option<Unit>>> {
        let batch: Vec<(u32, u32)> =
            wants.iter().map(|&(e, _)| (layer as u32, e as u32)).collect();
        let mut out = Vec::with_capacity(wants.len());
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if tick % AGE_EVERY_TICKS == 0 {
            for l in &mut inner.freq {
                for c in l.iter_mut() {
                    *c >>= 1;
                }
            }
        }
        for &(e, tokens) in wants {
            inner.freq[layer][e] += tokens as u64;
            // Resolve the unit's on-disk spec; a merged-layer id with no
            // delta has nothing to load or cache.
            let (spec, is_delta) = match &self.specs[layer] {
                LayerSpecs::Experts(v) => {
                    anyhow::ensure!(e < v.len(), "expert {e} out of range for layer {layer}");
                    (&v[e], false)
                }
                LayerSpecs::Deltas(v) => {
                    anyhow::ensure!(e < v.len(), "old expert {e} out of range for layer {layer}");
                    match &v[e] {
                        Some(s) => (s, true),
                        None => {
                            out.push(None);
                            continue;
                        }
                    }
                }
            };
            let key = (layer as u32, e as u32);
            loop {
                if let Some(ent) = inner.cache.get_mut(&key) {
                    ent.last_tick = tick;
                    let u = ent.u.clone();
                    inner.hits += 1;
                    out.push(Some(u));
                    break;
                }
                // Another thread is already reading this unit: wait for
                // its insert instead of duplicating the disk IO, then
                // re-check (it may also have failed, or been evicted).
                if inner.loading.contains(&key) {
                    inner = self.loaded.wait(inner).unwrap();
                    continue;
                }
                // This thread loads it. The disk read + decode run
                // *outside* the lock so concurrent fetches — cache hits
                // and loads of other units — proceed during the IO;
                // `loading` keeps the key claimed meanwhile.
                inner.misses += 1;
                inner.loading.insert(key);
                drop(inner);
                let t0 = Instant::now();
                let res = if is_delta {
                    read_delta_from(&self.file, &spec.prefix, &self.cfg)
                        .map(|d| Unit::Delta(Arc::new(d)))
                        .with_context(|| format!("loading merge delta '{}' on demand", spec.prefix))
                } else {
                    read_expert_from(&self.file, &spec.prefix, &self.cfg)
                        .map(|w| Unit::Expert(Arc::new(w)))
                        .with_context(|| format!("loading expert '{}' on demand", spec.prefix))
                };
                let stall = t0.elapsed().as_secs_f64();
                inner = self.inner.lock().unwrap();
                inner.loading.remove(&key);
                inner.stall_secs += stall;
                let u = match res {
                    Ok(u) => u,
                    Err(err) => {
                        // Waiters must wake even on failure (they will
                        // retry the load themselves and surface the same
                        // error).
                        self.loaded.notify_all();
                        return Err(err);
                    }
                };
                inner
                    .cache
                    .insert(key, CacheEntry { u: u.clone(), bytes: spec.bytes, last_tick: tick });
                inner.resident += spec.bytes;
                // Enforce the budget immediately after each insert, never
                // evicting the entry just added (the budget admits any
                // single unit, so other residents always cover the
                // overshoot). Current-batch residents are only evicted as
                // a last resort — the caller's guard handle keeps them
                // usable either way.
                while inner.resident > self.budget {
                    let victim = {
                        let i = &*inner;
                        i.cache
                            .iter()
                            .filter(|(k, _)| **k != key)
                            .min_by_key(|(k, ent)| {
                                let in_batch = batch.contains(*k);
                                (in_batch, i.freq[k.0 as usize][k.1 as usize], ent.last_tick)
                            })
                            .map(|(k, _)| *k)
                    };
                    let Some(v) = victim else { break };
                    // The victim key came from iterating this same map
                    // under the same lock, so the entry is present.
                    let Some(ent) = inner.cache.remove(&v) else { break };
                    inner.resident -= ent.bytes;
                    inner.evictions += 1;
                }
                inner.peak_resident = inner.peak_resident.max(inner.resident);
                self.loaded.notify_all();
                out.push(Some(u));
                break;
            }
        }
        Ok(out)
    }

    /// Re-seat the high-water mark to the current occupancy. The engine
    /// calls this at the start of each serve run so
    /// `peak_resident_bytes` reports that run's own peak instead of the
    /// store's lifetime maximum.
    pub fn reset_peak(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.peak_resident = inner.resident;
    }

    pub fn stats(&self) -> ExpertStoreStats {
        let inner = self.inner.lock().unwrap();
        ExpertStoreStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            load_stall_secs: inner.stall_secs,
            resident_bytes: inner.resident,
            peak_resident_bytes: inner.peak_resident,
            total_bytes: self.total_bytes,
            budget_bytes: self.budget,
        }
    }
}

impl Model {
    /// Open a checkpoint with its routed experts left **on disk**, served
    /// through a [`TieredStore`] under `budget_bytes`. Everything else
    /// (embeddings, norms, attention, routers, shared experts) loads
    /// eagerly as usual. Runs on the process-global pool.
    pub fn open_tiered(path: &Path, name: &str, budget_bytes: usize) -> Result<Model> {
        Self::open_tiered_with_pool(path, name, budget_bytes, ThreadPool::global().clone())
    }

    /// [`Model::open_tiered`] on an explicit worker pool.
    pub fn open_tiered_with_pool(
        path: &Path,
        name: &str,
        budget_bytes: usize,
        pool: Arc<ThreadPool>,
    ) -> Result<Model> {
        let file = IndexedTensorFile::open(path)?;
        let weights = Weights::from_source(&file, name, false)?;
        let store = TieredStore::new(file, &weights, budget_bytes)?;
        Ok(Model { weights, store: ExpertStore::Tiered(store), pool })
    }

    /// Convert a resident model into a tiered one: spill the weights to
    /// `spill` (full checkpoint save) and reopen with the routed experts
    /// on disk under `budget_bytes`. Keeps the model's pool. This is what
    /// `serve --expert-budget-mb` does for a model that was loaded (or
    /// initialized) fully resident.
    pub fn into_tiered(self, budget_bytes: usize, spill: &Path) -> Result<Model> {
        // Validate the budget *before* writing a model-sized checkpoint:
        // an infeasible budget must not cost a multi-GB spill first.
        // `max_expert_bytes` is the largest tierable unit — a routed
        // expert, or a merge delta for merged layers.
        let min = self.weights.max_expert_bytes();
        anyhow::ensure!(
            budget_bytes >= min,
            "expert budget {budget_bytes} B cannot hold the largest expert ({min} B); \
             the smallest feasible budget for this model is {:.3} MB",
            min as f64 / 1e6
        );
        self.weights
            .save(spill)
            .with_context(|| format!("spilling weights to {}", spill.display()))?;
        let mut model =
            Model::open_tiered_with_pool(spill, &self.weights.cfg.name, budget_bytes, self.pool)
                .map_err(|e| {
                    // Don't leave the spilled checkpoint behind on a failed
                    // open.
                    let _ = std::fs::remove_file(spill);
                    e
                })?;
        // The spill was created for this store alone: remove it when the
        // store drops (callers on unix may additionally unlink it eagerly
        // — the store reads through its open fd either way).
        if let ExpertStore::Tiered(t) = &mut model.store {
            t.owned_spill = Some(spill.to_path_buf());
        }
        Ok(model)
    }

    /// Guard handles for one layer's routed experts. `wants` is
    /// `(expert index, routed token count)` — merged ids for merged
    /// layers. Resident store: `Arc` clones out of [`Weights`]. Tiered
    /// store: cache hits or on-demand loads under the byte budget —
    /// except for merged layers, whose cluster bases stay resident in
    /// [`Weights`] in every store mode (only their deltas tier).
    pub(crate) fn experts_for_layer(
        &self,
        li: usize,
        wants: &[(usize, usize)],
    ) -> Vec<Arc<ExpertWeights>> {
        debug_assert!(li < self.weights.layers.len(), "layer {li} out of {}", self.weights.layers.len());
        match &self.store {
            ExpertStore::Resident => {
                wants.iter().map(|&(e, _)| self.weights.layers[li].expert_arc(e)).collect()
            }
            ExpertStore::Tiered(_) if self.weights.layers[li].remap().is_some() => {
                wants.iter().map(|&(e, _)| self.weights.layers[li].expert_arc(e)).collect()
            }
            ExpertStore::Tiered(t) => fetch_or_abort(|| t.fetch(li, wants)),
        }
    }

    /// Guard handles for one **merged** layer's per-old-expert deltas.
    /// `wants` is `(old expert id, routed token count)`; `None` entries
    /// mean the member has no delta (its cluster base is exact). Resident
    /// store: `Arc` clones of the weights' resident deltas. Tiered store:
    /// the deltas are the layer's eviction unit — same budget/retry/abort
    /// discipline as [`Model::experts_for_layer`].
    pub(crate) fn deltas_for_layer(
        &self,
        li: usize,
        wants: &[(usize, usize)],
    ) -> Vec<Option<Arc<ExpertDelta>>> {
        debug_assert!(li < self.weights.layers.len(), "layer {li} out of {}", self.weights.layers.len());
        match &self.store {
            ExpertStore::Resident => {
                wants.iter().map(|&(o, _)| self.weights.layers[li].delta_arc(o)).collect()
            }
            ExpertStore::Tiered(t) => fetch_or_abort(|| t.fetch_deltas(li, wants)),
        }
    }

    /// Start a fresh measurement window on the tiered store: the peak
    /// occupancy re-seats to the current occupancy (counters stay
    /// cumulative; callers delta them). No-op for a resident store.
    pub fn reset_expert_peak(&self) {
        if let ExpertStore::Tiered(t) = &self.store {
            t.reset_peak();
        }
    }

    /// Store accounting. For a resident store this degenerates to the
    /// weights' own expert bytes (everything resident, no budget, no
    /// traffic). **Routed** experts only — shared experts are pinned in
    /// [`Weights`] outside the budget in both modes.
    pub fn expert_store_stats(&self) -> ExpertStoreStats {
        match &self.store {
            ExpertStore::Resident => {
                let b = self.weights.routed_expert_bytes();
                ExpertStoreStats {
                    resident_bytes: b,
                    peak_resident_bytes: b,
                    total_bytes: b,
                    ..Default::default()
                }
            }
            ExpertStore::Tiered(t) => t.stats(),
        }
    }

    /// True resident bytes of everything being served: the weights still
    /// materialized in memory (embeddings, norms, attention, routers,
    /// shared experts — plus routed experts when the store is resident)
    /// plus whatever the tiered cache currently holds.
    pub fn resident_weight_bytes(&self) -> usize {
        let base = self.weights.storage_bytes();
        match &self.store {
            ExpertStore::Resident => base,
            ExpertStore::Tiered(t) => base + t.stats().resident_bytes,
        }
    }
}

/// Run a tiered-store fetch with a bounded retry, aborting the process on
/// persistent failure. The store was fully validated at open (index
/// complete, budget feasible), so an error here is an IO failure on the
/// checkpoint mid-serve. Transient hiccups get the retry (already-cached
/// units hit on the retry; only the failed load re-runs); continuing
/// without the unit's weights would silently produce wrong logits for
/// every token routed to it, and unwinding mid-batch through the pool
/// scope is no better — so a persistent failure terminates without
/// unwinding.
fn fetch_or_abort<T>(mut op: impl FnMut() -> Result<T>) -> T {
    let mut last_err = None;
    for attempt in 0..3u32 {
        match op() {
            Ok(v) => return v,
            Err(e) => {
                last_err = Some(e);
                if attempt < 2 {
                    std::thread::sleep(std::time::Duration::from_millis(10 << attempt));
                }
            }
        }
    }
    let err = match last_err {
        Some(e) => format!("{e:#}"),
        None => "no error recorded".to_string(),
    };
    eprintln!("tiered expert store: on-demand load failed after 3 attempts: {err}");
    std::process::abort()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::hooks::Hooks;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        }
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("eac_moe_store_{tag}_{}.bin", std::process::id()))
    }

    #[test]
    fn tiered_forward_bit_identical_to_resident() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 21);
        w.pack_experts_rtn(4, 16);
        let path = temp_path("fwd");
        w.save(&path).unwrap();
        let resident = Model::new(w.clone());
        let tokens: Vec<u32> = (0..24).map(|i| (i * 7) % 32).collect();
        let want = resident.forward(&tokens);
        let total = resident.expert_store_stats().total_bytes;
        let min_fit = w.max_expert_bytes();
        for budget in [total, total / 2, min_fit] {
            let tiered = Model::open_tiered(&path, "tiny", budget).unwrap();
            assert!(tiered.store.is_tiered());
            let got = tiered.forward(&tokens);
            assert_eq!(got.data, want.data, "budget {budget}");
            let st = tiered.expert_store_stats();
            assert!(st.resident_bytes <= budget, "resident {} > {budget}", st.resident_bytes);
            assert!(st.peak_resident_bytes <= budget);
            assert_eq!(st.total_bytes, total);
            assert!(st.misses > 0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tight_budget_evicts_and_reloads() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 22);
        w.pack_experts_rtn(4, 16);
        let path = temp_path("evict");
        w.save(&path).unwrap();
        let m = Model::open_tiered(&path, "tiny", w.max_expert_bytes()).unwrap();
        let tokens: Vec<u32> = (0..32).map(|i| (i * 5) % 32).collect();
        m.forward(&tokens);
        m.forward(&tokens);
        let st = m.expert_store_stats();
        // One-expert budget: every distinct expert in a layer forces a
        // load, and repeat passes reload (cold cache every time).
        assert!(st.evictions > 0, "smallest budget must evict");
        assert!(st.misses > st.hits, "smallest budget should mostly miss");
        assert!(st.peak_resident_bytes <= w.max_expert_bytes());
        assert!(st.load_stall_secs >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn budget_below_largest_expert_is_rejected() {
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 23);
        let path = temp_path("reject");
        w.save(&path).unwrap();
        let err = Model::open_tiered(&path, "tiny", w.max_expert_bytes() - 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("budget"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn frequency_weighted_eviction_prefers_cold_experts() {
        // Two experts fit. Make expert (0,0) hot, then touch two cold
        // experts; the cold pair should cycle while 0 stays resident.
        let cfg = tiny_cfg();
        let w = Weights::init(&cfg, 24);
        let path = temp_path("freq");
        w.save(&path).unwrap();
        let per = w.layers[0].experts()[0].storage_bytes();
        let m = Model::open_tiered(&path, "tiny", per * 2).unwrap();
        let ExpertStore::Tiered(t) = &m.store else { panic!("tiered") };
        for _ in 0..5 {
            t.fetch(0, &[(0, 8)]).unwrap(); // hot: high selection count
        }
        t.fetch(0, &[(1, 1)]).unwrap(); // cache: {0, 1}
        t.fetch(0, &[(2, 1)]).unwrap(); // evicts 1 (cold), keeps hot 0
        let st0 = t.stats();
        let h0 = st0.hits;
        t.fetch(0, &[(0, 1)]).unwrap(); // hot expert still resident -> hit
        assert_eq!(t.stats().hits, h0 + 1, "hot expert was evicted");
        t.fetch(0, &[(1, 1)]).unwrap(); // cold expert was evicted -> miss
        assert_eq!(t.stats().hits, h0 + 1);
        assert!(t.stats().evictions >= 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tiered_skeleton_keeps_shared_resident_and_drops_routed() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 25);
        w.pack_experts_rtn(4, 16);
        let path = temp_path("skel");
        w.save(&path).unwrap();
        let m = Model::open_tiered(&path, "tiny", w.expert_storage_bytes()).unwrap();
        for (li, l) in m.weights.layers.iter().enumerate() {
            assert!(l.experts().is_empty(), "layer {li} routed experts must be on disk");
            assert_eq!(l.shared().len(), cfg.n_shared, "layer {li} shared stay resident");
        }
        // Resident weight bytes exclude the routed experts until they load.
        let routed: usize = w
            .layers
            .iter()
            .flat_map(|l| l.experts().iter())
            .map(|e| e.storage_bytes())
            .sum();
        assert_eq!(m.resident_weight_bytes(), w.storage_bytes() - routed);
        // Forward with hooks still works and matches resident exactly.
        let resident = Model::new(w);
        let toks = [1u32, 5, 9, 2, 7];
        let a = m.forward_with_hooks(&toks, &Hooks::none());
        let b = resident.forward(&toks);
        assert_eq!(a.data, b.data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_packed_sidecar_fails_at_open_with_context() {
        use crate::util::binio::TensorFile;
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 26);
        w.pack_experts_rtn(4, 16);
        let mut tf = w.to_tensor_file();
        assert!(tf.entries.remove("layer0.expert1.w2.q.codes").is_some());
        let path = temp_path("sidecar");
        tf.save(&path).unwrap();
        // Whole-file load fails too (shared decode path)...
        assert!(Weights::from_tensor_file(&TensorFile::load(&path).unwrap(), "tiny").is_err());
        // ...and the tiered open names the broken expert instead of
        // deferring the failure to a mid-serve fetch.
        let err = Model::open_tiered(&path, "tiny", usize::MAX).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("layer0.expert1"), "{msg}");
        assert!(msg.contains("sidecar") || msg.contains("missing"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }
}
