//! Native forward pass of the MoE transformer: prefill (optionally
//! exporting its K/V into the decode cache) + kv-cache decode, single
//! sequence or batched.
//!
//! This mirrors the AOT-compiled JAX graph (L2) exactly — pre-norm blocks,
//! causal MHSA, SwiGLU experts, softmax-then-top-k routing with top-k score
//! renormalization (paper Eq. 2) — and adds the hooks the compression
//! pipeline needs. Expert execution is grouped: tokens routed to the same
//! expert are gathered and run through the expert FFN as one GEMM, so
//! skipping an expert (PESF) skips real work, which is exactly the latency
//! model the paper's speedup numbers rely on.
//!
//! All projection/expert GEMMs dispatch through [`WeightMat`]: a dense
//! matrix hits the blocked f32 GEMM, a packed quantized matrix hits the
//! fused group-dequant GEMM — QESC-compressed models serve directly from
//! their packed storage with no f32 weight copies resident.
//!
//! Routed expert weights are reached through the model's
//! [`ExpertStore`] as `Arc<ExpertWeights>` guard handles, fetched in one
//! batch right after routing determines which experts will run (the
//! router-score-driven prefetch). Under a `Tiered` store the fetch may
//! load experts from disk within a hard byte budget; under the default
//! `Resident` store it is a cheap `Arc` clone. Either way the math — and
//! therefore every output bit — is identical.
//!
//! Parallelism: every forward surface runs on the model's persistent
//! [`ThreadPool`] — rows within large GEMMs, whole experts within the MoE
//! block, and (sequence, head) pairs within attention — so decode keeps
//! every core busy even at B=1. Task partitioning never changes
//! per-element accumulation order, so outputs are bit-identical at every
//! pool size (pinned by `tests/thread_invariance.rs`).

use super::config::ModelConfig;
use super::hooks::{Hooks, TokenSelection};
use super::store::ExpertStore;
use super::weights::{ExpertDelta, ExpertWeights, LayerWeights, RemapReduce, RouterRemap, Weights};
use crate::tensor::ops::{rmsnorm, silu, softmax_inplace, topk_indices};
use crate::tensor::pool::ThreadPool;
use crate::tensor::{matmul_on, matmul_transb_on, simd, Mat};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Diagnostic output of one MoE layer (used by tests/analysis).
#[derive(Clone, Debug)]
pub struct MoeLayerOut {
    /// Per-expert token counts after any pruning.
    pub expert_tokens: Vec<usize>,
}

/// A runnable model: weights + expert store + forward implementations +
/// the worker pool all of its GEMMs and expert/head tasks run on.
pub struct Model {
    pub weights: Weights,
    /// Where routed expert weights live and how the forward pass fetches
    /// them: [`ExpertStore::Resident`] (all in `weights`, the default) or
    /// [`ExpertStore::Tiered`] (on disk, cached under a hard byte budget
    /// with selection-frequency-weighted LRU eviction — see
    /// [`crate::model::store`]). Swapping the store changes *when* expert
    /// bytes are resident, never the math: outputs are bit-identical at
    /// every budget.
    pub store: ExpertStore,
    /// Parallelism substrate for every forward-pass surface: row-parallel
    /// GEMMs, expert-level MoE dispatch, head-level attention. Swapping the
    /// pool changes scheduling only — outputs are bit-identical at every
    /// pool size (see `tests/thread_invariance.rs`).
    pub pool: Arc<ThreadPool>,
}

/// Storage precision for the KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPrecision {
    /// Full-precision f32 rows — bit-identical to the pre-quantization
    /// cache, the default.
    F32,
    /// Symmetric int8 per head per position: each appended K/V row is
    /// quantized one `head_dim` strip at a time with its own f32 scale
    /// (`amax / 127`), and dequantization is fused into the attention
    /// reads ([`crate::tensor::simd::dot_i8`] / `axpy_i8`) — the f32 row
    /// is never materialized again. ~4x smaller resident cache.
    Int8,
}

/// Rows the cache capacity grows by per reallocation. Chunked growth means
/// a short request never pays `max_seq` residency, and the byte metric
/// ([`KvCache::bytes`]) reflects what the request actually used.
const KV_GROW_ROWS: usize = 64;

/// One layer's K/V storage at the cache's precision. Capacity (`cap` rows)
/// is shared across layers and grows in [`KV_GROW_ROWS`] chunks.
#[derive(Clone)]
enum KvStore {
    F32 { k: Mat, v: Mat },
    Int8 { k: Vec<i8>, v: Vec<i8>, kscale: Vec<f32>, vscale: Vec<f32> },
}

/// Borrowed view of one layer for the attention inner loop.
enum KvLayerView<'a> {
    F32 { k: &'a Mat, v: &'a Mat },
    Int8 { k: &'a [i8], v: &'a [i8], kscale: &'a [f32], vscale: &'a [f32] },
}

/// KV cache for incremental decode: per layer, `len` rows of K and V at
/// [`KvPrecision`] storage (f32 `(cap, d_model)` Mats, or int8 codes with
/// per-head per-position scales). Filled either token-by-token by
/// [`Model::decode_step`] / [`Model::decode_step_batch`], or in one pass
/// by [`Model::prefill_into_cache`]. Capacity starts at zero and grows in
/// [`KV_GROW_ROWS`] chunks (capped at `max_seq`) as rows are appended.
#[derive(Clone)]
pub struct KvCache {
    layers: Vec<KvStore>,
    /// Number of valid positions (public: the engine and benches read and
    /// rewind it).
    pub len: usize,
    cap: usize,
    max_seq: usize,
    d: usize,
    heads: usize,
    hd: usize,
    precision: KvPrecision,
}

/// Quantize one head strip symmetrically to int8; returns the scale.
/// `amax == 0` yields scale 0.0 with all-zero codes (dequant gives 0.0).
fn quantize_head(src: &[f32], dst: &mut [i8]) -> f32 {
    let amax = src.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if amax == 0.0 {
        dst.iter_mut().for_each(|d| *d = 0);
        return 0.0;
    }
    let inv = 127.0 / amax;
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    amax / 127.0
}

impl KvCache {
    /// F32 cache (bit-identical to the historical eager-f32 cache in
    /// every read, minus the up-front `max_seq` allocation).
    pub fn new(cfg: &ModelConfig) -> Self {
        Self::with_precision(cfg, KvPrecision::F32)
    }

    /// Cache with an explicit storage precision (the engine maps
    /// `--kv-bits 8` to [`KvPrecision::Int8`]).
    pub fn with_precision(cfg: &ModelConfig, precision: KvPrecision) -> Self {
        let mk = || match precision {
            KvPrecision::F32 => KvStore::F32 { k: Mat::zeros(0, cfg.d_model), v: Mat::zeros(0, cfg.d_model) },
            KvPrecision::Int8 => {
                KvStore::Int8 { k: Vec::new(), v: Vec::new(), kscale: Vec::new(), vscale: Vec::new() }
            }
        };
        KvCache {
            layers: (0..cfg.n_layers).map(|_| mk()).collect(),
            len: 0,
            cap: 0,
            max_seq: cfg.max_seq,
            d: cfg.d_model,
            heads: cfg.n_heads,
            hd: cfg.head_dim(),
            precision,
        }
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    /// Currently allocated rows per layer (grows in [`KV_GROW_ROWS`]
    /// chunks; `len <= capacity <= max_seq`).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Resident bytes of the cache's backing storage across all layers —
    /// actual allocation, not the `max_seq` worst case.
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                KvStore::F32 { k, v } => (k.data.len() + v.data.len()) * 4,
                KvStore::Int8 { k, v, kscale, vscale } => {
                    k.len() + v.len() + (kscale.len() + vscale.len()) * 4
                }
            })
            .sum()
    }

    /// Grow every layer's storage to hold at least `rows` positions,
    /// rounding up to the next [`KV_GROW_ROWS`] chunk (capped at
    /// `max_seq`). New space is zero-filled; existing rows are untouched.
    fn ensure_capacity(&mut self, rows: usize) {
        assert!(rows <= self.max_seq, "kv cache beyond max_seq");
        if rows <= self.cap {
            return;
        }
        let new_cap = rows.div_ceil(KV_GROW_ROWS).saturating_mul(KV_GROW_ROWS).min(self.max_seq);
        for l in &mut self.layers {
            match l {
                KvStore::F32 { k, v } => {
                    k.data.resize(new_cap * self.d, 0.0);
                    k.rows = new_cap;
                    v.data.resize(new_cap * self.d, 0.0);
                    v.rows = new_cap;
                }
                KvStore::Int8 { k, v, kscale, vscale } => {
                    k.resize(new_cap * self.d, 0);
                    v.resize(new_cap * self.d, 0);
                    kscale.resize(new_cap * self.heads, 0.0);
                    vscale.resize(new_cap * self.heads, 0.0);
                }
            }
        }
        self.cap = new_cap;
    }

    /// Store one position's K/V rows (capacity must already cover `pos`).
    /// F32 stores the rows verbatim; Int8 quantizes per head strip.
    fn write_row(&mut self, li: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(pos < self.cap);
        let (d, heads, hd) = (self.d, self.heads, self.hd);
        match &mut self.layers[li] {
            KvStore::F32 { k, v } => {
                k.row_mut(pos).copy_from_slice(krow);
                v.row_mut(pos).copy_from_slice(vrow);
            }
            KvStore::Int8 { k, v, kscale, vscale } => {
                for head in 0..heads {
                    let off = head * hd;
                    kscale[pos * heads + head] =
                        quantize_head(&krow[off..off + hd], &mut k[pos * d + off..pos * d + off + hd]);
                    vscale[pos * heads + head] =
                        quantize_head(&vrow[off..off + hd], &mut v[pos * d + off..pos * d + off + hd]);
                }
            }
        }
    }

    /// Prefill export: store `k.rows` positions of layer `li` (the K/V
    /// projections of one prompt span) starting at position `base`,
    /// growing capacity as needed. Whole-prompt prefill exports at
    /// `base == 0`; chunked prefill exports each chunk at the number of
    /// positions already cached. Int8 caches quantize here too, so decode
    /// continues from exactly the same stored representation a
    /// token-by-token append would build.
    fn export_layer(&mut self, li: usize, base: usize, k: &Mat, v: &Mat) {
        self.ensure_capacity(base + k.rows);
        for r in 0..k.rows {
            self.write_row(li, base + r, k.row(r), v.row(r));
        }
    }

    fn layer(&self, li: usize) -> KvLayerView<'_> {
        debug_assert!(li < self.layers.len(), "kv cache layer {li} out of {}", self.layers.len());
        match &self.layers[li] {
            KvStore::F32 { k, v } => KvLayerView::F32 { k, v },
            KvStore::Int8 { k, v, kscale, vscale } => {
                KvLayerView::Int8 { k, v, kscale, vscale }
            }
        }
    }

    /// Dequantized K row at `pos` (f32 passthrough) — test/inspection
    /// accessor, not a hot path.
    pub fn k_row(&self, li: usize, pos: usize) -> Vec<f32> {
        self.read_row(li, pos, true)
    }

    /// Dequantized V row at `pos` (f32 passthrough).
    pub fn v_row(&self, li: usize, pos: usize) -> Vec<f32> {
        self.read_row(li, pos, false)
    }

    fn read_row(&self, li: usize, pos: usize, want_k: bool) -> Vec<f32> {
        assert!(pos < self.len, "kv row {pos} beyond len {}", self.len);
        match &self.layers[li] {
            KvStore::F32 { k, v } => if want_k { k.row(pos) } else { v.row(pos) }.to_vec(),
            KvStore::Int8 { k, v, kscale, vscale } => {
                let (codes, scales) = if want_k { (k, kscale) } else { (v, vscale) };
                (0..self.d)
                    .map(|t| codes[pos * self.d + t] as f32 * scales[pos * self.heads + t / self.hd])
                    .collect()
            }
        }
    }
}

impl Model {
    /// Model on the process-global pool (sized from `EAC_MOE_THREADS` at
    /// that pool's construction).
    pub fn new(weights: Weights) -> Self {
        Model { weights, store: ExpertStore::Resident, pool: ThreadPool::global().clone() }
    }

    /// Model on an explicit pool — how `EngineConfig::threads` and the
    /// thread-invariance tests control concurrency deterministically.
    pub fn with_pool(weights: Weights, pool: Arc<ThreadPool>) -> Self {
        Model { weights, store: ExpertStore::Resident, pool }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.weights.cfg
    }

    /// Full-sequence (prefill) forward. Returns logits (seq, vocab).
    pub fn forward(&self, tokens: &[u32]) -> Mat {
        self.forward_with_hooks(tokens, &Hooks::none())
    }

    /// Prefill forward with hooks.
    pub fn forward_with_hooks(&self, tokens: &[u32], hooks: &Hooks) -> Mat {
        self.forward_full(tokens, hooks, None)
    }

    /// Prefill that also exports each layer's K/V projections into `cache`,
    /// leaving it ready for [`Model::decode_step`] /
    /// [`Model::decode_step_batch`] at position `tokens.len()`. This is the
    /// serving engine's single-pass prompt path: with the same `hooks`, the
    /// K/V written here are bit-identical to what a token-by-token
    /// [`Model::decode_step`] replay of the prompt would produce (same
    /// per-row GEMMs, same accumulation order), so decode can continue from
    /// the prefill directly instead of re-computing the prompt.
    ///
    /// Note that with pruning hooks (PESF/EES/ODP) the exported K/V is the
    /// *pruned* prefill's — decode continues from the prompt the request
    /// actually saw, as a deployed system would, rather than from a second
    /// unpruned prompt pass like the old engine's replay did.
    pub fn prefill_into_cache(&self, tokens: &[u32], hooks: &Hooks, cache: &mut KvCache) -> Mat {
        assert_eq!(cache.len, 0, "prefill_into_cache requires an empty cache");
        let logits = self.forward_full(tokens, hooks, Some(cache));
        cache.len = tokens.len();
        logits
    }

    /// Resumable chunked prefill: forward `chunk` (the next span of a
    /// prompt) against the `cache.len` positions already prefilled into
    /// `cache`, exporting the chunk's K/V at that offset and advancing
    /// `cache.len`. Returns logits `(chunk.len(), vocab)` for the chunk's
    /// positions. Calling this over a prompt split at any chunk
    /// boundaries produces — bit for bit — the same logits rows, cache
    /// contents, and subsequent decode as one [`Model::prefill_into_cache`]
    /// pass: chunk size changes *scheduling only*, never the math. That
    /// holds because every per-position value depends only on positions
    /// `<= t`: the chunk's Q/K/V projections are row-independent GEMMs,
    /// attention reads prior K/V verbatim from the f32 cache (why this
    /// entry point requires [`KvPrecision::F32`] — an int8 cache would
    /// make the chunked pass read dequantized history the monolithic pass
    /// never sees), the causal mask keeps masked score entries exactly
    /// 0.0 (skipped identically by the GEMM accumulate at any width), and
    /// the MoE block is per-token.
    ///
    /// Hooks are applied per chunk: sequence-level statistics (PESF's
    /// Eq. 6 counts, selection records) would see each chunk as its own
    /// sequence, so callers that prune during prefill must use the
    /// monolithic path — the engine only chunks under `PrunePolicy::None`.
    pub fn prefill_chunk_into_cache(
        &self,
        chunk: &[u32],
        hooks: &Hooks,
        cache: &mut KvCache,
    ) -> Mat {
        let cfg = &self.weights.cfg;
        let base = cache.len;
        assert!(!chunk.is_empty(), "empty prefill chunk");
        assert!(base + chunk.len() <= cfg.max_seq, "sequence too long");
        assert!(
            cache.precision() == KvPrecision::F32,
            "chunked prefill requires an f32 KV cache (int8 history is not \
             bit-identical to the monolithic prefill's f32 reads)"
        );
        // Grow once, before the layer loop: capacity is shared across
        // layers, so per-layer exports below are plain writes.
        cache.ensure_capacity(base + chunk.len());
        let mut x = Mat::zeros(chunk.len(), cfg.d_model);
        for (i, &t) in chunk.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.weights.embed.row(t as usize));
        }
        for (li, layer) in self.weights.layers.iter().enumerate() {
            let normed = rmsnorm(&x, &layer.attn_norm, 1e-6);
            let attn = self.attention_chunk(&normed, layer, li, base, cache);
            for r in 0..x.rows {
                crate::tensor::ops::add_inplace(x.row_mut(r), attn.row(r));
            }
            let normed = rmsnorm(&x, &layer.ffn_norm, 1e-6);
            let (moe, _diag) = self.moe_layer(&normed, layer, li, hooks);
            for r in 0..x.rows {
                crate::tensor::ops::add_inplace(x.row_mut(r), moe.row(r));
            }
        }
        cache.len = base + chunk.len();
        let normed = rmsnorm(&x, &self.weights.final_norm, 1e-6);
        matmul_transb_on(&self.pool, &normed, &self.weights.embed)
    }

    /// Causal MHSA for one prefill chunk: queries are the chunk's
    /// `x.rows` positions; keys/values are the `base` cached positions
    /// plus the chunk's own projections (exported into `cache` at offset
    /// `base` first). Same head-parallel GEMM formulation as
    /// [`Model::attention`]; the causal boundary for chunk row `i` is the
    /// absolute position `base + i`.
    fn attention_chunk(
        &self,
        x: &Mat,
        layer: &LayerWeights,
        li: usize,
        base: usize,
        cache: &mut KvCache,
    ) -> Mat {
        let cfg = &self.weights.cfg;
        let (rows, d) = (x.rows, cfg.d_model);
        let total = base + rows;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let pool = &*self.pool;
        let q = layer.wq.matmul_on(pool, x);
        let k = layer.wk.matmul_on(pool, x);
        let v = layer.wv.matmul_on(pool, x);
        cache.export_layer(li, base, &k, &v);
        debug_assert!(h * hd == d && q.cols == d, "n_heads * head_dim must equal d_model");
        let scale = 1.0 / (hd as f32).sqrt();
        // Prior K/V come back out of the cache verbatim (f32 rows, checked
        // by the caller), so the assembled per-head kh/vh equal what a
        // monolithic pass would have projected for those positions.
        let cache: &KvCache = cache;
        let mut head_ctx: Vec<Mat> = (0..h).map(|_| Mat::zeros(0, 0)).collect();
        pool.scope(|s| {
            for (head, slot) in head_ctx.iter_mut().enumerate() {
                let (q, k, v) = (&q, &k, &v);
                s.spawn(move || {
                    let off = head * hd;
                    let mut qh = Mat::zeros(rows, hd);
                    let mut kh = Mat::zeros(total, hd);
                    let mut vh = Mat::zeros(total, hd);
                    if let KvLayerView::F32 { k: ck, v: cv } = cache.layer(li) {
                        for r in 0..base {
                            kh.row_mut(r).copy_from_slice(&ck.row(r)[off..off + hd]);
                            vh.row_mut(r).copy_from_slice(&cv.row(r)[off..off + hd]);
                        }
                    }
                    for r in 0..rows {
                        qh.row_mut(r).copy_from_slice(&q.row(r)[off..off + hd]);
                        kh.row_mut(base + r).copy_from_slice(&k.row(r)[off..off + hd]);
                        vh.row_mut(base + r).copy_from_slice(&v.row(r)[off..off + hd]);
                    }
                    // S = Q Kᵀ (scaled), causal mask at the absolute
                    // position, row softmax over j <= base + i. Masked
                    // entries are exactly 0.0, so the P V accumulate sums
                    // the same nonzero term set in the same ascending-k
                    // order as the monolithic pass: bit-identical rows.
                    let mut scores = matmul_transb_on(pool, &qh, &kh);
                    for i in 0..rows {
                        let limit = base + i;
                        let row = scores.row_mut(i);
                        for s in row[..=limit].iter_mut() {
                            *s *= scale;
                        }
                        softmax_inplace(&mut row[..=limit]);
                        for s in row[limit + 1..].iter_mut() {
                            *s = 0.0; // masked out: contributes nothing to P V
                        }
                    }
                    *slot = matmul_on(pool, &scores, &vh);
                });
            }
        });
        let mut ctx = Mat::zeros(rows, d);
        for (head, ctx_h) in head_ctx.into_iter().enumerate() {
            let off = head * hd;
            // The scope above barriers until every head task replaced its
            // placeholder; a 0x0 entry here would be a scheduler bug.
            debug_assert!(ctx_h.rows == rows && ctx_h.cols == hd, "head {head} output shape");
            for r in 0..rows {
                ctx.row_mut(r)[off..off + hd].copy_from_slice(ctx_h.row(r));
            }
        }
        layer.wo.matmul_on(pool, &ctx)
    }

    fn forward_full(&self, tokens: &[u32], hooks: &Hooks, mut cache: Option<&mut KvCache>) -> Mat {
        let cfg = &self.weights.cfg;
        assert!(tokens.len() <= cfg.max_seq, "sequence too long");
        // Embed.
        let mut x = Mat::zeros(tokens.len(), cfg.d_model);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.weights.embed.row(t as usize));
        }
        // Transformer layers.
        for (li, layer) in self.weights.layers.iter().enumerate() {
            // --- MHSA block (pre-norm, residual) ---
            let normed = rmsnorm(&x, &layer.attn_norm, 1e-6);
            if let Some(cap) = &hooks.capture_mhsa_inputs {
                cap.borrow_mut()[li] = Some(normed.clone());
            }
            let attn = self.attention(&normed, layer, li, hooks, cache.as_deref_mut());
            for r in 0..x.rows {
                crate::tensor::ops::add_inplace(x.row_mut(r), attn.row(r));
            }
            // --- MoE block (pre-norm, residual) ---
            let normed = rmsnorm(&x, &layer.ffn_norm, 1e-6);
            if let Some(cap) = &hooks.capture_moe_inputs {
                cap.borrow_mut()[li] = Some(normed.clone());
            }
            let (moe, _diag) = self.moe_layer(&normed, layer, li, hooks);
            for r in 0..x.rows {
                crate::tensor::ops::add_inplace(x.row_mut(r), moe.row(r));
            }
        }
        // Final norm + tied output head.
        let normed = rmsnorm(&x, &self.weights.final_norm, 1e-6);
        matmul_transb_on(&self.pool, &normed, &self.weights.embed)
    }

    /// Causal multi-head self-attention over the full sequence.
    ///
    /// GEMM-formulated (per head: S = Q Kᵀ, causal-masked row softmax,
    /// C = P V) so it rides the blocked matmul instead of scalar loops —
    /// the §Perf attention optimization (EXPERIMENTS.md §Perf). Heads are
    /// independent, so each head's whole chain runs as one pool task;
    /// assembling `ctx` from the per-head outputs is a pure copy into
    /// disjoint column strips, so task order cannot change the result and
    /// outputs stay bit-identical to the sequential loop.
    ///
    /// When `kv_export` is given, the layer's K/V projections are stored
    /// into the cache row-per-position at the cache's own precision (the
    /// prefill KV export feeding the decode cache).
    fn attention(
        &self,
        x: &Mat,
        layer: &LayerWeights,
        li: usize,
        hooks: &Hooks,
        kv_export: Option<&mut KvCache>,
    ) -> Mat {
        let cfg = &self.weights.cfg;
        let (seq, d) = (x.rows, cfg.d_model);
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let pool = &*self.pool;
        let q = layer.wq.matmul_on(pool, x);
        let k = layer.wk.matmul_on(pool, x);
        let v = layer.wv.matmul_on(pool, x);
        if let Some(c) = kv_export {
            c.export_layer(li, 0, &k, &v);
        }
        // Head strips `off..off + hd` stay inside the d_model projection
        // rows only under this contract; it also bounds the copies below.
        debug_assert!(h * hd == d && q.cols == d, "n_heads * head_dim must equal d_model");
        let scale = 1.0 / (hd as f32).sqrt();
        // Placeholder 0x0 mats; each head task overwrites its own slot and
        // the scope barriers until all have run.
        let mut head_ctx: Vec<Mat> = (0..h).map(|_| Mat::zeros(0, 0)).collect();
        pool.scope(|s| {
            for (head, slot) in head_ctx.iter_mut().enumerate() {
                let (q, k, v) = (&q, &k, &v);
                s.spawn(move || {
                    let off = head * hd;
                    let mut qh = Mat::zeros(seq, hd);
                    let mut kh = Mat::zeros(seq, hd);
                    let mut vh = Mat::zeros(seq, hd);
                    for r in 0..seq {
                        qh.row_mut(r).copy_from_slice(&q.row(r)[off..off + hd]);
                        kh.row_mut(r).copy_from_slice(&k.row(r)[off..off + hd]);
                        vh.row_mut(r).copy_from_slice(&v.row(r)[off..off + hd]);
                    }
                    // S = Q Kᵀ (scaled), causal mask, row softmax over j <= i.
                    let mut scores = matmul_transb_on(pool, &qh, &kh);
                    for i in 0..seq {
                        let row = scores.row_mut(i);
                        for s in row[..=i].iter_mut() {
                            *s *= scale;
                        }
                        softmax_inplace(&mut row[..=i]);
                        for s in row[i + 1..].iter_mut() {
                            *s = 0.0; // masked out: contributes nothing to P V
                        }
                    }
                    *slot = matmul_on(pool, &scores, &vh);
                });
            }
        });
        let mut ctx = Mat::zeros(seq, d);
        for (head, ctx_h) in head_ctx.into_iter().enumerate() {
            let off = head * hd;
            // The scope above barriers until every head task replaced its
            // placeholder; a 0x0 entry here would be a scheduler bug.
            debug_assert!(ctx_h.rows == seq && ctx_h.cols == hd, "head {head} output shape");
            for r in 0..seq {
                ctx.row_mut(r)[off..off + hd].copy_from_slice(ctx_h.row(r));
            }
        }
        if let Some(cap) = &hooks.capture_wo_inputs {
            cap.borrow_mut()[li] = Some(ctx.clone());
        }
        layer.wo.matmul_on(pool, &ctx)
    }

    /// Route tokens, execute (unpruned) experts grouped by expert, and add
    /// shared experts. Returns (output, diagnostics).
    ///
    /// A layer with an installed router remap (expert merging) dispatches
    /// to [`Model::moe_layer_merged`] on its first line; the unmerged body
    /// below is untouched by that feature, which is what makes the
    /// threshold=1.0 "merge nothing" contract structurally bit-identical
    /// rather than merely numerically so.
    pub fn moe_layer(
        &self,
        x: &Mat,
        layer: &LayerWeights,
        li: usize,
        hooks: &Hooks,
    ) -> (Mat, MoeLayerOut) {
        if let Some(rm) = layer.remap() {
            return self.moe_layer_merged(x, layer, rm, li, hooks);
        }
        let cfg = &self.weights.cfg;
        let seq = x.rows;
        let n = cfg.n_experts;
        let k = cfg.top_k;
        if let Some(rows) = &hooks.seq_expert_masks {
            assert_eq!(rows.len(), seq, "one seq-mask slot per row");
        }

        // Router logits + softmax scores. The softmax runs *in place* over
        // the router-GEMM output — this is once per layer per decode step,
        // and the old per-call `logits.clone()` was pure allocator traffic.
        // Only the capture hook (calibration-time) still pays for a copy of
        // the raw logits.
        let pool = &*self.pool;
        let mut scores = matmul_on(pool, x, &layer.router);
        if let Some(cap) = &hooks.capture_router_logits {
            cap.borrow_mut()[li] = Some(scores.clone());
        }
        for r in 0..seq {
            softmax_inplace(scores.row_mut(r));
        }

        // Per-token selections (or forced replay).
        let mut selections: Vec<TokenSelection> = Vec::with_capacity(seq);
        for t in 0..seq {
            let mut sel = if let Some(forced) = &hooks.force_selections {
                forced.record.layers[li][t].clone()
            } else {
                let idx = topk_indices(scores.row(t), k);
                TokenSelection {
                    experts: idx.iter().map(|&e| e as u16).collect(),
                    scores: idx.iter().map(|&e| scores.at(t, e)).collect(),
                }
            };
            if let Some(filter) = &hooks.selection_filter {
                let before = sel.experts.len();
                filter(li, t, x.row(t), &mut sel);
                if let Some(stats) = &hooks.filter_drops {
                    let mut s = stats.borrow_mut();
                    s.seen += before as u64;
                    s.dropped += (before - sel.experts.len()) as u64;
                }
            }
            selections.push(sel);
        }
        if let Some(rec) = &hooks.record_selections {
            let mut rec = rec.borrow_mut();
            rec.layers[li].extend(selections.iter().cloned());
        }

        // PESF (Eq. 6): derive this layer's prune mask from this sequence's
        // own selection counts — a single counting pass between routing and
        // expert dispatch.
        let pesf_mask: Option<Vec<bool>> = hooks.pesf_alpha.map(|alpha| {
            let mut counts = vec![0u64; n];
            for sel in &selections {
                for &e in &sel.experts {
                    counts[e as usize] += 1;
                }
            }
            let thr = (seq * k) as f32 / n as f32 * alpha;
            counts.iter().map(|&c| alpha > 0.0 && (c as f32) < thr).collect()
        });
        if let (Some(stats), Some(mask)) = (&hooks.pesf_pruned, &pesf_mask) {
            stats.borrow_mut()[li] = mask.iter().filter(|&&m| m).count();
        }

        // Group token-slots by expert, applying the prune masks. Masks are
        // per (token, expert): the global `expert_mask` and the in-layer
        // PESF mask apply to every token, while `seq_expert_masks` is
        // row-indexed so each decode-batch sequence prunes by its own
        // statistics.
        let masked = |t: usize, e: usize| {
            hooks.expert_mask.as_ref().map(|m| m[li][e]).unwrap_or(false)
                || pesf_mask.as_ref().map(|m| m[e]).unwrap_or(false)
                || hooks
                    .seq_expert_masks
                    .as_ref()
                    .and_then(|rows| rows[t].as_ref())
                    .map(|m| m[li][e])
                    .unwrap_or(false)
        };
        // For each token: surviving (expert, score) pairs, renormalized.
        let mut out = Mat::zeros(seq, cfg.d_model);
        let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n]; // expert -> (token, weight)
        for (t, sel) in selections.iter().enumerate() {
            let survivors: Vec<(usize, f32)> = sel
                .experts
                .iter()
                .zip(&sel.scores)
                .filter(|(e, _)| !masked(t, **e as usize))
                .map(|(&e, &s)| (e as usize, s))
                .collect();
            let denom: f32 = survivors.iter().map(|(_, s)| *s).sum();
            if denom <= 0.0 {
                continue; // all selected experts pruned: MoE contributes 0
            }
            for (e, s) in survivors {
                groups[e].push((t, s / denom));
            }
        }

        // Prefetch: routing has just determined exactly which experts are
        // about to run, so fetch all of their guard handles from the
        // expert store in one batch *before* the expert GEMMs. On a
        // Resident store these are Arc clones; on a Tiered store this is
        // the load point — misses stall here (once, together), never
        // inside the compute tasks — and the per-expert routed-token
        // counts feed the store's selection-frequency eviction signal
        // (the same counts PESF thresholds in Eq. 6). Pruned experts are
        // never fetched, so PESF's compute savings double as residency
        // savings under a tiered store.
        let wants: Vec<(usize, usize)> = groups
            .iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .map(|(e, g)| (e, g.len()))
            .collect();
        let fetched = self.experts_for_layer(li, &wants);
        let mut handles: Vec<Option<Arc<ExpertWeights>>> = (0..n).map(|_| None).collect();
        for (&(e, _), h) in wants.iter().zip(fetched) {
            handles[e] = Some(h);
        }

        // Execute each expert on its gathered tokens as one GEMM. Experts
        // (routed and shared) are independent, so each gather → SwiGLU runs
        // as its own pool task — decode-time MoE uses every core even at
        // B=1, which is where the PESF latency claim lives. The scatter
        // below stays sequential in ascending expert order, so every
        // token's output accumulates in exactly the order the old
        // sequential loop used: bit-identical at every pool size.
        let shared = layer.shared();
        let mut expert_out: Vec<Option<Mat>> = (0..n).map(|_| None).collect();
        // Placeholder 0x0 mats; each shared-expert task overwrites its own
        // slot and the scope barriers until all have run.
        let mut shared_out: Vec<Mat> = (0..shared.len()).map(|_| Mat::zeros(0, 0)).collect();
        pool.scope(|s| {
            for ((e, group), slot) in groups.iter().enumerate().zip(expert_out.iter_mut()) {
                if group.is_empty() {
                    continue;
                }
                // The prefetch loop above filled `handles[e]` for every
                // non-empty group (same `groups` iteration). If that ever
                // regressed, skipping the group (those tokens fall back to
                // shared experts only) beats unwinding mid-batch.
                debug_assert!(handles[e].is_some(), "prefetch missed expert {e}");
                let Some(h) = handles[e].as_ref() else { continue };
                s.spawn(move || {
                    let token_ids: Vec<usize> = group.iter().map(|(t, _)| *t).collect();
                    let gathered = x.gather_rows(&token_ids);
                    *slot = Some(expert_forward_on(pool, &gathered, h));
                });
            }
            for (sh, slot) in shared.iter().zip(shared_out.iter_mut()) {
                s.spawn(move || *slot = expert_forward_on(pool, x, sh));
            }
        });
        let mut expert_tokens = vec![0usize; n];
        for ((e, group), y) in groups.iter().enumerate().zip(expert_out) {
            let Some(y) = y else { continue };
            expert_tokens[e] = group.len();
            for (row, &(t, w)) in group.iter().enumerate() {
                crate::tensor::ops::axpy(out.row_mut(t), w, y.row(row));
            }
        }

        // Shared experts: always-on, added with weight 1 (DeepSeek-MoE style).
        for y in shared_out {
            // One spawned task per shared expert, barriered by the scope
            // above — a 0x0 placeholder here would be a scheduler bug.
            debug_assert!(y.rows == seq, "shared expert output shape");
            for t in 0..seq {
                crate::tensor::ops::add_inplace(out.row_mut(t), y.row(t));
            }
        }

        (out, MoeLayerOut { expert_tokens })
    }

    /// MoE layer over a **merged** expert set (see `prune::merge`): the
    /// router still emits one logit per original expert; this path reduces
    /// those to one logit per merged cluster (max or sum per the remap),
    /// routes softmax/top-k/PESF over the merged width, and executes each
    /// selected cluster as its base expert plus the low-rank delta of the
    /// cluster member whose raw logit won for that token — so a cluster of
    /// near-duplicates still specializes per token at a fraction of the
    /// weight bytes.
    ///
    /// Everything downstream of routing sees merged ids: selection
    /// records, PESF masks, `seq_expert_masks` rows and
    /// `MoeLayerOut::expert_tokens` are all `n_merged` wide.
    ///
    /// Determinism contract matches [`Model::moe_layer`]: grouping is by
    /// `(merged id, winning old id)` in a BTreeMap, execution parallelism
    /// never splits a group, and the scatter walks groups in ascending key
    /// order — bit-identical at every pool size and store budget.
    fn moe_layer_merged(
        &self,
        x: &Mat,
        layer: &LayerWeights,
        rm: &RouterRemap,
        li: usize,
        hooks: &Hooks,
    ) -> (Mat, MoeLayerOut) {
        let cfg = &self.weights.cfg;
        let seq = x.rows;
        let n_old = rm.map.len();
        let n = rm.n_merged;
        // A merge can leave fewer clusters than top_k in a layer.
        let k = cfg.top_k.min(n);
        if let Some(rows) = &hooks.seq_expert_masks {
            assert_eq!(rows.len(), seq, "one seq-mask slot per row");
        }

        let pool = &*self.pool;
        let raw = matmul_on(pool, x, &layer.router);
        debug_assert!(raw.cols == n_old, "router width {} != remap width {n_old}", raw.cols);
        // Calibration captures see the raw per-old-expert logits — the
        // gate itself is unchanged by merging.
        if let Some(cap) = &hooks.capture_router_logits {
            cap.borrow_mut()[li] = Some(raw.clone());
        }
        // Reduce old-id logits to merged-id logits, remembering per
        // (token, merged id) which member's raw logit won — that member's
        // delta is applied on top of the cluster base. Strict `>` keeps
        // the lowest old id on ties, deterministically.
        let mut scores = Mat::zeros(seq, n);
        let mut winners: Vec<u16> = vec![0; seq * n];
        let mut best: Vec<f32> = vec![f32::NEG_INFINITY; n];
        for t in 0..seq {
            best.iter_mut().for_each(|b| *b = f32::NEG_INFINITY);
            let row = raw.row(t);
            let srow = scores.row_mut(t);
            for (o, &logit) in row.iter().enumerate() {
                let m = rm.map[o] as usize;
                debug_assert!(m < n, "remap target {m} out of {n}");
                if logit > best[m] {
                    best[m] = logit;
                    winners[t * n + m] = o as u16;
                }
                match rm.reduce {
                    // First member seen for m overwrites the zero init;
                    // `best` doubles as the "seen" flag (still -inf).
                    RemapReduce::Max => srow[m] = best[m],
                    RemapReduce::Sum => srow[m] += logit,
                }
            }
            softmax_inplace(srow);
        }

        // Per-token selections over merged ids (or forced replay, which by
        // contract was recorded against this same merged width).
        let mut selections: Vec<TokenSelection> = Vec::with_capacity(seq);
        for t in 0..seq {
            let mut sel = if let Some(forced) = &hooks.force_selections {
                forced.record.layers[li][t].clone()
            } else {
                let idx = topk_indices(scores.row(t), k);
                TokenSelection {
                    experts: idx.iter().map(|&e| e as u16).collect(),
                    scores: idx.iter().map(|&e| scores.at(t, e)).collect(),
                }
            };
            if let Some(filter) = &hooks.selection_filter {
                let before = sel.experts.len();
                filter(li, t, x.row(t), &mut sel);
                if let Some(stats) = &hooks.filter_drops {
                    let mut s = stats.borrow_mut();
                    s.seen += before as u64;
                    s.dropped += (before - sel.experts.len()) as u64;
                }
            }
            selections.push(sel);
        }
        if let Some(rec) = &hooks.record_selections {
            let mut rec = rec.borrow_mut();
            rec.layers[li].extend(selections.iter().cloned());
        }

        // PESF (Eq. 6) over the merged width: the threshold divisor is the
        // number of ids a token can actually select here, `n_merged`.
        let pesf_mask: Option<Vec<bool>> = hooks.pesf_alpha.map(|alpha| {
            let mut counts = vec![0u64; n];
            for sel in &selections {
                for &e in &sel.experts {
                    debug_assert!((e as usize) < n, "merged selection id {e} out of {n}");
                    counts[e as usize] += 1;
                }
            }
            let thr = (seq * k) as f32 / n as f32 * alpha;
            counts.iter().map(|&c| alpha > 0.0 && (c as f32) < thr).collect()
        });
        if let (Some(stats), Some(mask)) = (&hooks.pesf_pruned, &pesf_mask) {
            stats.borrow_mut()[li] = mask.iter().filter(|&&m| m).count();
        }

        // Same mask semantics as the unmerged path; all indices are merged
        // ids (mask providers must build rows of width >= n_merged).
        let masked = |t: usize, e: usize| {
            hooks.expert_mask.as_ref().map(|m| m[li][e]).unwrap_or(false)
                || pesf_mask.as_ref().map(|m| m[e]).unwrap_or(false)
                || hooks
                    .seq_expert_masks
                    .as_ref()
                    .and_then(|rows| rows[t].as_ref())
                    .map(|m| m[li][e])
                    .unwrap_or(false)
        };

        // Group tokens by (merged id, winning old id): every token in a
        // group runs the same base + the same delta, as one gathered GEMM
        // chain. BTreeMap iteration gives ascending key order for both the
        // prefetch lists and the scatter below.
        let mut out = Mat::zeros(seq, cfg.d_model);
        let mut groups: BTreeMap<(usize, usize), Vec<(usize, f32)>> = BTreeMap::new();
        for (t, sel) in selections.iter().enumerate() {
            let survivors: Vec<(usize, f32)> = sel
                .experts
                .iter()
                .zip(&sel.scores)
                .filter(|(e, _)| !masked(t, **e as usize))
                .map(|(&e, &s)| (e as usize, s))
                .collect();
            let denom: f32 = survivors.iter().map(|(_, s)| *s).sum();
            if denom <= 0.0 {
                continue; // all selected clusters pruned: MoE contributes 0
            }
            for (m, s) in survivors {
                debug_assert!(m < n, "selected merged id {m} out of {n}");
                let o = winners[t * n + m] as usize;
                groups.entry((m, o)).or_default().push((t, s / denom));
            }
        }

        // Prefetch bases (by merged id) and deltas (by winning old id) in
        // one batch each. Bases are always resident — even under a tiered
        // store only deltas tier — so the base fetch is an Arc clone;
        // the delta fetch is the tiered load point and feeds the store's
        // frequency signal with per-old-id routed-token counts.
        let mut m_counts = vec![0usize; n];
        let mut o_counts: BTreeMap<usize, usize> = BTreeMap::new();
        for (&(m, o), g) in &groups {
            m_counts[m] += g.len();
            *o_counts.entry(o).or_insert(0) += g.len();
        }
        let base_wants: Vec<(usize, usize)> = m_counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(m, &c)| (m, c))
            .collect();
        let delta_wants: Vec<(usize, usize)> = o_counts.into_iter().collect();
        let fetched = self.experts_for_layer(li, &base_wants);
        let mut base_handles: Vec<Option<Arc<ExpertWeights>>> = (0..n).map(|_| None).collect();
        for (&(m, _), h) in base_wants.iter().zip(fetched) {
            base_handles[m] = Some(h);
        }
        let dfetched = self.deltas_for_layer(li, &delta_wants);
        let mut delta_handles: BTreeMap<usize, Option<Arc<ExpertDelta>>> = BTreeMap::new();
        for (&(o, _), d) in delta_wants.iter().zip(dfetched) {
            delta_handles.insert(o, d);
        }

        // Execute each (base, delta) group as one pool task; scatter
        // sequentially in ascending (merged, old) order.
        let shared = layer.shared();
        let group_list: Vec<(&(usize, usize), &Vec<(usize, f32)>)> = groups.iter().collect();
        let mut group_out: Vec<Option<Mat>> = (0..group_list.len()).map(|_| None).collect();
        let mut shared_out: Vec<Mat> = (0..shared.len()).map(|_| Mat::zeros(0, 0)).collect();
        pool.scope(|s| {
            for ((&(m, o), group), slot) in group_list.iter().copied().zip(group_out.iter_mut()) {
                // The prefetch loops above covered every group key; a miss
                // means those tokens fall back to shared experts only,
                // which beats unwinding mid-batch.
                debug_assert!(base_handles[m].is_some(), "prefetch missed merged expert {m}");
                let Some(h) = base_handles[m].as_ref() else { continue };
                let delta = delta_handles.get(&o).and_then(|d| d.as_deref());
                s.spawn(move || {
                    let token_ids: Vec<usize> = group.iter().map(|(t, _)| *t).collect();
                    let gathered = x.gather_rows(&token_ids);
                    *slot = Some(expert_forward_delta_on(pool, &gathered, h, delta));
                });
            }
            for (sh, slot) in shared.iter().zip(shared_out.iter_mut()) {
                s.spawn(move || *slot = expert_forward_on(pool, x, sh));
            }
        });
        let mut expert_tokens = vec![0usize; n];
        for ((&(m, _), group), y) in group_list.iter().copied().zip(group_out) {
            let Some(y) = y else { continue };
            expert_tokens[m] += group.len();
            for (row, &(t, w)) in group.iter().enumerate() {
                crate::tensor::ops::axpy(out.row_mut(t), w, y.row(row));
            }
        }
        for y in shared_out {
            debug_assert!(y.rows == seq, "shared expert output shape");
            for t in 0..seq {
                crate::tensor::ops::add_inplace(out.row_mut(t), y.row(t));
            }
        }

        (out, MoeLayerOut { expert_tokens })
    }

    /// Single-token decode step with kv cache (generate stage). PESF
    /// reaches decode through the hooks: `Hooks::seq_expert_masks` (one
    /// row here) and the global masks all apply. Thin wrapper over
    /// [`Model::decode_step_batch`] with B=1, so the two paths cannot
    /// drift.
    pub fn decode_step(&self, token: u32, cache: &mut KvCache, hooks: &Hooks) -> Vec<f32> {
        self.decode_step_batch(&[token], std::slice::from_mut(cache), hooks).data
    }

    /// Batched decode: advance B independent sequences one token each.
    /// `tokens[b]` is appended to `caches[b]` (caches may hold different
    /// lengths); returns logits `(B, vocab)`.
    ///
    /// The projections, router and experts all run over the B-row batch as
    /// single GEMMs — [`Model::moe_layer`] gathers tokens routed to the
    /// same expert *across the whole batch*, which is where MoE batching
    /// wins: with B sequences decoding together, an expert touched by any
    /// of them amortizes its (de)quantized weight traffic over all its
    /// routed tokens instead of re-reading weights per sequence.
    ///
    /// Per-sequence pruning: `hooks.seq_expert_masks[b]` (if set) is
    /// sequence `b`'s `layer × expert` PESF mask; [`Model::moe_layer`]
    /// drops that row's masked experts from its survivor set and
    /// renormalizes the remaining top-k scores, so a pruned expert
    /// selected only by masked rows never runs at all.
    ///
    /// Per-row results are bit-identical to the B=1 path: every op here is
    /// row-independent with a fixed accumulation order (the blocked GEMM
    /// partitions by row; rmsnorm/softmax are per-row; each row's mask
    /// travels with it), so batch composition cannot change any
    /// sequence's output.
    pub fn decode_step_batch(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
        hooks: &Hooks,
    ) -> Mat {
        let cfg = &self.weights.cfg;
        let bsz = tokens.len();
        assert_eq!(bsz, caches.len(), "one kv cache per sequence");
        assert!(bsz > 0, "empty decode batch");
        for c in caches.iter_mut() {
            assert!(c.len < cfg.max_seq, "kv cache full");
            // Grow once per step, before the layer loop: capacity is
            // shared across layers, so the per-layer appends below are
            // plain writes.
            c.ensure_capacity(c.len + 1);
        }
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let d = cfg.d_model;
        let scale = 1.0 / (hd as f32).sqrt();
        let pool = &*self.pool;
        let mut x = Mat::zeros(bsz, cfg.d_model);
        for (b, &t) in tokens.iter().enumerate() {
            x.row_mut(b).copy_from_slice(self.weights.embed.row(t as usize));
        }
        for (li, layer) in self.weights.layers.iter().enumerate() {
            // --- MHSA block: q/k/v projected for the whole batch at once;
            // attention itself is per-sequence (each has its own cache).
            let normed = rmsnorm(&x, &layer.attn_norm, 1e-6);
            let q = layer.wq.matmul_on(pool, &normed);
            let knew = layer.wk.matmul_on(pool, &normed);
            let vnew = layer.wv.matmul_on(pool, &normed);
            // Append each sequence's new K/V row first (f32 copy or int8
            // quantize, per the cache's precision), so attention below can
            // read the caches immutably.
            for (b, cache) in caches.iter_mut().enumerate() {
                let pos = cache.len;
                cache.write_row(li, pos, knew.row(b), vnew.row(b));
            }
            // Every (sequence, head) pair is independent and owns a
            // disjoint hd-wide strip of ctx (row-major ctx is exactly
            // [b][head][hd]), so the pairs are chunked evenly across the
            // pool — head-level parallelism reaches decode even at B=1.
            // Per-strip arithmetic matches the old sequential loop
            // operation for operation: bit-identical outputs.
            let mut ctx = Mat::zeros(bsz, cfg.d_model);
            {
                let caches: &[KvCache] = caches;
                let q = &q;
                let total = bsz * h;
                let per = total.div_ceil(pool.threads().min(total));
                pool.scope(|s| {
                    for (ci, chunk) in ctx.data.chunks_mut(per * hd).enumerate() {
                        s.spawn(move || {
                            // One scores buffer per task, resized per strip
                            // (every element is overwritten before the
                            // softmax, so reuse cannot change results).
                            let mut scores: Vec<f32> = Vec::new();
                            for (j, strip) in chunk.chunks_mut(hd).enumerate() {
                                let idx = ci * per + j;
                                let (b, head) = (idx / h, idx % h);
                                let cache = &caches[b];
                                let pos = cache.len;
                                let off = head * hd;
                                let qh = &q.row(b)[off..off + hd];
                                scores.clear();
                                scores.resize(pos + 1, 0.0);
                                // Scores and context run on the SIMD dot /
                                // axpy kernels; the int8 arm fuses
                                // dequantization into the reads (one
                                // per-head scale applied per position).
                                match cache.layer(li) {
                                    KvLayerView::F32 { k, v } => {
                                        for (jj, s) in scores.iter_mut().enumerate() {
                                            *s = simd::dot(qh, &k.row(jj)[off..off + hd]) * scale;
                                        }
                                        softmax_inplace(&mut scores);
                                        for (jj, &w) in scores.iter().enumerate() {
                                            simd::axpy(strip, w, &v.row(jj)[off..off + hd]);
                                        }
                                    }
                                    KvLayerView::Int8 { k, v, kscale, vscale } => {
                                        for (jj, s) in scores.iter_mut().enumerate() {
                                            let kj = &k[jj * d + off..jj * d + off + hd];
                                            *s = simd::dot_i8(qh, kj)
                                                * (kscale[jj * h + head] * scale);
                                        }
                                        softmax_inplace(&mut scores);
                                        for (jj, &w) in scores.iter().enumerate() {
                                            let vj = &v[jj * d + off..jj * d + off + hd];
                                            simd::axpy_i8(strip, w * vscale[jj * h + head], vj);
                                        }
                                    }
                                }
                            }
                        });
                    }
                });
            }
            let attn = layer.wo.matmul_on(pool, &ctx);
            for b in 0..bsz {
                crate::tensor::ops::add_inplace(x.row_mut(b), attn.row(b));
            }
            // --- MoE block over the batch: one router GEMM, experts
            // gathered across all B sequences.
            let normed = rmsnorm(&x, &layer.ffn_norm, 1e-6);
            let (moe, _) = self.moe_layer(&normed, layer, li, hooks);
            for b in 0..bsz {
                crate::tensor::ops::add_inplace(x.row_mut(b), moe.row(b));
            }
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        let normed = rmsnorm(&x, &self.weights.final_norm, 1e-6);
        matmul_transb_on(pool, &normed, &self.weights.embed)
    }
}

/// SwiGLU expert FFN on the global pool: (silu(x@w1) * (x@w3)) @ w2.
pub fn expert_forward(x: &Mat, e: &ExpertWeights) -> Mat {
    expert_forward_on(ThreadPool::global(), x, e)
}

/// [`expert_forward`] on an explicit pool. Each matrix dispatches through
/// [`WeightMat::matmul_on`], so packed experts run the fused dequant GEMM
/// directly.
pub fn expert_forward_on(pool: &ThreadPool, x: &Mat, e: &ExpertWeights) -> Mat {
    let mut a = e.w1.matmul_on(pool, x);
    let b = e.w3.matmul_on(pool, x);
    for (av, &bv) in a.data.iter_mut().zip(&b.data) {
        *av = silu(*av) * bv;
    }
    e.w2.matmul_on(pool, &a)
}

/// Accumulate the low-rank correction `x @ (u·v)` into `acc`, computed as
/// `(x@u)@v` — two skinny GEMMs instead of materializing the dense
/// `u·v`, and exact: `x@(W + u·v) = x@W + (x@u)@v`.
fn add_lowrank_on(pool: &ThreadPool, acc: &mut Mat, x: &Mat, u: &Mat, v: &Mat) {
    let xu = matmul_on(pool, x, u);
    let corr = matmul_on(pool, &xu, v);
    debug_assert!(
        acc.rows == corr.rows && acc.cols == corr.cols,
        "low-rank correction shape {}x{} vs {}x{}",
        corr.rows,
        corr.cols,
        acc.rows,
        acc.cols
    );
    for (a, &c) in acc.data.iter_mut().zip(&corr.data) {
        *a += c;
    }
}

/// [`expert_forward_on`] for a merged cluster: the base expert's SwiGLU
/// with the absorbed member's per-projection low-rank corrections folded
/// in *before* each nonlinearity/product, so a delta that fully captures
/// its member's residual reproduces the original expert exactly. With
/// `delta = None` the GEMM sequence and elementwise loop are identical to
/// [`expert_forward_on`] — singleton clusters are bit-identical to their
/// unmerged expert.
pub fn expert_forward_delta_on(
    pool: &ThreadPool,
    x: &Mat,
    base: &ExpertWeights,
    delta: Option<&ExpertDelta>,
) -> Mat {
    let mut a = base.w1.matmul_on(pool, x);
    let mut b = base.w3.matmul_on(pool, x);
    if let Some(d) = delta {
        add_lowrank_on(pool, &mut a, x, &d.u1, &d.v1);
        add_lowrank_on(pool, &mut b, x, &d.u3, &d.v3);
    }
    for (av, &bv) in a.data.iter_mut().zip(&b.data) {
        *av = silu(*av) * bv;
    }
    let mut y = base.w2.matmul_on(pool, &a);
    if let Some(d) = delta {
        add_lowrank_on(pool, &mut y, &a, &d.u2, &d.v2);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::hooks::SelectionRecord;

    fn tiny_model() -> Model {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        Model::new(Weights::init(&cfg, 3))
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = tiny_model();
        let logits = m.forward(&[1, 5, 9, 2]);
        assert_eq!(logits.rows, 4);
        assert_eq!(logits.cols, 32);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position i must not depend on tokens after i.
        let m = tiny_model();
        let a = m.forward(&[1, 2, 3, 4]);
        let b = m.forward(&[1, 2, 3, 30]);
        for j in 0..a.cols {
            assert!((a.at(0, j) - b.at(0, j)).abs() < 1e-5);
            assert!((a.at(2, j) - b.at(2, j)).abs() < 1e-5);
        }
        // ...and position 3 should differ.
        let differs = (0..a.cols).any(|j| (a.at(3, j) - b.at(3, j)).abs() > 1e-4);
        assert!(differs);
    }

    #[test]
    fn recording_then_forcing_reproduces_output() {
        let m = tiny_model();
        let tokens = [3u32, 7, 11, 13, 17];
        let hooks = Hooks::recording(2);
        let base = m.forward_with_hooks(&tokens, &hooks);
        let rec = hooks.take_selections().unwrap();
        assert_eq!(rec.layers[0].len(), tokens.len());
        let forced = Hooks::forcing(rec);
        let replay = m.forward_with_hooks(&tokens, &forced);
        for (x, y) in base.data.iter().zip(&replay.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn masking_all_selected_experts_zeroes_moe_path() {
        let m = tiny_model();
        let tokens = [3u32, 7, 11];
        // Mask every routed expert in both layers: MoE contributes only the
        // shared expert. Output must still be finite and differ from base.
        let mask = vec![vec![true; 4]; 2];
        let hooks = Hooks { expert_mask: Some(mask), ..Default::default() };
        let out = m.forward_with_hooks(&tokens, &hooks);
        assert!(out.data.iter().all(|x| x.is_finite()));
        let base = m.forward(&tokens);
        let differs = out.data.iter().zip(&base.data).any(|(a, b)| (a - b).abs() > 1e-4);
        assert!(differs);
    }

    #[test]
    fn pruned_expert_renormalizes_weights() {
        // With one of the two selected experts masked, the other gets weight
        // 1.0 — check via diagnostics that masked experts run zero tokens.
        let m = tiny_model();
        let tokens = [1u32, 2, 3, 4, 5, 6];
        let x = Mat::randn(6, 16, 1.0, &mut crate::tensor::Pcg64::seeded(9));
        let mask = vec![vec![true, false, false, false]; 2];
        let hooks = Hooks { expert_mask: Some(mask), ..Default::default() };
        let (_, diag) = m.moe_layer(&x, &m.weights.layers[0], 0, &hooks);
        assert_eq!(diag.expert_tokens[0], 0);
        let _ = tokens;
    }

    #[test]
    fn decode_matches_prefill() {
        let m = tiny_model();
        let tokens = [4u32, 9, 14, 19];
        let prefill = m.forward(&tokens);
        let mut cache = KvCache::new(m.cfg());
        let mut last = Vec::new();
        for &t in &tokens {
            last = m.decode_step(t, &mut cache, &Hooks::none());
        }
        let want = prefill.row(tokens.len() - 1);
        for (x, y) in last.iter().zip(want) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn prefill_kv_export_matches_decode_refill_bitwise() {
        // The cache written by prefill_into_cache must equal, bit for bit,
        // the cache a token-by-token decode_step replay of the prompt
        // builds — this is what lets the engine skip the second prompt pass.
        let m = tiny_model();
        let tokens = [4u32, 9, 14, 19, 23, 2, 7];
        let mut exported = KvCache::new(m.cfg());
        let logits = m.prefill_into_cache(&tokens, &Hooks::none(), &mut exported);
        let plain = m.forward(&tokens);
        assert_eq!(logits.data, plain.data, "prefill logits unchanged by export");
        let mut replayed = KvCache::new(m.cfg());
        for &t in &tokens {
            m.decode_step(t, &mut replayed, &Hooks::none());
        }
        assert_eq!(exported.len, replayed.len);
        for li in 0..m.cfg().n_layers {
            for r in 0..tokens.len() {
                assert_eq!(exported.k_row(li, r), replayed.k_row(li, r), "k layer {li} row {r}");
                assert_eq!(exported.v_row(li, r), replayed.v_row(li, r), "v layer {li} row {r}");
            }
        }
        // ...and decode continues identically from either cache.
        let a = m.decode_step(1, &mut exported, &Hooks::none());
        let b = m.decode_step(1, &mut replayed, &Hooks::none());
        assert_eq!(a, b);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        // Splitting a prompt at any chunk boundaries must reproduce the
        // monolithic prefill exactly: same per-position logits, same
        // cache rows, same subsequent decode. Chunk size is scheduling,
        // not math.
        let m = tiny_model();
        let tokens = [4u32, 9, 14, 19, 23, 2, 7, 30, 12];
        let mut mono = KvCache::new(m.cfg());
        let mono_logits = m.prefill_into_cache(&tokens, &Hooks::none(), &mut mono);
        for chunk_size in [1usize, 2, 3, 4, tokens.len()] {
            let mut cache = KvCache::new(m.cfg());
            let mut logits_rows: Vec<Vec<f32>> = Vec::new();
            for chunk in tokens.chunks(chunk_size) {
                let l = m.prefill_chunk_into_cache(chunk, &Hooks::none(), &mut cache);
                for r in 0..l.rows {
                    logits_rows.push(l.row(r).to_vec());
                }
            }
            assert_eq!(cache.len, tokens.len(), "chunk={chunk_size}");
            for (t, row) in logits_rows.iter().enumerate() {
                assert_eq!(&row[..], mono_logits.row(t), "chunk={chunk_size} logits row {t}");
            }
            for li in 0..m.cfg().n_layers {
                for r in 0..tokens.len() {
                    assert_eq!(cache.k_row(li, r), mono.k_row(li, r), "chunk={chunk_size} k {li}/{r}");
                    assert_eq!(cache.v_row(li, r), mono.v_row(li, r), "chunk={chunk_size} v {li}/{r}");
                }
            }
            let a = m.decode_step(1, &mut cache, &Hooks::none());
            let mut mono2 = mono.clone();
            let b = m.decode_step(1, &mut mono2, &Hooks::none());
            assert_eq!(a, b, "chunk={chunk_size} decode after chunked prefill");
        }
    }

    #[test]
    fn decode_step_batch_matches_sequential_bitwise() {
        // Each row of a batched decode step must equal the corresponding
        // single-sequence decode exactly, even with unequal prompt lengths.
        let m = tiny_model();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[7, 11, 13, 17, 19], &[5]];
        let mut solo_caches: Vec<KvCache> = Vec::new();
        let mut solo_logits: Vec<Vec<f32>> = Vec::new();
        for p in prompts {
            let mut c = KvCache::new(m.cfg());
            m.prefill_into_cache(p, &Hooks::none(), &mut c);
            solo_logits.push(m.decode_step(p[0], &mut c, &Hooks::none()));
            solo_caches.push(c);
        }
        let mut batch_caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(m.cfg());
                m.prefill_into_cache(p, &Hooks::none(), &mut c);
                c
            })
            .collect();
        let toks: Vec<u32> = prompts.iter().map(|p| p[0]).collect();
        let logits = m.decode_step_batch(&toks, &mut batch_caches, &Hooks::none());
        assert_eq!(logits.rows, 3);
        for b in 0..3 {
            assert_eq!(logits.row(b), &solo_logits[b][..], "row {b}");
            assert_eq!(batch_caches[b].len, solo_caches[b].len);
            for li in 0..m.cfg().n_layers {
                let pos = batch_caches[b].len - 1;
                assert_eq!(batch_caches[b].k_row(li, pos), solo_caches[b].k_row(li, pos));
                assert_eq!(batch_caches[b].v_row(li, pos), solo_caches[b].v_row(li, pos));
            }
        }
    }

    #[test]
    fn seq_masks_apply_per_row_only() {
        use crate::model::hooks::SeqExpertMask;
        use std::sync::Arc;
        let m = tiny_model();
        let prompts: [&[u32]; 2] = [&[1, 2, 3], &[7, 11, 13, 17]];
        let mk_caches = || -> Vec<KvCache> {
            prompts
                .iter()
                .map(|p| {
                    let mut c = KvCache::new(m.cfg());
                    m.prefill_into_cache(p, &Hooks::none(), &mut c);
                    c
                })
                .collect()
        };
        let toks = [2u32, 5];
        // All-false masks must be bit-identical to unpruned decode.
        let open: SeqExpertMask = Arc::new(vec![vec![false; 4]; 2]);
        let mut c1 = mk_caches();
        let a = m.decode_step_batch(
            &toks,
            &mut c1,
            &Hooks::with_seq_masks(vec![Some(open.clone()), Some(open)]),
        );
        let mut c2 = mk_caches();
        let b = m.decode_step_batch(&toks, &mut c2, &Hooks::none());
        assert_eq!(a.data, b.data, "all-false seq masks must be a no-op");
        // Masking every expert for row 1 only: row 0 unchanged bitwise,
        // row 1 differs (its MoE path collapses to the shared expert).
        let closed: SeqExpertMask = Arc::new(vec![vec![true; 4]; 2]);
        let mut c3 = mk_caches();
        let c = m.decode_step_batch(
            &toks,
            &mut c3,
            &Hooks::with_seq_masks(vec![None, Some(closed)]),
        );
        assert_eq!(c.row(0), b.row(0), "unmasked row must be unaffected");
        let differs = c.row(1).iter().zip(b.row(1)).any(|(x, y)| (x - y).abs() > 1e-5);
        assert!(differs, "masked row must change");
        assert!(c.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn kv_cache_grows_in_chunks_not_eagerly() {
        let mut cfg = tiny_model().cfg().clone();
        cfg.max_seq = 200; // > KV_GROW_ROWS so chunking is observable
        let m = Model::new(Weights::init(&cfg, 3));
        let mut cache = KvCache::new(&cfg);
        assert_eq!(cache.capacity(), 0);
        assert_eq!(cache.bytes(), 0, "empty cache holds no storage");
        m.prefill_into_cache(&[4, 9, 14], &Hooks::none(), &mut cache);
        assert_eq!(cache.capacity(), KV_GROW_ROWS, "first chunk only");
        let eager = cfg.n_layers * cfg.max_seq * cfg.d_model * 2 * 4;
        assert!(cache.bytes() < eager, "{} !< {eager}", cache.bytes());
        // Decoding past the chunk boundary grows by one more chunk.
        for t in 0..=(KV_GROW_ROWS - 3) as u32 {
            m.decode_step(t % cfg.vocab as u32, &mut cache, &Hooks::none());
        }
        assert_eq!(cache.len, KV_GROW_ROWS + 1);
        assert_eq!(cache.capacity(), 2 * KV_GROW_ROWS);
    }

    #[test]
    fn kv_capacity_rounds_to_max_seq() {
        let cfg = tiny_model().cfg().clone(); // max_seq = 64 == KV_GROW_ROWS
        let mut cache = KvCache::new(&cfg);
        cache.ensure_capacity(cfg.max_seq);
        assert_eq!(cache.capacity(), cfg.max_seq);
    }

    #[test]
    fn int8_kv_cache_is_smaller_and_decode_stays_close() {
        let m = tiny_model();
        let prompt = [4u32, 9, 14, 19, 23];
        let mut f32_cache = KvCache::new(m.cfg());
        let mut i8_cache = KvCache::with_precision(m.cfg(), KvPrecision::Int8);
        assert_eq!(i8_cache.precision(), KvPrecision::Int8);
        m.prefill_into_cache(&prompt, &Hooks::none(), &mut f32_cache);
        m.prefill_into_cache(&prompt, &Hooks::none(), &mut i8_cache);
        assert!(
            i8_cache.bytes() * 2 < f32_cache.bytes(),
            "int8 {} !<< f32 {}",
            i8_cache.bytes(),
            f32_cache.bytes()
        );
        // Stored rows dequantize close to the f32 rows...
        for li in 0..m.cfg().n_layers {
            for r in 0..prompt.len() {
                let (kf, ki) = (f32_cache.k_row(li, r), i8_cache.k_row(li, r));
                let amax = kf.iter().fold(0f32, |a, &x| a.max(x.abs()));
                for (x, y) in kf.iter().zip(&ki) {
                    assert!((x - y).abs() <= amax / 127.0 + 1e-6, "{x} vs {y}");
                }
            }
        }
        // ...and a short greedy decode stays close to the f32-cache path.
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for &t in &[1u32, 6, 11] {
            a = m.decode_step(t, &mut f32_cache, &Hooks::none());
            b = m.decode_step(t, &mut i8_cache, &Hooks::none());
        }
        let scale = a.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-6);
        let rel = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max)
            / scale;
        assert!(rel < 0.05, "int8 KV decode drift {rel}");
        assert!(b.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn quantize_head_zero_and_roundtrip() {
        let mut dst = [0i8; 4];
        assert_eq!(quantize_head(&[0.0; 4], &mut dst), 0.0);
        assert_eq!(dst, [0i8; 4]);
        let src = [1.0f32, -0.5, 0.25, -1.0];
        let s = quantize_head(&src, &mut dst);
        for (&c, &x) in dst.iter().zip(&src) {
            assert!((c as f32 * s - x).abs() <= s * 0.5 + 1e-7);
        }
    }

    #[test]
    fn selection_scores_are_descending() {
        let m = tiny_model();
        let hooks = Hooks::recording(2);
        m.forward_with_hooks(&[1, 2, 3, 4, 5, 6, 7, 8], &hooks);
        let rec = hooks.take_selections().unwrap();
        for layer in &rec.layers {
            for sel in layer {
                for w in sel.scores.windows(2) {
                    assert!(w[0] >= w[1]);
                }
            }
        }
    }

    #[test]
    fn shared_experts_always_contribute() {
        // deepseek-style config has shared experts; removing them changes out.
        let m = tiny_model();
        let x = Mat::randn(3, 16, 1.0, &mut crate::tensor::Pcg64::seeded(10));
        let (with_shared, _) = m.moe_layer(&x, &m.weights.layers[0], 0, &Hooks::none());
        let mut m2 = Model::new(m.weights.clone());
        m2.weights.layers[0].set_shared(vec![]);
        let (without, _) = m2.moe_layer(&x, &m2.weights.layers[0], 0, &Hooks::none());
        let differs =
            with_shared.data.iter().zip(&without.data).any(|(a, b)| (a - b).abs() > 1e-5);
        assert!(differs);
    }
}
