//! Mixed-precision bit-width allocators for MoE experts — the baselines the
//! paper compares QESC against (Table 2, Table 9, Appendix A.6):
//!
//! * **Uniform** — every expert at the same bit-width (GPTQ baseline rows).
//! * **HalfSplit** — the paper's own 2.5-bit setting: experts in the first
//!   half of the layers at 3-bit, second half at 2-bit (Appendix A.5).
//! * **BSP** (Li et al., 2024a) — frequency split: the top-half (or top-n)
//!   most-frequently-selected experts get the high bit-width, the rest get
//!   the low one; shared experts get 8-bit.
//! * **PMQ** (Huang et al., 2024a) — importance-weighted allocation solved
//!   as a budgeted assignment: maximize Σ importance(e)·u(bits(e)) subject
//!   to the average-bit budget, with concave per-bit utility (greedy
//!   marginal-gain is exact for concave u + unit bit steps).
//!
//! Allocators consume *expert-selection frequencies measured on a
//! calibration set* — precisely the thing §3.3/Table 9 shows overfits
//! across task types, which `experiments::table9` demonstrates.

/// Bit-width assignment for every (layer, expert) plus shared experts.
#[derive(Clone, Debug, PartialEq)]
pub struct BitAlloc {
    /// bits[layer][expert]
    pub bits: Vec<Vec<u32>>,
    /// shared_bits[layer][shared_expert]
    pub shared_bits: Vec<Vec<u32>>,
}

impl BitAlloc {
    pub fn uniform(n_layers: usize, n_experts: usize, n_shared: usize, bits: u32) -> Self {
        BitAlloc {
            bits: vec![vec![bits; n_experts]; n_layers],
            shared_bits: vec![vec![bits; n_shared]; n_layers],
        }
    }

    /// Average bits per expert *parameter*: each expert's bit-width is
    /// weighted by its parameter count, so routed and shared experts with
    /// different shapes (and layers with different expert counts, e.g.
    /// after expert merging) average to the true storage cost rather than
    /// a head-count mean. An all-empty alloc averages to 0.0.
    pub fn average_bits_weighted(&self, expert_params: usize, shared_params: usize) -> f64 {
        debug_assert_eq!(
            self.bits.len(),
            self.shared_bits.len(),
            "bits and shared_bits must cover the same layers"
        );
        let mut bit_sum = 0f64;
        let mut param_sum = 0f64;
        for (l, s) in self.bits.iter().zip(&self.shared_bits) {
            for &b in l {
                bit_sum += b as f64 * expert_params as f64;
                param_sum += expert_params as f64;
            }
            for &b in s {
                bit_sum += b as f64 * shared_params as f64;
                param_sum += shared_params as f64;
            }
        }
        if param_sum == 0.0 {
            0.0
        } else {
            bit_sum / param_sum
        }
    }

    /// Head-count average bits per expert. Equals the parameter-weighted
    /// average only because this codebase's routed and shared experts
    /// share one shape (the d_model x d_ff SwiGLU triple) — stated here
    /// instead of silently assumed; use [`Self::average_bits_weighted`]
    /// when the shapes differ.
    pub fn average_bits(&self) -> f64 {
        self.average_bits_weighted(1, 1)
    }
}

/// The allocation strategies.
#[derive(Clone, Debug)]
pub enum Allocator {
    Uniform { bits: u32 },
    /// Paper's 2.5-bit setting: first half of layers hi, second half lo.
    HalfSplit { hi: u32, lo: u32 },
    /// BSP: top `hi_count` experts by frequency get `hi` bits, rest `lo`;
    /// shared experts get `shared` bits.
    Bsp { hi: u32, lo: u32, hi_count: usize, shared: u32 },
    /// PMQ: budgeted importance-weighted assignment over `choices`,
    /// targeting `avg_bits` average; shared experts get `shared` bits.
    Pmq { avg_bits: f64, shared: u32 },
}

impl Allocator {
    /// Produce an allocation. `freq[layer][expert]` are measured selection
    /// frequencies (ignored by Uniform/HalfSplit).
    pub fn allocate(
        &self,
        n_layers: usize,
        n_experts: usize,
        n_shared: usize,
        freq: &[Vec<f32>],
    ) -> BitAlloc {
        match *self {
            Allocator::Uniform { bits } => {
                BitAlloc::uniform(n_layers, n_experts, n_shared, bits)
            }
            Allocator::HalfSplit { hi, lo } => {
                let bits = (0..n_layers)
                    .map(|l| vec![if l < n_layers / 2 { hi } else { lo }; n_experts])
                    .collect();
                let shared_bits = (0..n_layers)
                    .map(|l| vec![if l < n_layers / 2 { hi } else { lo }; n_shared])
                    .collect();
                BitAlloc { bits, shared_bits }
            }
            Allocator::Bsp { hi, lo, hi_count, shared } => {
                assert_eq!(freq.len(), n_layers, "BSP needs per-layer frequencies");
                let bits = (0..n_layers)
                    .map(|l| {
                        let order = crate::tensor::ops::topk_indices(&freq[l], n_experts);
                        let mut row = vec![lo; n_experts];
                        for &e in order.iter().take(hi_count.min(n_experts)) {
                            row[e] = hi;
                        }
                        row
                    })
                    .collect();
                BitAlloc { bits, shared_bits: vec![vec![shared; n_shared]; n_layers] }
            }
            Allocator::Pmq { avg_bits, shared } => {
                assert_eq!(freq.len(), n_layers);
                let bits = pmq_allocate(n_layers, n_experts, freq, avg_bits);
                BitAlloc { bits, shared_bits: vec![vec![shared; n_shared]; n_layers] }
            }
        }
    }
}

/// Concave utility of giving an expert b bits (diminishing returns; the
/// shape matters, not the constants — mirrors PMQ's error-model weights).
fn bit_utility(b: u32) -> f64 {
    match b {
        0 | 1 | 2 => 0.0,
        3 => 1.0,
        4 => 1.7,
        _ => 1.7 + 0.15 * (b as f64 - 4.0),
    }
}

/// Greedy marginal-gain allocation: start everyone at 2 bits, repeatedly
/// grant +1 bit to the (layer, expert) with the highest
/// `importance × Δutility` until the global budget is exhausted.
fn pmq_allocate(
    n_layers: usize,
    n_experts: usize,
    freq: &[Vec<f32>],
    avg_bits: f64,
) -> Vec<Vec<u32>> {
    debug_assert!(
        freq.len() == n_layers && freq.iter().all(|r| r.len() == n_experts),
        "frequency table shape must be n_layers x n_experts"
    );
    let base = 2u32;
    let max_bits = 8u32;
    let total_budget = (avg_bits * (n_layers * n_experts) as f64).round() as i64;
    let mut bits = vec![vec![base; n_experts]; n_layers];
    let mut spent = (base as i64) * (n_layers * n_experts) as i64;
    // Max-heap of candidate upgrades via sort-each-round would be O(n² log n);
    // use a simple binary heap on (gain, layer, expert).
    let mut heap: std::collections::BinaryHeap<(ordered::F64, usize, usize)> =
        std::collections::BinaryHeap::new();
    let gain = |f: f32, b: u32| -> f64 { f as f64 * (bit_utility(b + 1) - bit_utility(b)) };
    for (l, row) in freq.iter().enumerate() {
        for (e, &f) in row.iter().enumerate() {
            heap.push((ordered::F64(gain(f, base)), l, e));
        }
    }
    while spent < total_budget {
        let Some((_, l, e)) = heap.pop() else { break };
        if bits[l][e] >= max_bits {
            continue;
        }
        bits[l][e] += 1;
        spent += 1;
        if bits[l][e] < max_bits {
            heap.push((ordered::F64(gain(freq[l][e], bits[l][e])), l, e));
        }
    }
    bits
}

/// Ordered f64 wrapper for use in a BinaryHeap (NaN-free inputs only).
mod ordered {
    #[derive(PartialEq, PartialOrd)]
    pub(super) struct F64(pub(super) f64);
    impl Eq for F64 {}
    #[allow(clippy::derive_ord_xor_partial_ord)]
    impl Ord for F64 {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
        }
    }
}

/// Parameter counts that [`model_average_bits`] accounts over, decoupled
/// from `model::ModelConfig` so `quant` stays below `model` in the module
/// layering (`ModelConfig::bit_dims()` builds one).
#[derive(Clone, Copy, Debug)]
pub struct BitDims {
    pub n_layers: usize,
    /// Parameters per (routed or shared) expert.
    pub expert_params: usize,
    /// Total MHSA parameters across all layers.
    pub mhsa_params: usize,
    /// Total router parameters across all layers.
    pub router_params: usize,
}

/// Average-bit accounting for a whole model under a given expert allocation
/// (Appendix A.5 / Table 12): MHSA at `mhsa_bits`, router at fp16,
/// experts per `alloc`, group-overhead included.
pub fn model_average_bits(
    dims: &BitDims,
    alloc: &BitAlloc,
    mhsa_bits: u32,
    group_size: usize,
) -> f64 {
    let expert_params = dims.expert_params;
    let overhead = 40.0 / group_size as f64; // f32 scale + u8 zero per group
    let mut bit_sum = 0f64;
    let mut param_sum = 0f64;
    // Experts.
    for l in 0..dims.n_layers {
        for &b in alloc.bits[l].iter().chain(&alloc.shared_bits[l]) {
            bit_sum += (b as f64 + overhead) * expert_params as f64;
            param_sum += expert_params as f64;
        }
    }
    // MHSA.
    let mhsa = dims.mhsa_params as f64;
    bit_sum += (mhsa_bits as f64 + overhead) * mhsa;
    param_sum += mhsa;
    // Router stays fp16.
    let router = dims.router_params as f64;
    bit_sum += 16.0 * router;
    param_sum += router;
    bit_sum / param_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ZooModel;

    fn flat_freq(n_layers: usize, n_experts: usize) -> Vec<Vec<f32>> {
        vec![vec![1.0 / n_experts as f32; n_experts]; n_layers]
    }

    #[test]
    fn uniform_alloc() {
        let a = Allocator::Uniform { bits: 3 }.allocate(2, 4, 1, &flat_freq(2, 4));
        assert_eq!(a.average_bits(), 3.0);
    }

    /// Unequal shared counts per layer + shared params != expert params:
    /// the parameter-weighted average diverges from the head-count mean by
    /// exactly the hand-computed amount.
    #[test]
    fn average_bits_weights_by_parameter_count() {
        let a = BitAlloc {
            bits: vec![vec![2, 2], vec![4, 4]],
            // Layer 0 has one shared expert, layer 1 has three.
            shared_bits: vec![vec![8], vec![8, 8, 8]],
        };
        // Head-count: (2+2+4+4 + 8*4) / 8 = 44/8 = 5.5.
        assert!((a.average_bits() - 5.5).abs() < 1e-12);
        // Shared experts 10x the params of routed ones:
        // bit_sum = (2+2+4+4)*100 + 8*4*1000 = 1200 + 32000 = 33200
        // params  = 4*100 + 4*1000 = 4400 -> 33200/4400 = 7.5454545...
        let w = a.average_bits_weighted(100, 1000);
        assert!((w - 33_200.0 / 4_400.0).abs() < 1e-12, "weighted {w}");
        assert!(w > a.average_bits(), "heavier shared experts pull the average up");
        // Equal params reduces to the head-count mean.
        assert!((a.average_bits_weighted(7, 7) - 5.5).abs() < 1e-12);
        // Empty alloc stays a defined 0.0, not NaN.
        let empty = BitAlloc { bits: vec![], shared_bits: vec![] };
        assert_eq!(empty.average_bits(), 0.0);
        assert_eq!(empty.average_bits_weighted(10, 10), 0.0);
    }

    #[test]
    fn half_split_averages_between() {
        let a = Allocator::HalfSplit { hi: 3, lo: 2 }.allocate(4, 8, 0, &flat_freq(4, 8));
        assert_eq!(a.average_bits(), 2.5);
        assert!(a.bits[0].iter().all(|&b| b == 3));
        assert!(a.bits[3].iter().all(|&b| b == 2));
    }

    #[test]
    fn bsp_tops_get_high_bits() {
        let mut freq = flat_freq(1, 8);
        freq[0] = vec![0.4, 0.05, 0.3, 0.05, 0.05, 0.05, 0.05, 0.05];
        let a = Allocator::Bsp { hi: 4, lo: 2, hi_count: 2, shared: 8 }.allocate(1, 8, 2, &freq);
        assert_eq!(a.bits[0][0], 4);
        assert_eq!(a.bits[0][2], 4);
        assert_eq!(a.bits[0][1], 2);
        assert_eq!(a.shared_bits[0], vec![8, 8]);
    }

    #[test]
    fn pmq_respects_budget_and_prefers_frequent() {
        let mut freq = flat_freq(2, 8);
        freq[0][3] = 0.9;
        freq[1][5] = 0.9;
        let a = Allocator::Pmq { avg_bits: 2.5, shared: 3 }.allocate(2, 8, 0, &freq);
        let avg = a.average_bits();
        assert!((avg - 2.5).abs() < 0.07, "avg={avg}");
        // The heavy experts must end with >= the bits of any light expert.
        assert!(a.bits[0][3] >= a.bits[0][1], "{:?}", a.bits);
        assert!(a.bits[1][5] >= a.bits[1][0]);
        assert!(a.bits[0][3] > 2);
    }

    #[test]
    fn pmq_different_calibration_changes_alloc() {
        // The overfitting premise of Table 9: different frequency profiles
        // produce different allocations.
        let mut fa = flat_freq(1, 8);
        fa[0] = vec![0.8, 0.05, 0.02, 0.02, 0.02, 0.03, 0.03, 0.03];
        let mut fb = flat_freq(1, 8);
        fb[0] = vec![0.02, 0.05, 0.8, 0.02, 0.02, 0.03, 0.03, 0.03];
        let alloc = |f: &Vec<Vec<f32>>| {
            Allocator::Pmq { avg_bits: 2.3, shared: 2 }.allocate(1, 8, 0, f)
        };
        assert_ne!(alloc(&fa).bits, alloc(&fb).bits);
    }

    #[test]
    fn table12_average_bits_accounting() {
        // Reproduce Table 12's shape: experts at 2/2.5/3-bit + 4-bit MHSA
        // lands near 2.06 / 2.54 / 3.03 average bits.
        for m in ZooModel::ALL {
            let cfg = m.config();
            for (ebits, want) in [(2u32, 2.06), (3u32, 3.03)] {
                let a = Allocator::Uniform { bits: ebits }.allocate(
                    cfg.n_layers,
                    cfg.n_experts,
                    cfg.n_shared,
                    &flat_freq(cfg.n_layers, cfg.n_experts),
                );
                let avg = model_average_bits(&cfg.bit_dims(), &a, 4, 128);
                // Minis have a higher MHSA fraction than the real models, so
                // allow a looser band than the paper's ±0.01.
                assert!(
                    (avg - want).abs() < 0.45,
                    "{} ebits={ebits}: avg={avg:.3} want≈{want}",
                    cfg.name
                );
            }
            let half = Allocator::HalfSplit { hi: 3, lo: 2 }.allocate(
                cfg.n_layers,
                cfg.n_experts,
                cfg.n_shared,
                &flat_freq(cfg.n_layers, cfg.n_experts),
            );
            let avg = model_average_bits(&cfg.bit_dims(), &half, 4, 128);
            assert!((avg - 2.54).abs() < 0.45, "{}: 2.5-bit avg={avg:.3}", cfg.name);
        }
    }
}
