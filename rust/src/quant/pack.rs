//! Sub-byte bit-packing for quantized weight storage (the BitBLAS role in
//! the paper: this is what makes the *memory* numbers real).
//!
//! Codes are packed along the **row (K/reduction) axis** in little-endian bit
//! order within each column-contiguous stream. Packing along K mirrors why
//! BitBLAS packs along the warp-contiguous axis on GPU: at dequant time a
//! K-tile unpacks as one contiguous byte run (see DESIGN.md
//! §Hardware-Adaptation; the Pallas kernel in
//! `python/compile/kernels/quant_matmul.py` uses the same layout).

use super::quantizer::{GroupQuant, QuantConfig};
use crate::tensor::Mat;

/// A bit-packed quantized matrix: storage form of [`GroupQuant`].
#[derive(Clone, Debug)]
pub struct PackedMat {
    pub cfg: QuantConfig,
    pub rows: usize,
    pub cols: usize,
    /// Packed codes: per column, `rows * bits` bits, padded to a byte
    /// boundary; columns concatenated.
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
}

impl PackedMat {
    /// Bytes needed to pack one column.
    fn col_bytes(rows: usize, bits: u32) -> usize {
        (rows * bits as usize).div_ceil(8)
    }

    /// Pack a [`GroupQuant`] into sub-byte storage.
    pub fn pack(gq: &GroupQuant) -> PackedMat {
        let bits = gq.cfg.bits as usize;
        let cb = Self::col_bytes(gq.rows, gq.cfg.bits);
        let mut packed = vec![0u8; cb * gq.cols];
        for c in 0..gq.cols {
            let col = &mut packed[c * cb..(c + 1) * cb];
            for r in 0..gq.rows {
                let code = gq.codes[r * gq.cols + c] as usize;
                let bit0 = r * bits;
                for b in 0..bits {
                    if (code >> b) & 1 == 1 {
                        let pos = bit0 + b;
                        col[pos / 8] |= 1 << (pos % 8);
                    }
                }
            }
        }
        PackedMat {
            cfg: gq.cfg,
            rows: gq.rows,
            cols: gq.cols,
            packed,
            scales: gq.scales.clone(),
            zeros: gq.zeros.clone(),
        }
    }

    /// Unpack back to byte codes.
    pub fn unpack(&self) -> GroupQuant {
        let bits = self.cfg.bits as usize;
        let cb = Self::col_bytes(self.rows, self.cfg.bits);
        let mut codes = vec![0u8; self.rows * self.cols];
        for c in 0..self.cols {
            let col = &self.packed[c * cb..(c + 1) * cb];
            for r in 0..self.rows {
                let bit0 = r * bits;
                let mut code = 0usize;
                for b in 0..bits {
                    let pos = bit0 + b;
                    if (col[pos / 8] >> (pos % 8)) & 1 == 1 {
                        code |= 1 << b;
                    }
                }
                codes[r * self.cols + c] = code as u8;
            }
        }
        GroupQuant::from_parts(
            self.cfg,
            self.rows,
            self.cols,
            codes,
            self.scales.clone(),
            self.zeros.clone(),
        )
    }

    /// Real storage footprint in bytes (packed codes + scales + zeros,
    /// zeros stored as u8 on disk).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4 + self.zeros.len()
    }

    /// Fused dequantize-matmul: `x (m, rows) @ dequant(self) (rows, cols)`.
    ///
    /// This is the native-path analogue of the Pallas `quant_matmul` kernel:
    /// it never materializes the full f32 weight matrix; each column is
    /// unpacked group-by-group into a stack buffer and consumed immediately.
    ///
    /// Unpacking is LUT-driven for the byte-aligned widths (2-bit: one
    /// 256×4 table lookup per byte; 4-bit: 256×2) — the §Perf optimization
    /// that took this from ~8x slower than dequant-then-GEMM to ~parity at
    /// small M (see EXPERIMENTS.md §Perf). Non-aligned widths (3/5-bit)
    /// take the generic bit-extraction path.
    pub fn matmul_dequant(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.rows, "matmul_dequant inner-dim mismatch");
        let bits = self.cfg.bits as usize;
        let cb = Self::col_bytes(self.rows, self.cfg.bits);
        let g = if self.cfg.group_size == 0 { self.rows } else { self.cfg.group_size };
        let mut out = Mat::zeros(x.rows, self.cols);
        let mut colbuf = vec![0f32; self.rows + 8]; // slack for LUT over-write
        for c in 0..self.cols {
            let col = &self.packed[c * cb..(c + 1) * cb];
            match bits {
                2 => unpack2_lut(col, &mut colbuf),
                4 => unpack4_lut(col, &mut colbuf),
                8 => {
                    for (dst, &b) in colbuf.iter_mut().zip(col) {
                        *dst = b as f32;
                    }
                }
                _ => unpack_generic(col, bits, self.rows, &mut colbuf),
            }
            // Affine-correct per group: w = (code - zero) * scale.
            for gi in 0..self.cfg.n_groups(self.rows) {
                let scale = self.scales[gi * self.cols + c];
                let zero = self.zeros[gi * self.cols + c];
                let r1 = ((gi + 1) * g).min(self.rows);
                for v in &mut colbuf[gi * g..r1] {
                    *v = (*v - zero) * scale;
                }
            }
            // out[:, c] = x @ colbuf
            for m in 0..x.rows {
                let xr = x.row(m);
                let mut acc = 0.0f32;
                for (xv, wv) in xr.iter().zip(&colbuf[..self.rows]) {
                    acc += xv * wv;
                }
                *out.at_mut(m, c) = acc;
            }
        }
        out
    }
}

/// 256-entry LUT: byte -> four 2-bit codes as f32.
fn lut2() -> &'static [[f32; 4]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 4]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0f32; 4]; 256];
        for (b, e) in t.iter_mut().enumerate() {
            for (k, v) in e.iter_mut().enumerate() {
                *v = ((b >> (2 * k)) & 3) as f32;
            }
        }
        t
    })
}

/// 256-entry LUT: byte -> two 4-bit codes as f32.
fn lut4() -> &'static [[f32; 2]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 2]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0f32; 2]; 256];
        for (b, e) in t.iter_mut().enumerate() {
            e[0] = (b & 15) as f32;
            e[1] = (b >> 4) as f32;
        }
        t
    })
}

fn unpack2_lut(col: &[u8], out: &mut [f32]) {
    let lut = lut2();
    for (i, &b) in col.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&lut[b as usize]);
    }
}

fn unpack4_lut(col: &[u8], out: &mut [f32]) {
    let lut = lut4();
    for (i, &b) in col.iter().enumerate() {
        out[i * 2..i * 2 + 2].copy_from_slice(&lut[b as usize]);
    }
}

fn unpack_generic(col: &[u8], bits: usize, rows: usize, out: &mut [f32]) {
    let mask = ((1u32 << bits) - 1) as u8;
    for (r, dst) in out.iter_mut().enumerate().take(rows) {
        let bit0 = r * bits;
        let byte = bit0 / 8;
        let off = bit0 % 8;
        let mut raw = col[byte] as u32 >> off;
        if off + bits > 8 && byte + 1 < col.len() {
            raw |= (col[byte + 1] as u32) << (8 - off);
        }
        *dst = ((raw as u8) & mask) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, Pcg64};

    #[test]
    fn pack_unpack_identity_all_bitwidths() {
        let mut rng = Pcg64::seeded(31);
        for bits in [2u32, 3, 4, 5, 8] {
            let rows = 37; // deliberately not byte-aligned
            let cols = 5;
            let w = Mat::randn(rows, cols, 1.0, &mut rng);
            let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 16));
            let packed = PackedMat::pack(&gq);
            let back = packed.unpack();
            assert_eq!(back.codes, gq.codes, "bits={bits}");
            assert_eq!(back.scales, gq.scales);
        }
    }

    #[test]
    fn packed_size_matches_bits() {
        let gq = GroupQuant::quantize(&Mat::zeros(128, 64), QuantConfig::new(2, 128));
        let p = PackedMat::pack(&gq);
        // 128 rows * 2 bits = 32 bytes per column * 64 cols.
        assert_eq!(p.packed.len(), 32 * 64);
        let gq3 = GroupQuant::quantize(&Mat::zeros(128, 64), QuantConfig::new(3, 128));
        let p3 = PackedMat::pack(&gq3);
        assert_eq!(p3.packed.len(), 48 * 64);
    }

    #[test]
    fn matmul_dequant_matches_explicit() {
        let mut rng = Pcg64::seeded(32);
        for bits in [2u32, 3, 4] {
            let w = Mat::randn(48, 20, 1.0, &mut rng);
            let x = Mat::randn(7, 48, 1.0, &mut rng);
            let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 16));
            let p = PackedMat::pack(&gq);
            let fused = p.matmul_dequant(&x);
            let explicit = matmul(&x, &gq.dequantize());
            for (a, b) in fused.data.iter().zip(&explicit.data) {
                assert!((a - b).abs() < 1e-3, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn compression_ratio_is_real() {
        // 2-bit packing of a 128x128 f32 matrix: 65536 B -> ~4096 B codes.
        let w = Mat::zeros(128, 128);
        let gq = GroupQuant::quantize(&w, QuantConfig::new(2, 128));
        let p = PackedMat::pack(&gq);
        let fp32 = 128 * 128 * 4;
        let ratio = fp32 as f64 / p.storage_bytes() as f64;
        assert!(ratio > 13.0, "ratio={ratio}"); // ~13.9x with group overhead
    }

    /// Property: pack∘unpack is the identity on random code matrices.
    #[test]
    fn prop_pack_roundtrip_random() {
        let mut rng = Pcg64::seeded(33);
        for _ in 0..10 {
            let bits = 2 + rng.below(4) as u32; // 2..=5
            let rows = 1 + rng.below_usize(70);
            let cols = 1 + rng.below_usize(9);
            let qmax = (1u32 << bits) - 1;
            let codes: Vec<u8> =
                (0..rows * cols).map(|_| rng.below(qmax as u64 + 1) as u8).collect();
            let ng = QuantConfig::new(bits, 16).n_groups(rows);
            let gq = GroupQuant::from_parts(
                QuantConfig::new(bits, 16),
                rows,
                cols,
                codes.clone(),
                vec![1.0; ng * cols],
                vec![0.0; ng * cols],
            );
            let back = PackedMat::pack(&gq).unpack();
            assert_eq!(back.codes, codes);
        }
    }
}
