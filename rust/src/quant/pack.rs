//! Sub-byte bit-packing for quantized weight storage (the BitBLAS role in
//! the paper: this is what makes the *memory* numbers real).
//!
//! Codes are packed along the **row (K/reduction) axis** in little-endian bit
//! order within each column-contiguous stream. Packing along K mirrors why
//! BitBLAS packs along the warp-contiguous axis on GPU: at dequant time a
//! K-tile unpacks as one contiguous byte run (see DESIGN.md
//! §Hardware-Adaptation; the Pallas kernel in
//! `python/compile/kernels/quant_matmul.py` uses the same layout).

use super::quantizer::{GroupQuant, QuantConfig};
use crate::tensor::Mat;

/// A bit-packed quantized matrix: storage form of [`GroupQuant`].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMat {
    pub cfg: QuantConfig,
    pub rows: usize,
    pub cols: usize,
    /// Packed codes: per column, `rows * bits` bits, padded to a byte
    /// boundary; columns concatenated.
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    /// Zero-points, resident as u8 (they are integers in `0..=qmax`), so
    /// [`PackedMat::storage_bytes`] is the true in-memory footprint.
    pub zeros: Vec<u8>,
}

impl PackedMat {
    /// Bytes needed to pack one column.
    pub(crate) fn col_bytes(rows: usize, bits: u32) -> usize {
        (rows * bits as usize).div_ceil(8)
    }

    /// Pack a [`GroupQuant`] into sub-byte storage.
    pub fn pack(gq: &GroupQuant) -> PackedMat {
        debug_assert!(gq.codes.len() == gq.rows * gq.cols, "GroupQuant code buffer shape");
        let bits = gq.cfg.bits as usize;
        let cb = Self::col_bytes(gq.rows, gq.cfg.bits);
        let mut packed = vec![0u8; cb * gq.cols];
        for c in 0..gq.cols {
            let col = &mut packed[c * cb..(c + 1) * cb];
            for r in 0..gq.rows {
                let code = gq.codes[r * gq.cols + c] as usize;
                let bit0 = r * bits;
                for b in 0..bits {
                    if (code >> b) & 1 == 1 {
                        let pos = bit0 + b;
                        col[pos / 8] |= 1 << (pos % 8);
                    }
                }
            }
        }
        PackedMat {
            cfg: gq.cfg,
            rows: gq.rows,
            cols: gq.cols,
            packed,
            scales: gq.scales.clone(),
            // Integral by construction (RTN and GPTQ both round + clamp to
            // 0..=qmax), so the u8 narrowing is exact.
            zeros: gq.zeros.iter().map(|&z| z as u8).collect(),
        }
    }

    /// Unpack back to byte codes.
    pub fn unpack(&self) -> GroupQuant {
        let bits = self.cfg.bits as usize;
        let cb = Self::col_bytes(self.rows, self.cfg.bits);
        debug_assert!(self.packed.len() == cb * self.cols, "packed buffer shape");
        let mut codes = vec![0u8; self.rows * self.cols];
        for c in 0..self.cols {
            let col = &self.packed[c * cb..(c + 1) * cb];
            for r in 0..self.rows {
                let bit0 = r * bits;
                let mut code = 0usize;
                for b in 0..bits {
                    let pos = bit0 + b;
                    if (col[pos / 8] >> (pos % 8)) & 1 == 1 {
                        code |= 1 << b;
                    }
                }
                codes[r * self.cols + c] = code as u8;
            }
        }
        GroupQuant::from_parts(
            self.cfg,
            self.rows,
            self.cols,
            codes,
            self.scales.clone(),
            self.zeros.iter().map(|&z| z as f32).collect(),
        )
    }

    /// Real storage footprint in bytes (packed codes + f32 scales + u8
    /// zeros) — this is both the resident and the on-disk size.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4 + self.zeros.len()
    }

    /// Fused dequantize-matmul: `x (m, rows) @ dequant(self) (rows, cols)`.
    ///
    /// Delegates to the cache-blocked kernel in [`crate::quant::fused`],
    /// which unpacks each K-tile into an f32 strip once per call and
    /// reuses it across the M dimension (the old implementation here
    /// unpacked every full column per call with zero reuse). The LUT
    /// unpackers below (2-bit: one
    /// 256×4 table lookup per byte; 4-bit: 256×2) are what it builds on;
    /// non-byte-aligned widths (3/5-bit) take the generic bit-extraction
    /// path.
    pub fn matmul_dequant(&self, x: &Mat) -> Mat {
        crate::quant::fused::matmul_packed(x, self)
    }
}

/// 256-entry LUT: byte -> four 2-bit codes as f32.
fn lut2() -> &'static [[f32; 4]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 4]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0f32; 4]; 256];
        for (b, e) in t.iter_mut().enumerate() {
            for (k, v) in e.iter_mut().enumerate() {
                *v = ((b >> (2 * k)) & 3) as f32;
            }
        }
        t
    })
}

/// 256-entry LUT: byte -> two 4-bit codes as f32.
fn lut4() -> &'static [[f32; 2]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 2]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0f32; 2]; 256];
        debug_assert!(t.len() == 256 && t[0].len() == 2);
        for (b, e) in t.iter_mut().enumerate() {
            e[0] = (b & 15) as f32;
            e[1] = (b >> 4) as f32;
        }
        t
    })
}

pub(crate) fn unpack2_lut(col: &[u8], out: &mut [f32]) {
    debug_assert!(out.len() >= col.len() * 4, "unpack2 output buffer too small");
    let lut = lut2();
    for (i, &b) in col.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&lut[b as usize]);
    }
}

pub(crate) fn unpack4_lut(col: &[u8], out: &mut [f32]) {
    debug_assert!(out.len() >= col.len() * 2, "unpack4 output buffer too small");
    let lut = lut4();
    for (i, &b) in col.iter().enumerate() {
        out[i * 2..i * 2 + 2].copy_from_slice(&lut[b as usize]);
    }
}

pub(crate) fn unpack_generic(col: &[u8], bits: usize, rows: usize, out: &mut [f32]) {
    let mask = ((1u32 << bits) - 1) as u8;
    for (r, dst) in out.iter_mut().enumerate().take(rows) {
        let bit0 = r * bits;
        let byte = bit0 / 8;
        let off = bit0 % 8;
        let mut raw = col[byte] as u32 >> off;
        if off + bits > 8 && byte + 1 < col.len() {
            raw |= (col[byte + 1] as u32) << (8 - off);
        }
        *dst = ((raw as u8) & mask) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, Pcg64};

    #[test]
    fn pack_unpack_identity_all_bitwidths() {
        let mut rng = Pcg64::seeded(31);
        for bits in [2u32, 3, 4, 5, 8] {
            let rows = 37; // deliberately not byte-aligned
            let cols = 5;
            let w = Mat::randn(rows, cols, 1.0, &mut rng);
            let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 16));
            let packed = PackedMat::pack(&gq);
            let back = packed.unpack();
            assert_eq!(back.codes, gq.codes, "bits={bits}");
            assert_eq!(back.scales, gq.scales);
        }
    }

    #[test]
    fn packed_size_matches_bits() {
        let gq = GroupQuant::quantize(&Mat::zeros(128, 64), QuantConfig::new(2, 128));
        let p = PackedMat::pack(&gq);
        // 128 rows * 2 bits = 32 bytes per column * 64 cols.
        assert_eq!(p.packed.len(), 32 * 64);
        let gq3 = GroupQuant::quantize(&Mat::zeros(128, 64), QuantConfig::new(3, 128));
        let p3 = PackedMat::pack(&gq3);
        assert_eq!(p3.packed.len(), 48 * 64);
    }

    #[test]
    fn matmul_dequant_matches_explicit() {
        let mut rng = Pcg64::seeded(32);
        for bits in [2u32, 3, 4] {
            let w = Mat::randn(48, 20, 1.0, &mut rng);
            let x = Mat::randn(7, 48, 1.0, &mut rng);
            let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, 16));
            let p = PackedMat::pack(&gq);
            let fused = p.matmul_dequant(&x);
            let explicit = matmul(&x, &gq.dequantize());
            for (a, b) in fused.data.iter().zip(&explicit.data) {
                assert!((a - b).abs() < 1e-3, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn compression_ratio_is_real() {
        // 2-bit packing of a 128x128 f32 matrix: 65536 B -> ~4096 B codes.
        let w = Mat::zeros(128, 128);
        let gq = GroupQuant::quantize(&w, QuantConfig::new(2, 128));
        let p = PackedMat::pack(&gq);
        let fp32 = 128 * 128 * 4;
        let ratio = fp32 as f64 / p.storage_bytes() as f64;
        assert!(ratio > 13.0, "ratio={ratio}"); // ~13.9x with group overhead
    }

    /// Property: pack∘unpack is the identity on random code matrices at
    /// every supported width, including the byte-aligned 8-bit case and
    /// row counts that do not land on byte boundaries for any width.
    #[test]
    fn prop_pack_roundtrip_random() {
        let mut rng = Pcg64::seeded(33);
        let widths = [2u32, 3, 4, 5, 8];
        for trial in 0..20 {
            let bits = widths[trial % widths.len()];
            let rows = 1 + rng.below_usize(70);
            let cols = 1 + rng.below_usize(9);
            let qmax = (1u32 << bits) - 1;
            let codes: Vec<u8> =
                (0..rows * cols).map(|_| rng.below(qmax as u64 + 1) as u8).collect();
            let ng = QuantConfig::new(bits, 16).n_groups(rows);
            let gq = GroupQuant::from_parts(
                QuantConfig::new(bits, 16),
                rows,
                cols,
                codes.clone(),
                vec![1.0; ng * cols],
                vec![0.0; ng * cols],
            );
            let back = PackedMat::pack(&gq).unpack();
            assert_eq!(back.codes, codes);
        }
    }
}
