//! Weight quantization substrate: group-wise asymmetric quantization,
//! sub-byte bit packing, RTN and GPTQ quantizers, and the mixed-precision
//! bit-width allocators (BSP / PMQ) the paper compares against.

pub mod alloc;
pub mod fused;
pub mod gptq;
pub mod pack;
pub mod quantizer;

pub use alloc::{BitAlloc, Allocator};
pub use fused::matmul_packed;
pub use gptq::{gptq_quantize_mat, GptqConfig};
pub use pack::PackedMat;
pub use quantizer::{quantize_dequant_mat, GroupQuant, QuantConfig};
