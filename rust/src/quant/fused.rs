//! Fused group-dequant GEMM over [`PackedMat`] — the kernel that makes the
//! packed execution path *servable* instead of just storable.
//!
//! `matmul_packed(x, w)` computes `x @ dequant(w)` without ever
//! materializing the f32 weight matrix. Per K-tile (KC rows of `w`), the
//! codes are unpacked + affine-corrected into an f32 strip **exactly
//! once**, then all rows of `x` consume the strip through the same
//! persistent-pool row parallelism as `tensor::matmul` — so the unpack cost
//! is `K×N` total, independent of both the batch size and the thread
//! count. The old naive `PackedMat::matmul_dequant` unpacked every full
//! column per call with zero reuse.
//!
//! Summation order per output element is identical to the dense
//! `matmul(x, &w.dequantize())` (k ascending, same KC blocking, same
//! `(code - zero) * scale` dequant expression), so the fused path matches
//! the dequantize-then-GEMM reference to float-roundoff — the equivalence
//! test below asserts 1e-5.

use super::pack::PackedMat;
use crate::tensor::pool::ThreadPool;
use crate::tensor::{simd, Mat};

/// K-tile height (matches the dense GEMM's KC so summation order agrees).
/// Must be a multiple of 8 so every tile starts on a byte boundary in the
/// packed column stream for *any* bit-width (kb*bits ≡ 0 mod 8), which
/// keeps tile unpacking branch-free.
const KC: usize = 256;

/// `x (m, k) @ dequant(w) (k, n)` with on-the-fly group dequantization.
pub fn matmul_packed(x: &Mat, w: &PackedMat) -> Mat {
    matmul_packed_on(ThreadPool::global(), x, w)
}

/// [`matmul_packed`] on an explicit pool (the model threads its own pool
/// through so `EngineConfig::threads` controls the packed path too).
pub fn matmul_packed_on(pool: &ThreadPool, x: &Mat, w: &PackedMat) -> Mat {
    assert_eq!(
        x.cols, w.rows,
        "matmul_packed inner-dim mismatch: {}x{} @ {}x{}",
        x.rows, x.cols, w.rows, w.cols
    );
    let n = w.cols;
    let mut out = Mat::zeros(x.rows, n);
    // Dequantized K-strip (KC × n, row-major) and a column staging buffer
    // (+8 slack for the whole-byte LUT unpackers). One strip per K-tile,
    // shared read-only by every worker thread.
    let mut strip = vec![0f32; KC * n];
    let mut colbuf = vec![0f32; KC + 8];
    for kb in (0..w.rows).step_by(KC) {
        let kend = (kb + KC).min(w.rows);
        let kc = kend - kb;
        unpack_tile(w, kb, kc, &mut colbuf, &mut strip);
        let strip_ref = &strip;
        let body = |r0: usize, r1: usize, cout: &mut [f32]| {
            for r in r0..r1 {
                let xrow = &x.row(r)[kb..kend];
                let crow = &mut cout[(r - r0) * n..(r - r0 + 1) * n];
                for (kk, &av) in xrow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let wrow = &strip_ref[kk * n..kk * n + n];
                    simd::axpy(crow, av, wrow);
                }
            }
        };
        // Accumulates into `out` (zero-initialized; each K-tile adds its
        // contribution), k ascending per element exactly like the dense
        // kernel's KC blocking.
        pool.run_rows(x.rows, n, &mut out.data, &body);
    }
    out
}

/// Unpack + dequantize rows `kb..kb+kc` of every column of `w` into
/// `strip` (row-major, `w.cols`-wide rows). `kb` must be a multiple of 8.
fn unpack_tile(w: &PackedMat, kb: usize, kc: usize, colbuf: &mut [f32], strip: &mut [f32]) {
    let n = w.cols;
    let bits = w.cfg.bits as usize;
    let cb = PackedMat::col_bytes(w.rows, w.cfg.bits);
    debug_assert!(kb % 8 == 0 && w.packed.len() == cb * n, "unaligned or short packed tile");
    let g = if w.cfg.group_size == 0 { w.rows } else { w.cfg.group_size };
    // Tile start is byte-aligned because kb % 8 == 0.
    let b0 = kb * bits / 8;
    let nbytes = (kc * bits).div_ceil(8);
    for c in 0..n {
        let col = &w.packed[c * cb + b0..c * cb + b0 + nbytes];
        match bits {
            2 => super::pack::unpack2_lut(col, colbuf),
            4 => super::pack::unpack4_lut(col, colbuf),
            8 => simd::bytes_to_f32(col, colbuf),
            _ => super::pack::unpack_generic(col, bits, kc, colbuf),
        }
        // Affine-correct per quantization group: w = (code - zero) * scale.
        // One `simd::affine` call per group — the correction stays scoped
        // to the group the packed format defines, so per-group (future
        // per-block mixed-precision) scale/zero layouts need no kernel
        // changes. All dispatch levels are bit-identical to the scalar
        // expression.
        let mut kk = 0;
        while kk < kc {
            let gi = (kb + kk) / g;
            let gend = ((gi + 1) * g - kb).min(kc);
            let scale = w.scales[gi * n + c];
            let zero = w.zeros[gi * n + c] as f32;
            simd::affine(&mut colbuf[kk..gend], zero, scale);
            kk = gend;
        }
        // Scatter the column into the row-major strip.
        for (kk, &v) in colbuf[..kc].iter().enumerate() {
            strip[kk * n + c] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantizer::{GroupQuant, QuantConfig};
    use crate::tensor::{matmul, Pcg64};

    fn max_abs_diff(a: &Mat, b: &Mat) -> f32 {
        a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    /// Acceptance: fused dequant-GEMM ≈ dense GEMM on dequantized weights
    /// to 1e-5, across bit-widths, group sizes, and shapes that exercise
    /// partial tiles in both K and N.
    #[test]
    fn fused_matches_dequant_then_dense_within_1e5() {
        let mut rng = Pcg64::seeded(91);
        for &bits in &[2u32, 3, 4, 5, 8] {
            for &(m, k, n, gs) in &[
                (1usize, 48usize, 20usize, 16usize),
                (7, 300, 140, 128),  // K spans two tiles (ragged second tile)
                (65, 256, 64, 0),    // parallel path (m >= 64), per-column groups
                (3, 37, 5, 16),      // ragged K, non-byte-aligned rows
            ] {
                let w = Mat::randn(k, n, 1.0, &mut rng);
                let x = Mat::randn(m, k, 1.0, &mut rng);
                let gq = GroupQuant::quantize(&w, QuantConfig::new(bits, gs));
                let p = PackedMat::pack(&gq);
                let fused = matmul_packed(&x, &p);
                let reference = matmul(&x, &gq.dequantize());
                let err = max_abs_diff(&fused, &reference);
                assert!(err <= 1e-5, "bits={bits} m={m} k={k} n={n} gs={gs}: err={err}");
            }
        }
    }

    #[test]
    fn fused_handles_empty_inputs() {
        let gq = GroupQuant::quantize(&Mat::zeros(16, 8), QuantConfig::new(4, 16));
        let p = PackedMat::pack(&gq);
        let out = matmul_packed(&Mat::zeros(0, 16), &p);
        assert_eq!(out.rows, 0);
        assert_eq!(out.cols, 8);
    }

    /// The strip is rebuilt per K-tile, never the whole matrix at once:
    /// spot-check a K far larger than one tile (guards tile indexing).
    #[test]
    fn multi_tile_k_dimension_exact() {
        let mut rng = Pcg64::seeded(92);
        let k = 2 * 256 + 19; // two full K-tiles plus a ragged tail
        let w = Mat::randn(k, 9, 0.7, &mut rng);
        let x = Mat::randn(2, k, 0.7, &mut rng);
        let gq = GroupQuant::quantize(&w, QuantConfig::new(3, 128));
        let p = PackedMat::pack(&gq);
        let err = max_abs_diff(&matmul_packed(&x, &p), &matmul(&x, &gq.dequantize()));
        assert!(err <= 1e-5, "err={err}");
    }
}
