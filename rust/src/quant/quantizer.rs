//! Group-wise asymmetric uniform quantization (the paper's setting:
//! group size 128, asymmetric, weight-only).
//!
//! A weight column group `g` of size G is mapped to integers
//! `q = clamp(round(w / scale) + zero, 0, 2^B - 1)` with
//! `scale = (max - min) / (2^B - 1)` and `zero = round(-min / scale)`;
//! dequantization is `w ≈ (q - zero) * scale`.

use crate::tensor::Mat;

/// Quantization settings for one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Target bit-width (2..=8).
    pub bits: u32,
    /// Group size along the input (row) dimension; each column is split into
    /// groups of this many consecutive rows. 0 = per-column (one group).
    pub group_size: usize,
}

impl QuantConfig {
    pub fn new(bits: u32, group_size: usize) -> Self {
        assert!((2..=8).contains(&bits), "bits must be in 2..=8");
        QuantConfig { bits, group_size }
    }

    /// Paper default: group size 128.
    pub fn paper(bits: u32) -> Self {
        Self::new(bits, 128)
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    /// Number of groups for a matrix with `rows` input features.
    pub fn n_groups(&self, rows: usize) -> usize {
        let g = if self.group_size == 0 { rows } else { self.group_size };
        rows.div_ceil(g)
    }

    pub fn group_rows(&self, rows: usize) -> usize {
        if self.group_size == 0 {
            rows
        } else {
            self.group_size.min(rows)
        }
    }

    /// Storage cost in bits per weight including scale+zero overhead
    /// (f32 scale + u8 zero per group, amortized).
    pub fn bits_per_weight(&self, rows: usize) -> f64 {
        let g = if self.group_size == 0 { rows } else { self.group_size.min(rows) };
        self.bits as f64 + (32.0 + 8.0) / g as f64
    }
}

/// Quantized representation of a (rows=in, cols=out) weight matrix:
/// integer codes plus per-(group, col) scale and zero-point.
#[derive(Clone, Debug)]
pub struct GroupQuant {
    pub cfg: QuantConfig,
    pub rows: usize,
    pub cols: usize,
    /// Integer codes, row-major, one u8 per weight (packing is separate —
    /// see [`super::pack::PackedMat`] for the storage form).
    pub codes: Vec<u8>,
    /// (n_groups, cols) scales.
    pub scales: Vec<f32>,
    /// (n_groups, cols) zero-points (stored as f32 for dequant math).
    pub zeros: Vec<f32>,
}

impl GroupQuant {
    /// Quantize a matrix (round-to-nearest within each group).
    pub fn quantize(w: &Mat, cfg: QuantConfig) -> GroupQuant {
        let rows = w.rows;
        let cols = w.cols;
        let g = if cfg.group_size == 0 { rows } else { cfg.group_size };
        let n_groups = rows.div_ceil(g);
        debug_assert!(w.data.len() == rows * cols, "Mat shape contract");
        let qmax = cfg.qmax() as f32;
        let mut codes = vec![0u8; rows * cols];
        let mut scales = vec![0f32; n_groups * cols];
        let mut zeros = vec![0f32; n_groups * cols];
        for gi in 0..n_groups {
            let r0 = gi * g;
            let r1 = (r0 + g).min(rows);
            for c in 0..cols {
                let mut mn = f32::INFINITY;
                let mut mx = f32::NEG_INFINITY;
                for r in r0..r1 {
                    let v = w.at(r, c);
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                // Ensure zero is representable & range non-degenerate.
                mn = mn.min(0.0);
                mx = mx.max(0.0);
                let scale = ((mx - mn) / qmax).max(1e-10);
                let zero = (-mn / scale).round().clamp(0.0, qmax);
                scales[gi * cols + c] = scale;
                zeros[gi * cols + c] = zero;
                for r in r0..r1 {
                    let q = (w.at(r, c) / scale + zero).round().clamp(0.0, qmax);
                    codes[r * cols + c] = q as u8;
                }
            }
        }
        GroupQuant { cfg, rows, cols, codes, scales, zeros }
    }

    /// Build from externally-computed codes (GPTQ fills this in).
    pub fn from_parts(
        cfg: QuantConfig,
        rows: usize,
        cols: usize,
        codes: Vec<u8>,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> GroupQuant {
        assert_eq!(codes.len(), rows * cols);
        let ng = cfg.n_groups(rows);
        assert_eq!(scales.len(), ng * cols);
        assert_eq!(zeros.len(), ng * cols);
        GroupQuant { cfg, rows, cols, codes, scales, zeros }
    }

    /// Dequantize to f32.
    pub fn dequantize(&self) -> Mat {
        debug_assert!(self.codes.len() == self.rows * self.cols, "code buffer shape");
        let g = if self.cfg.group_size == 0 { self.rows } else { self.cfg.group_size };
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let gi = r / g;
            let srow = &self.scales[gi * self.cols..(gi + 1) * self.cols];
            let zrow = &self.zeros[gi * self.cols..(gi + 1) * self.cols];
            let crow = &self.codes[r * self.cols..(r + 1) * self.cols];
            let orow = out.row_mut(r);
            for c in 0..self.cols {
                orow[c] = (crow[c] as f32 - zrow[c]) * srow[c];
            }
        }
        out
    }

    /// Storage bytes for the packed form (codes at `bits` + scales + zeros).
    pub fn storage_bytes(&self) -> usize {
        let code_bits = self.rows * self.cols * self.cfg.bits as usize;
        let ng = self.cfg.n_groups(self.rows);
        code_bits.div_ceil(8) + ng * self.cols * (4 + 1)
    }
}

/// Convenience: quantize then immediately dequantize (RTN baseline).
///
/// Calibration/analysis only — the inference path never materializes
/// dequantized weights anymore; packed matrices execute through
/// [`crate::quant::fused::matmul_packed`] instead.
pub fn quantize_dequant_mat(w: &Mat, cfg: QuantConfig) -> Mat {
    GroupQuant::quantize(w, cfg).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seeded(21);
        let w = Mat::randn(128, 32, 1.0, &mut rng);
        for bits in [2u32, 3, 4, 8] {
            let cfg = QuantConfig::new(bits, 32);
            let gq = GroupQuant::quantize(&w, cfg);
            let dq = gq.dequantize();
            // Per-group max error must be <= scale/2 (+ eps).
            let g = 32;
            for gi in 0..w.rows / g {
                for c in 0..w.cols {
                    let scale = gq.scales[gi * w.cols + c];
                    for r in gi * g..(gi + 1) * g {
                        let err = (w.at(r, c) - dq.at(r, c)).abs();
                        assert!(err <= scale * 0.5 + 1e-5, "bits={bits} err={err} scale={scale}");
                    }
                }
            }
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Pcg64::seeded(22);
        let w = Mat::randn(256, 16, 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let dq = quantize_dequant_mat(&w, QuantConfig::new(bits, 128));
            let mse = w.mse(&dq);
            assert!(mse < last, "bits={bits}: {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Pcg64::seeded(23);
        let w = Mat::randn(64, 8, 3.0, &mut rng);
        let cfg = QuantConfig::new(3, 16);
        let gq = GroupQuant::quantize(&w, cfg);
        assert!(gq.codes.iter().all(|&c| (c as i32) <= cfg.qmax()));
    }

    #[test]
    fn zero_weight_exactly_representable() {
        // With asymmetric quant the range always includes 0.
        let mut w = Mat::zeros(16, 4);
        for r in 0..16 {
            for c in 0..4 {
                *w.at_mut(r, c) = if r % 3 == 0 { 0.0 } else { (r as f32 - 8.0) * 0.1 };
            }
        }
        let dq = quantize_dequant_mat(&w, QuantConfig::new(4, 16));
        for r in (0..16).step_by(3) {
            for c in 0..4 {
                assert!(dq.at(r, c).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn ragged_last_group() {
        let mut rng = Pcg64::seeded(24);
        let w = Mat::randn(100, 8, 1.0, &mut rng); // 100 = 3*32 + 4
        let cfg = QuantConfig::new(4, 32);
        assert_eq!(cfg.n_groups(100), 4);
        let gq = GroupQuant::quantize(&w, cfg);
        let dq = gq.dequantize();
        assert!(w.mse(&dq) < 0.01);
    }

    #[test]
    fn storage_accounting() {
        let cfg = QuantConfig::new(2, 128);
        let gq = GroupQuant::quantize(&Mat::zeros(128, 128), cfg);
        // 128*128 weights at 2 bits = 4096 bytes, + 1 group * 128 cols * 5B.
        assert_eq!(gq.storage_bytes(), 4096 + 640);
        // bits_per_weight ~ 2 + 40/128.
        assert!((cfg.bits_per_weight(128) - (2.0 + 40.0 / 128.0)).abs() < 1e-9);
    }

    /// Property: quantization is idempotent — quantizing a dequantized
    /// matrix reproduces it exactly (codes map to themselves).
    #[test]
    fn prop_idempotent() {
        let mut rng = Pcg64::seeded(25);
        for _ in 0..5 {
            let rows = 32 * (1 + rng.below_usize(4));
            let cols = 1 + rng.below_usize(16);
            let w = Mat::randn(rows, cols, 1.0, &mut rng);
            let cfg = QuantConfig::new(3, 32);
            let d1 = quantize_dequant_mat(&w, cfg);
            let d2 = quantize_dequant_mat(&d1, cfg);
            for (a, b) in d1.data.iter().zip(&d2.data) {
                assert!((a - b).abs() < 2e-3, "{a} vs {b}");
            }
        }
    }
}
