//! GPTQ (Frantar et al., 2022): post-training weight quantization with
//! Hessian-aware error compensation — the quantizer QESC builds on
//! (paper §3.1, §4.2).
//!
//! For a layer `y = x @ W` with `W: (d_in, d_out)` and calibration inputs
//! `X: (tokens, d_in)`, GPTQ minimizes `||XW - XW_q||²` by processing input
//! features in order: quantize row `j` of `W`, divide the residual by the
//! Cholesky diagonal of the inverse Hessian `H⁻¹ = (2XᵀX + λI)⁻¹`, and fold
//! the error into the not-yet-quantized rows. Group scale/zero are computed
//! lazily when a group is first entered, on the *compensated* weights.

use super::quantizer::{GroupQuant, QuantConfig};
use crate::tensor::Mat;

/// GPTQ hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    pub quant: QuantConfig,
    /// Dampening fraction of mean(diag(H)) added to the diagonal.
    pub percdamp: f32,
}

impl GptqConfig {
    pub fn new(bits: u32, group_size: usize) -> Self {
        GptqConfig { quant: QuantConfig::new(bits, group_size), percdamp: 0.01 }
    }
}

/// Accumulated Hessian for one linear layer: `H = 2 Σ xᵀx`.
#[derive(Clone, Debug)]
pub struct Hessian {
    pub d: usize,
    pub h: Mat,
    pub n_samples: usize,
}

impl Hessian {
    pub fn new(d: usize) -> Self {
        Hessian { d, h: Mat::zeros(d, d), n_samples: 0 }
    }

    /// Add a batch of layer inputs (rows = tokens).
    pub fn update(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.d);
        // H += 2 * X^T X, computed as a rank-batch update.
        let xt = x.transpose();
        let xtx = crate::tensor::matmul(&xt, x);
        for (hv, &uv) in self.h.data.iter_mut().zip(&xtx.data) {
            *hv += 2.0 * uv;
        }
        self.n_samples += x.rows;
    }
}

/// Cholesky decomposition `A = L Lᵀ` (lower). Returns None if not PD.
fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = (sum.sqrt()) as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Invert an SPD matrix via its Cholesky factor.
fn spd_inverse(a: &Mat) -> Option<Mat> {
    debug_assert!(a.rows == a.cols, "spd_inverse needs a square matrix");
    let n = a.rows;
    let l = cholesky(a)?;
    // Solve L y = e_i, then Lᵀ x = y, column by column.
    let mut inv = Mat::zeros(n, n);
    let mut y = vec![0f64; n];
    let mut x = vec![0f64; n];
    for col in 0..n {
        // forward solve
        for i in 0..n {
            let mut sum = if i == col { 1.0f64 } else { 0.0 };
            for k in 0..i {
                sum -= l.at(i, k) as f64 * y[k];
            }
            y[i] = sum / l.at(i, i) as f64;
        }
        // backward solve
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l.at(k, i) as f64 * x[k];
            }
            x[i] = sum / l.at(i, i) as f64;
        }
        for i in 0..n {
            *inv.at_mut(i, col) = x[i] as f32;
        }
    }
    Some(inv)
}

/// Upper-triangular Cholesky factor `U` with `A = Uᵀ U` (i.e. `chol(A)ᵀ`).
///
/// This is the factor GPTQ's error propagation needs: with `H⁻¹ = UᵀU`,
/// the trailing-submatrix identity `(H[j:,j:])⁻¹ = U[j:,j:]ᵀ U[j:,j:]` makes
/// the OBQ update at step j exactly `w[j+1:] -= (w_j - q_j)/U[j,j] · U[j,j+1:]`.
fn cholesky_upper(a: &Mat) -> Option<Mat> {
    cholesky(a).map(|l| l.transpose())
}

/// Quantize one weight matrix with GPTQ given its accumulated Hessian.
/// Returns the quantized representation; `w` is not modified.
pub fn gptq_quantize_mat(w: &Mat, hess: &Hessian, cfg: GptqConfig) -> GroupQuant {
    let d = w.rows; // input features
    let n = w.cols; // output features
    assert_eq!(hess.d, d);
    let qcfg = cfg.quant;
    let g = if qcfg.group_size == 0 { d } else { qcfg.group_size };
    let qmax = qcfg.qmax() as f32;

    // Damped Hessian.
    let mut h = hess.h.clone();
    let mean_diag = (0..d).map(|i| h.at(i, i)).sum::<f32>() / d as f32;
    let damp = (cfg.percdamp * mean_diag).max(1e-8);
    // Dead features (zero diagonal) get unit diagonal and their weights
    // quantize plain-RTN (their error can't propagate usefully).
    for i in 0..d {
        if h.at(i, i) == 0.0 {
            *h.at_mut(i, i) = 1.0;
        }
        *h.at_mut(i, i) += damp;
    }

    // Hinv's reverse-Cholesky factor U with Hinv = U·? — what GPTQ needs is
    // the diagonal d_j = U[j,j] and the row U[j, j+1..] such that the error
    // propagation w[j+1..] -= (w_j - q_j)/U[j,j] * U[j, j+1..] minimizes the
    // quadratic proxy. This matches torch.linalg.cholesky(Hinv, upper=True).
    // Damping makes H SPD in exact arithmetic; if float pathology still
    // defeats the factorization, quantize this matrix plain-RTN instead of
    // unwinding mid-calibration (RTN is GPTQ's no-compensation baseline).
    let Some(u) = spd_inverse(&h).and_then(|hinv| cholesky_upper(&hinv)) else {
        return GroupQuant::quantize(w, qcfg);
    };

    let mut work = w.clone(); // compensated weights, mutated in place
    let mut codes = vec![0u8; d * n];
    let ng = qcfg.n_groups(d);
    let mut scales = vec![0f32; ng * n];
    let mut zeros = vec![0f32; ng * n];

    for j in 0..d {
        let gi = j / g;
        if j % g == 0 {
            // Entering a new group: fit scale/zero on the compensated
            // weights of this group (GPTQ's per-group lazy calibration).
            let r1 = (j + g).min(d);
            for c in 0..n {
                let mut mn = 0f32;
                let mut mx = 0f32;
                for r in j..r1 {
                    let v = work.at(r, c);
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                let scale = ((mx - mn) / qmax).max(1e-10);
                let zero = (-mn / scale).round().clamp(0.0, qmax);
                scales[gi * n + c] = scale;
                zeros[gi * n + c] = zero;
            }
        }
        let djj = u.at(j, j).max(1e-10);
        // Quantize row j and compute the scaled error.
        let mut err = vec![0f32; n];
        for c in 0..n {
            let scale = scales[gi * n + c];
            let zero = zeros[gi * n + c];
            let v = work.at(j, c);
            let q = (v / scale + zero).round().clamp(0.0, qmax);
            codes[j * n + c] = q as u8;
            let vq = (q - zero) * scale;
            err[c] = (v - vq) / djj;
        }
        // Propagate into the not-yet-quantized rows.
        for r in j + 1..d {
            let urj = u.at(j, r);
            if urj == 0.0 {
                continue;
            }
            let row = work.row_mut(r);
            for c in 0..n {
                row[c] -= urj * err[c];
            }
        }
    }

    GroupQuant::from_parts(qcfg, d, n, codes, scales, zeros)
}

/// Reconstruction loss `||XW - XW_q||² / tokens` for evaluating quantizers.
pub fn reconstruction_error(w: &Mat, wq: &Mat, x: &Mat) -> f32 {
    let y = crate::tensor::matmul(x, w);
    let yq = crate::tensor::matmul(x, wq);
    y.mse(&yq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantizer::quantize_dequant_mat;
    use crate::tensor::{matmul, Pcg64};

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seeded(41);
        let a = Mat::randn(8, 12, 1.0, &mut rng);
        let spd = {
            let at = a.transpose();
            let mut m = matmul(&a, &at); // 8x8 SPD
            for i in 0..8 {
                *m.at_mut(i, i) += 0.5;
            }
            m
        };
        let l = cholesky(&spd).unwrap();
        let rec = matmul(&l, &l.transpose());
        for (x, y) in rec.data.iter().zip(&spd.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn spd_inverse_correct() {
        let mut rng = Pcg64::seeded(42);
        let a = Mat::randn(6, 10, 1.0, &mut rng);
        let mut spd = matmul(&a, &a.transpose());
        for i in 0..6 {
            *spd.at_mut(i, i) += 1.0;
        }
        let inv = spd_inverse(&spd).unwrap();
        let prod = matmul(&spd, &inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-3, "{i},{j}");
            }
        }
    }

    #[test]
    fn chol_upper_factor_property() {
        let mut rng = Pcg64::seeded(43);
        let a = Mat::randn(5, 9, 1.0, &mut rng);
        let mut spd = matmul(&a, &a.transpose());
        for i in 0..5 {
            *spd.at_mut(i, i) += 1.0;
        }
        let u = cholesky_upper(&spd).unwrap();
        // Invariant: spd = Uᵀ U with U upper-triangular.
        let rec = matmul(&u.transpose(), &u);
        for (x, y) in rec.data.iter().zip(&spd.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
        // Trailing-submatrix identity: (spd[2:,2:])⁻¹ == U[2:,2:]ᵀ U[2:,2:]
        // computed on H⁻¹'s factor. Check via H⁻¹ = UᵀU path.
        let hinv = spd_inverse(&spd).unwrap();
        let uu = cholesky_upper(&hinv).unwrap();
        // H[2:,2:]⁻¹ from scratch:
        let mut sub = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                *sub.at_mut(i, j) = spd.at(i + 2, j + 2);
            }
        }
        let sub_inv = spd_inverse(&sub).unwrap();
        // U[2:,2:]ᵀ U[2:,2:]:
        let mut ut = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                let mut acc = 0.0;
                for k in 0..3 {
                    acc += uu.at(k + 2, i + 2) * uu.at(k + 2, j + 2);
                }
                *ut.at_mut(i, j) = acc;
            }
        }
        for (x, y) in ut.data.iter().zip(&sub_inv.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    /// Correlated calibration inputs: GPTQ must beat plain RTN on ||XW-XWq||.
    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        let mut rng = Pcg64::seeded(44);
        let d = 64;
        let n = 32;
        let w = Mat::randn(d, n, 1.0, &mut rng);
        // Correlated inputs: x = z @ A with a low-dim-ish mixing.
        let mix = Mat::randn(d, d, 0.3, &mut rng);
        let z = Mat::randn(256, d, 1.0, &mut rng);
        let x = matmul(&z, &mix);
        let mut hess = Hessian::new(d);
        hess.update(&x);
        for bits in [2u32, 3] {
            let cfg = GptqConfig::new(bits, 32);
            let gq = gptq_quantize_mat(&w, &hess, cfg);
            let w_gptq = gq.dequantize();
            let w_rtn = quantize_dequant_mat(&w, cfg.quant);
            let e_gptq = reconstruction_error(&w, &w_gptq, &x);
            let e_rtn = reconstruction_error(&w, &w_rtn, &x);
            assert!(
                e_gptq < e_rtn * 0.9,
                "bits={bits}: gptq {e_gptq} not well below rtn {e_rtn}"
            );
        }
    }

    #[test]
    fn gptq_codes_in_range_and_dims() {
        let mut rng = Pcg64::seeded(45);
        let w = Mat::randn(48, 16, 1.0, &mut rng);
        let x = Mat::randn(100, 48, 1.0, &mut rng);
        let mut hess = Hessian::new(48);
        hess.update(&x);
        let cfg = GptqConfig::new(4, 16);
        let gq = gptq_quantize_mat(&w, &hess, cfg);
        assert_eq!(gq.rows, 48);
        assert_eq!(gq.cols, 16);
        assert!(gq.codes.iter().all(|&c| c <= 15));
        // 8-bit should be near-lossless.
        let cfg8 = GptqConfig::new(8, 16);
        let gq8 = gptq_quantize_mat(&w, &hess, cfg8);
        assert!(w.mse(&gq8.dequantize()) < 1e-4);
    }

    #[test]
    fn hessian_accumulates_over_batches() {
        let mut rng = Pcg64::seeded(46);
        let x1 = Mat::randn(10, 8, 1.0, &mut rng);
        let x2 = Mat::randn(14, 8, 1.0, &mut rng);
        let mut ha = Hessian::new(8);
        ha.update(&x1);
        ha.update(&x2);
        let mut all = Mat::zeros(24, 8);
        all.data[..80].copy_from_slice(&x1.data);
        all.data[80..].copy_from_slice(&x2.data);
        let mut hb = Hessian::new(8);
        hb.update(&all);
        for (a, b) in ha.h.data.iter().zip(&hb.h.data) {
            assert!((a - b).abs() < 1e-3);
        }
        assert_eq!(ha.n_samples, 24);
    }

    #[test]
    fn dead_features_dont_crash() {
        // Column of X entirely zero -> zero Hessian diagonal entry.
        let mut rng = Pcg64::seeded(47);
        let mut x = Mat::randn(64, 16, 1.0, &mut rng);
        for r in 0..64 {
            *x.at_mut(r, 3) = 0.0;
        }
        let w = Mat::randn(16, 8, 1.0, &mut rng);
        let mut h = Hessian::new(16);
        h.update(&x);
        let gq = gptq_quantize_mat(&w, &h, GptqConfig::new(3, 8));
        assert!(gq.dequantize().data.iter().all(|v| v.is_finite()));
    }
}
