//! The QESC layer-by-layer compression pipeline (paper Fig 3, §4.2).
//!
//! Per transformer layer, in order:
//!
//! 1. **Quantize MHSA** (wq/wk/wv on the normed block input, wo on the
//!    attention context) with GPTQ at `mhsa_bits` — activations come from
//!    the *partially quantized* model, so earlier layers' quantization error
//!    is visible to later layers.
//! 2. **Calibrate the router**: fit the router weights so its logits on the
//!    quantized model's activations match the full-precision model's logits
//!    on the same tokens, under TopK-MSE (Eq. 5). This undoes the
//!    expert-shift that MHSA/expert quantization of *previous* layers plus
//!    this layer's MHSA quantization induced.
//! 3. **Quantize the experts** with GPTQ at the allocator-assigned
//!    bit-width; each expert's Hessian is accumulated from the tokens the
//!    (calibrated, quantized) router actually routes to it, falling back to
//!    all tokens for never-selected experts. w2's Hessian uses the hidden
//!    activations computed through the already-quantized w1/w3.
//!
//! Skipping step 2 (`calib_router = false`) yields exactly the GPTQ
//! baseline of Table 2; the allocator picks uniform vs BSP/PMQ
//! mixed-precision.
//!
//! Quantized matrices are emitted **packed** ([`WeightMat::Packed`]): the
//! compressed model serves through the fused dequant GEMM with the low-bit
//! codes as its only resident copy of those weights. Routers, norms and
//! embeddings stay f32 (the paper keeps them full-precision).

use crate::model::hooks::Hooks;
use crate::model::{Model, WeightMat, Weights};
use crate::quant::alloc::{Allocator, BitAlloc};
use crate::quant::gptq::{GptqConfig, Hessian};
use crate::quant::pack::PackedMat;
use crate::quant::quantizer::QuantConfig;
use crate::calib::adam::Adam;
use crate::calib::loss::{loss_grad, LossType};
use crate::tensor::ops::silu;
use crate::tensor::Mat;
use std::time::Instant;

/// QESC pipeline configuration.
#[derive(Clone, Debug)]
pub struct QescConfig {
    /// Expert bit-width allocation strategy.
    pub expert_alloc: Allocator,
    /// MHSA bit-width (paper: 4).
    pub mhsa_bits: u32,
    /// Quantization group size (paper: 128).
    pub group_size: usize,
    /// Router calibration loss (paper: TopK-MSE with model-specific K).
    pub loss: LossType,
    /// Enable router calibration (false = plain GPTQ baseline).
    pub calib_router: bool,
    /// Adam steps per router.
    pub router_steps: usize,
    pub router_lr: f32,
}

impl QescConfig {
    /// Paper-default QESC at a uniform expert bit-width.
    pub fn qesc(expert_bits: u32, topk_mse_k: usize) -> Self {
        QescConfig {
            expert_alloc: Allocator::Uniform { bits: expert_bits },
            mhsa_bits: 4,
            group_size: 128,
            loss: LossType::TopkMse(topk_mse_k),
            calib_router: true,
            router_steps: 120,
            router_lr: 2e-3,
        }
    }

    /// GPTQ baseline (no router calibration).
    pub fn gptq(expert_bits: u32) -> Self {
        QescConfig { calib_router: false, ..Self::qesc(expert_bits, 0) }
    }

    /// Paper's default K per zoo model (Table 10): ~2.5x top_k, min 4.
    pub fn default_k(cfg: &crate::model::ModelConfig) -> usize {
        match cfg.n_experts {
            0..=8 => cfg.n_experts, // mixtral-mini: few experts, use all
            9..=16 => 8,            // phi: 8
            _ => 20,                // deepseek / qwen: 20
        }
    }
}

/// What the pipeline reports (Table 7 time split + §6.2 diagnostics).
#[derive(Clone, Debug, Default)]
pub struct CompressReport {
    pub gptq_secs: f64,
    pub router_calib_secs: f64,
    /// Per-layer router loss before/after calibration.
    pub router_loss_before: Vec<f32>,
    pub router_loss_after: Vec<f32>,
    /// Packed storage bytes of all quantized weights + fp leftovers.
    pub compressed_bytes: usize,
    /// fp32 baseline bytes of the same weights.
    pub fp_bytes: usize,
    /// Average quantized bits per expert weight.
    pub avg_expert_bits: f64,
}

impl CompressReport {
    pub fn compression_ratio(&self) -> f64 {
        self.fp_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Run QESC on `model` with calibration sequences `calib` (token streams).
/// Returns the compressed model — MHSA and expert matrices packed at their
/// assigned bit-widths, served via the fused dequant GEMM — and the
/// report. The original model is not modified.
pub fn qesc_compress(model: &Model, calib: &[Vec<u32>], cfg: &QescConfig) -> (Model, CompressReport) {
    let mcfg = model.cfg().clone();
    let n_layers = mcfg.n_layers;
    let mut report = CompressReport {
        fp_bytes: model.weights.param_count() * 4,
        ..Default::default()
    };

    // ---- Pass 0: full-precision targets + ES frequencies for allocators.
    let mut fp_logits: Vec<Mat> = vec![Mat::zeros(0, 0); n_layers];
    let mut fp_record = crate::model::hooks::SelectionRecord::with_layers(n_layers);
    for seq in calib {
        let h = Hooks {
            capture_router_logits: Some(std::cell::RefCell::new(vec![None; n_layers])),
            record_selections: Some(std::cell::RefCell::new(
                crate::model::hooks::SelectionRecord::with_layers(n_layers),
            )),
            ..Default::default()
        };
        model.forward_with_hooks(seq, &h);
        // Both hooks were installed just above; a None here would mean the
        // forward pass dropped a capture cell.
        debug_assert!(
            h.capture_router_logits.is_some() && h.record_selections.is_some(),
            "hooks installed above"
        );
        let Some(cells) = h.capture_router_logits else { continue };
        for (li, m) in cells.into_inner().into_iter().enumerate() {
            debug_assert!(m.is_some(), "layer {li} router logits captured");
            let Some(m) = m else { continue };
            append_rows(&mut fp_logits[li], &m);
        }
        let Some(rec_cell) = h.record_selections else { continue };
        let rec = rec_cell.into_inner();
        for li in 0..n_layers {
            fp_record.layers[li].extend(rec.layers[li].iter().cloned());
        }
    }
    let freqs: Vec<Vec<f32>> =
        (0..n_layers).map(|li| fp_record.frequency(li, mcfg.n_experts)).collect();
    let alloc: BitAlloc =
        cfg.expert_alloc.allocate(n_layers, mcfg.n_experts, mcfg.n_shared, &freqs);
    report.avg_expert_bits = alloc.average_bits();

    // ---- Layer-by-layer quantize + calibrate.
    let mut work = Model::new(model.weights.clone());
    let mut compressed_bytes = fp_overhead_bytes(&model.weights);
    for li in 0..n_layers {
        // Capture current activations of the partially quantized model.
        let (mhsa_x, wo_x, moe_x) = capture_layer_inputs(&work, calib, li, n_layers);

        // (1) Quantize MHSA.
        let t0 = Instant::now();
        let mh_cfg = GptqConfig { quant: QuantConfig::new(cfg.mhsa_bits, cfg.group_size.min(mcfg.d_model)), percdamp: 0.01 };
        let mut h_in = Hessian::new(mcfg.d_model);
        h_in.update(&mhsa_x);
        let mut h_wo = Hessian::new(mcfg.d_model);
        h_wo.update(&wo_x);
        for (which, hess) in [(0usize, &h_in), (1, &h_in), (2, &h_in), (3, &h_wo)] {
            let w = match which {
                0 => &work.weights.layers[li].wq,
                1 => &work.weights.layers[li].wk,
                2 => &work.weights.layers[li].wv,
                _ => &work.weights.layers[li].wo,
            };
            let gq = w.gptq_quantize(hess, mh_cfg);
            let pm = PackedMat::pack(&gq);
            compressed_bytes += pm.storage_bytes();
            // Install the packed form: later layers' activation capture (and
            // the final served model) run through the fused dequant GEMM.
            let wm = WeightMat::Packed(pm);
            match which {
                0 => work.weights.layers[li].wq = wm,
                1 => work.weights.layers[li].wk = wm,
                2 => work.weights.layers[li].wv = wm,
                _ => work.weights.layers[li].wo = wm,
            }
        }
        report.gptq_secs += t0.elapsed().as_secs_f64();

        // Re-capture MoE input: it now reflects this layer's quantized MHSA.
        let (_, _, moe_x_q) = capture_layer_inputs(&work, calib, li, n_layers);
        let _ = moe_x; // superseded by moe_x_q

        // (2) Calibrate the router.
        let t1 = Instant::now();
        {
            let router = &mut work.weights.layers[li].router;
            let (before, _) = loss_grad(
                effective_loss(cfg, mcfg.top_k),
                router,
                &moe_x_q,
                &fp_logits[li],
            );
            report.router_loss_before.push(before);
            if cfg.calib_router {
                let mut opt = Adam::new(router.data.len(), cfg.router_lr);
                for _ in 0..cfg.router_steps {
                    let (_, grad) =
                        loss_grad(effective_loss(cfg, mcfg.top_k), router, &moe_x_q, &fp_logits[li]);
                    opt.step(&mut router.data, &grad.data);
                }
            }
            let (after, _) = loss_grad(
                effective_loss(cfg, mcfg.top_k),
                router,
                &moe_x_q,
                &fp_logits[li],
            );
            report.router_loss_after.push(after);
        }
        report.router_calib_secs += t1.elapsed().as_secs_f64();

        // (3) Quantize the experts with routed-token Hessians.
        let t2 = Instant::now();
        let routed = route_tokens(&work, &moe_x_q, li);
        for e in 0..mcfg.n_experts {
            let bits = alloc.bits[li][e];
            let x_e: Mat = if routed[e].is_empty() {
                moe_x_q.clone()
            } else {
                moe_x_q.gather_rows(&routed[e])
            };
            compressed_bytes +=
                quantize_expert(work.weights.layers[li].expert_mut(e), &x_e, bits, cfg);
        }
        for s in 0..mcfg.n_shared {
            let bits = alloc.shared_bits[li][s];
            compressed_bytes +=
                quantize_expert(work.weights.layers[li].shared_expert_mut(s), &moe_x_q, bits, cfg);
        }
        report.gptq_secs += t2.elapsed().as_secs_f64();
    }
    report.compressed_bytes = compressed_bytes;
    (work, report)
}

fn effective_loss(cfg: &QescConfig, top_k: usize) -> LossType {
    match cfg.loss {
        LossType::TopkMse(0) => LossType::TopkMse(top_k.max(1)),
        other => other,
    }
}

/// fp16-equivalent bytes of everything QESC leaves unquantized
/// (embeddings, norms, routers).
fn fp_overhead_bytes(w: &Weights) -> usize {
    let mut n = w.embed.data.len() + w.final_norm.len();
    for l in &w.layers {
        n += l.attn_norm.len() + l.ffn_norm.len() + l.router.data.len();
    }
    n * 2 // fp16 on disk
}

/// GPTQ-quantize one expert in place, leaving it **packed**; returns the
/// packed storage bytes (which are now also the resident bytes).
fn quantize_expert(
    e: &mut crate::model::ExpertWeights,
    x: &Mat,
    bits: u32,
    cfg: &QescConfig,
) -> usize {
    let d_model = e.w1.rows();
    let d_ff = e.w1.cols();
    let gcfg = |dim: usize| GptqConfig {
        quant: QuantConfig::new(bits, cfg.group_size.min(dim)),
        percdamp: 0.01,
    };
    let mut bytes = 0usize;
    let mut h_x = Hessian::new(d_model);
    h_x.update(x);
    // w1 and w3 both consume x.
    let gq1 = e.w1.gptq_quantize(&h_x, gcfg(d_model));
    let p1 = PackedMat::pack(&gq1);
    bytes += p1.storage_bytes();
    e.w1 = WeightMat::Packed(p1);
    let gq3 = e.w3.gptq_quantize(&h_x, gcfg(d_model));
    let p3 = PackedMat::pack(&gq3);
    bytes += p3.storage_bytes();
    e.w3 = WeightMat::Packed(p3);
    // Hidden activations through the *quantized* (packed) w1/w3 feed w2.
    let mut hidden = e.w1.matmul(x);
    let b = e.w3.matmul(x);
    for (hv, &bv) in hidden.data.iter_mut().zip(&b.data) {
        *hv = silu(*hv) * bv;
    }
    let mut h_h = Hessian::new(d_ff);
    h_h.update(&hidden);
    let gq2 = e.w2.gptq_quantize(&h_h, gcfg(d_ff));
    let p2 = PackedMat::pack(&gq2);
    bytes += p2.storage_bytes();
    e.w2 = WeightMat::Packed(p2);
    bytes
}

/// Which calibration tokens the working model routes to each expert of
/// layer `li` (top-k of the current router on the given activations).
fn route_tokens(model: &Model, moe_x: &Mat, li: usize) -> Vec<Vec<usize>> {
    let mcfg = model.cfg();
    debug_assert!(li < model.weights.layers.len(), "layer {li} out of {}", model.weights.layers.len());
    let logits = crate::tensor::matmul(moe_x, &model.weights.layers[li].router);
    let mut routed: Vec<Vec<usize>> = vec![Vec::new(); mcfg.n_experts];
    for t in 0..logits.rows {
        for &e in &crate::tensor::ops::topk_indices(logits.row(t), mcfg.top_k) {
            routed[e].push(t);
        }
    }
    routed
}

/// Run the working model over all calibration sequences, returning the
/// concatenated (mhsa_input, wo_input, moe_input) activations of layer `li`.
fn capture_layer_inputs(
    model: &Model,
    calib: &[Vec<u32>],
    li: usize,
    n_layers: usize,
) -> (Mat, Mat, Mat) {
    let mut mhsa = Mat::zeros(0, 0);
    let mut wo = Mat::zeros(0, 0);
    let mut moe = Mat::zeros(0, 0);
    for seq in calib {
        let h = Hooks::capturing(n_layers);
        model.forward_with_hooks(seq, &h);
        // `Hooks::capturing` installs all three capture cells and the
        // forward pass fills every layer slot; a miss here is a hook bug.
        debug_assert!(
            h.capture_mhsa_inputs.is_some()
                && h.capture_wo_inputs.is_some()
                && h.capture_moe_inputs.is_some(),
            "capturing hooks installed above"
        );
        let (Some(mh), Some(woh), Some(moeh)) =
            (&h.capture_mhsa_inputs, &h.capture_wo_inputs, &h.capture_moe_inputs)
        else {
            continue;
        };
        debug_assert!(
            mh.borrow()[li].is_some() && woh.borrow()[li].is_some() && moeh.borrow()[li].is_some(),
            "layer {li} activations captured"
        );
        if let (Some(a), Some(b), Some(c)) =
            (&mh.borrow()[li], &woh.borrow()[li], &moeh.borrow()[li])
        {
            append_rows(&mut mhsa, a);
            append_rows(&mut wo, b);
            append_rows(&mut moe, c);
        }
    }
    (mhsa, wo, moe)
}

fn append_rows(dst: &mut Mat, src: &Mat) {
    if dst.rows == 0 {
        *dst = src.clone();
        return;
    }
    assert_eq!(dst.cols, src.cols);
    dst.data.extend_from_slice(&src.data);
    dst.rows += src.rows;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::tensor::Pcg64;

    fn tiny_model() -> Model {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        Model::new(Weights::init(&cfg, 5))
    }

    fn calib_seqs(n: usize, len: usize, vocab: u64) -> Vec<Vec<u32>> {
        let mut rng = Pcg64::seeded(71);
        (0..n).map(|_| (0..len).map(|_| rng.below(vocab) as u32).collect()).collect()
    }

    #[test]
    fn pipeline_runs_and_reduces_router_loss() {
        let m = tiny_model();
        let calib = calib_seqs(3, 16, 32);
        let cfg = QescConfig {
            router_steps: 60,
            ..QescConfig::qesc(3, 3)
        };
        let (qm, report) = qesc_compress(&m, &calib, &cfg);
        assert_eq!(report.router_loss_before.len(), 2);
        // Calibration must not increase the loss on the calibration set.
        for (b, a) in report.router_loss_before.iter().zip(&report.router_loss_after) {
            assert!(a <= b, "calibration worsened router loss: {b} -> {a}");
        }
        // Quantized weights actually changed, and are emitted packed.
        assert!(qm.weights.layers[0].experts()[0].w1.is_packed());
        assert!(qm.weights.layers[0].wq.is_packed());
        let orig = m.weights.layers[0].experts()[0].w1.to_dense();
        let quant = qm.weights.layers[0].experts()[0].w1.to_dense();
        let diff = orig.data.iter().zip(&quant.data).any(|(x, y)| (x - y).abs() > 1e-6);
        assert!(diff);
        // Storage accounting is sane: compressed well below fp32, and the
        // *resident* model actually shrank (the point of the packed path).
        assert!(report.compressed_bytes < report.fp_bytes / 3);
        assert!(report.compression_ratio() > 3.0);
        assert!(
            qm.weights.storage_bytes() < m.weights.storage_bytes(),
            "packed model must be smaller resident: {} vs {}",
            qm.weights.storage_bytes(),
            m.weights.storage_bytes()
        );
        assert!(qm.weights.expert_storage_bytes() < m.weights.expert_storage_bytes() / 3);
    }

    #[test]
    fn gptq_baseline_leaves_router_untouched() {
        let m = tiny_model();
        let calib = calib_seqs(2, 12, 32);
        let (qm, _) = qesc_compress(&m, &calib, &QescConfig::gptq(3));
        for li in 0..2 {
            assert_eq!(qm.weights.layers[li].router.data, m.weights.layers[li].router.data);
        }
    }

    #[test]
    fn calibrated_model_has_lower_shift_than_uncalibrated() {
        // The Fig-6 claim, end to end on the tiny model: QESC's change rate
        // <= GPTQ's change rate on held-out tokens.
        let m = tiny_model();
        let calib = calib_seqs(4, 16, 32);
        let eval = calib_seqs(3, 16, 32);
        let (gptq_m, _) = qesc_compress(&m, &calib, &QescConfig::gptq(2));
        let cfgq = QescConfig { router_steps: 150, ..QescConfig::qesc(2, 3) };
        let (qesc_m, _) = qesc_compress(&m, &calib, &cfgq);
        let record = |mm: &Model| {
            let h = Hooks::recording(2);
            for seq in &eval {
                mm.forward_with_hooks(seq, &h);
            }
            h.take_selections().unwrap()
        };
        let fp = record(&m);
        let rg = record(&gptq_m);
        let rq = record(&qesc_m);
        let cg = crate::calib::shift::mean_change_rates(&fp, &rg);
        let cq = crate::calib::shift::mean_change_rates(&fp, &rq);
        // Allow equality (tiny model can saturate) but not regression.
        assert!(
            cq.any_changed <= cg.any_changed + 0.02,
            "QESC shift {:?} vs GPTQ {:?}",
            cq,
            cg
        );
    }

    #[test]
    fn mixed_precision_allocators_plug_in() {
        let m = tiny_model();
        let calib = calib_seqs(2, 12, 32);
        let bsp = QescConfig {
            expert_alloc: Allocator::Bsp { hi: 4, lo: 2, hi_count: 2, shared: 8 },
            calib_router: false,
            ..QescConfig::qesc(2, 3)
        };
        let (_, rep) = qesc_compress(&m, &calib, &bsp);
        assert!(rep.avg_expert_bits > 2.0 && rep.avg_expert_bits < 5.0);
        let pmq = QescConfig {
            expert_alloc: Allocator::Pmq { avg_bits: 2.5, shared: 3 },
            calib_router: false,
            ..QescConfig::qesc(2, 3)
        };
        let (_, rep2) = qesc_compress(&m, &calib, &pmq);
        assert!((rep2.avg_expert_bits - 2.5).abs() < 0.3, "{}", rep2.avg_expert_bits);
    }
}
