//! Router-calibration losses (paper §4.3).
//!
//! Given the full-precision router's logits `y = W·x` on full-precision
//! activations and the calibrated router's logits `ŷ = Ŵ·x̂` on *quantized*
//! activations, we fit `Ŵ` to minimize either
//!
//! * **MSE** over all N experts, or
//! * **TopK-MSE** (Eq. 5): MSE over only the K highest-probability experts
//!   *of the full-precision model* — the experts that matter for selection.
//!   Fig 4's observation: ~96% of shifted experts sit within the top-16 of
//!   the probability distribution, but those ranks carry only ~29% of the
//!   full MSE loss, so full MSE drowns the signal in noise from never-
//!   selected experts.

use crate::tensor::ops::topk_indices;
use crate::tensor::Mat;

/// Which calibration loss to use (Table 6 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossType {
    Mse,
    /// TopK-MSE with the given K.
    TopkMse(usize),
}

/// MSE loss + gradient w.r.t. router weights `w` (d × n).
///
/// `x_q`: quantized-model activations (tokens × d);
/// `target`: FP-model logits (tokens × n). Returns (loss, grad(d × n)).
pub fn mse_loss_grad(w: &Mat, x_q: &Mat, target: &Mat) -> (f32, Mat) {
    let pred = crate::tensor::matmul(x_q, w);
    let tokens = x_q.rows;
    let n = w.cols;
    let mut grad = Mat::zeros(w.rows, w.cols);
    let mut loss = 0f64;
    // dL/dW = 2/T/N * X^T (pred - target)
    let mut diff = Mat::zeros(tokens, n);
    for i in 0..tokens * n {
        let d = pred.data[i] - target.data[i];
        diff.data[i] = d;
        loss += (d * d) as f64;
    }
    let scale = 2.0 / (tokens * n) as f32;
    let xt = x_q.transpose();
    let g = crate::tensor::matmul(&xt, &diff);
    for i in 0..grad.data.len() {
        grad.data[i] = g.data[i] * scale;
    }
    ((loss / (tokens * n) as f64) as f32, grad)
}

/// TopK-MSE loss + gradient (Eq. 5): per token, only the K indices with the
/// highest *target* logits contribute.
pub fn topk_mse_loss_grad(w: &Mat, x_q: &Mat, target: &Mat, k: usize) -> (f32, Mat) {
    let pred = crate::tensor::matmul(x_q, w);
    let tokens = x_q.rows;
    let n = w.cols;
    let k = k.min(n);
    let mut grad = Mat::zeros(w.rows, w.cols);
    let mut loss = 0f64;
    // Build the masked diff, then one GEMM for the gradient.
    let mut diff = Mat::zeros(tokens, n);
    for t in 0..tokens {
        let top = topk_indices(target.row(t), k);
        for &i in &top {
            let d = pred.at(t, i) - target.at(t, i);
            *diff.at_mut(t, i) = d;
            loss += (d * d) as f64;
        }
    }
    let scale = 2.0 / (tokens * k) as f32;
    let xt = x_q.transpose();
    let g = crate::tensor::matmul(&xt, &diff);
    for i in 0..grad.data.len() {
        grad.data[i] = g.data[i] * scale;
    }
    ((loss / (tokens * k) as f64) as f32, grad)
}

/// Dispatch on [`LossType`].
pub fn loss_grad(lt: LossType, w: &Mat, x_q: &Mat, target: &Mat) -> (f32, Mat) {
    match lt {
        LossType::Mse => mse_loss_grad(w, x_q, target),
        LossType::TopkMse(k) => topk_mse_loss_grad(w, x_q, target, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn zero_loss_at_optimum() {
        let mut rng = Pcg64::seeded(51);
        let w = Mat::randn(8, 6, 1.0, &mut rng);
        let x = Mat::randn(20, 8, 1.0, &mut rng);
        let target = crate::tensor::matmul(&x, &w);
        let (l1, g1) = mse_loss_grad(&w, &x, &target);
        let (l2, g2) = topk_mse_loss_grad(&w, &x, &target, 3);
        assert!(l1 < 1e-10);
        assert!(l2 < 1e-10);
        assert!(g1.data.iter().all(|&g| g.abs() < 1e-6));
        assert!(g2.data.iter().all(|&g| g.abs() < 1e-6));
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Pcg64::seeded(52);
        let mut w = Mat::randn(5, 4, 0.5, &mut rng);
        let x = Mat::randn(12, 5, 1.0, &mut rng);
        let wt = Mat::randn(5, 4, 0.5, &mut rng);
        let target = crate::tensor::matmul(&x, &wt);
        for lt in [LossType::Mse, LossType::TopkMse(2)] {
            let (_, grad) = loss_grad(lt, &w, &x, &target);
            let eps = 1e-3;
            for idx in [0usize, 7, 13, 19] {
                let orig = w.data[idx];
                w.data[idx] = orig + eps;
                let (lp, _) = loss_grad(lt, &w, &x, &target);
                w.data[idx] = orig - eps;
                let (lm, _) = loss_grad(lt, &w, &x, &target);
                w.data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                assert!(
                    (fd - grad.data[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{lt:?} idx={idx}: fd={fd} analytic={}",
                    grad.data[idx]
                );
            }
        }
    }

    #[test]
    fn topk_ignores_low_rank_targets() {
        // Perturb prediction only on the lowest-target expert: TopK loss
        // must not change, MSE must.
        let x = Mat::from_vec(1, 2, vec![1.0, 0.0]);
        // w maps to logits = first row of w.
        let w_good = Mat::from_vec(2, 3, vec![3.0, 2.0, -5.0, 0.0, 0.0, 0.0]);
        let target = Mat::from_vec(1, 3, vec![3.0, 2.0, 1.0]);
        let (lt_topk, _) = topk_mse_loss_grad(&w_good, &x, &target, 2);
        let (lt_mse, _) = mse_loss_grad(&w_good, &x, &target);
        // top-2 of target are experts 0,1 — both match exactly.
        assert!(lt_topk < 1e-10, "topk loss={lt_topk}");
        assert!(lt_mse > 1.0, "mse loss={lt_mse}");
    }

    #[test]
    fn topk_equals_mse_when_k_is_n() {
        let mut rng = Pcg64::seeded(53);
        let w = Mat::randn(6, 5, 1.0, &mut rng);
        let x = Mat::randn(9, 6, 1.0, &mut rng);
        let t = Mat::randn(9, 5, 1.0, &mut rng);
        let (l1, g1) = mse_loss_grad(&w, &x, &t);
        let (l2, g2) = topk_mse_loss_grad(&w, &x, &t, 5);
        assert!((l1 - l2).abs() < 1e-6);
        for (a, b) in g1.data.iter().zip(&g2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
