//! Expert-shift metrics.
//!
//! * [`change_rates`] — the three per-layer metrics plotted in Fig 6:
//!   change-rate 1 = all of a token's selections changed, change-rate 2 =
//!   at least one changed, change-rate 3 = half or more changed.
//! * [`shift_rank_analysis`] — Fig 4: of the experts that were selected at
//!   full precision but not after quantization ("shifted experts"), what
//!   fraction still sits within the quantized model's top-R probability
//!   ranks, and what fraction of the total MSE loss those ranks carry.

use crate::model::hooks::SelectionRecord;
use crate::tensor::ops::topk_indices;
use crate::tensor::Mat;

/// Expert-selection change rates relative to a reference (FP) record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChangeRates {
    /// Fraction of tokens where ALL selected experts changed.
    pub all_changed: f32,
    /// Fraction of tokens where AT LEAST ONE selection changed.
    pub any_changed: f32,
    /// Fraction of tokens where HALF OR MORE selections changed.
    pub half_changed: f32,
}

/// Compute change rates for one layer between two selection records taken
/// on the same token stream.
pub fn change_rates(reference: &SelectionRecord, other: &SelectionRecord, layer: usize) -> ChangeRates {
    let ref_toks = &reference.layers[layer];
    let oth_toks = &other.layers[layer];
    assert_eq!(ref_toks.len(), oth_toks.len(), "records cover different token streams");
    let n = ref_toks.len();
    if n == 0 {
        return ChangeRates::default();
    }
    let (mut all_c, mut any_c, mut half_c) = (0usize, 0usize, 0usize);
    for (a, b) in ref_toks.iter().zip(oth_toks) {
        let k = a.experts.len();
        let changed = a
            .experts
            .iter()
            .filter(|e| !b.experts.contains(e))
            .count();
        if changed == k {
            all_c += 1;
        }
        if changed > 0 {
            any_c += 1;
        }
        if 2 * changed >= k {
            half_c += 1;
        }
    }
    ChangeRates {
        all_changed: all_c as f32 / n as f32,
        any_changed: any_c as f32 / n as f32,
        half_changed: half_c as f32 / n as f32,
    }
}

/// Averaged change rates across all layers.
pub fn mean_change_rates(reference: &SelectionRecord, other: &SelectionRecord) -> ChangeRates {
    let l = reference.layers.len();
    let mut acc = ChangeRates::default();
    for i in 0..l {
        let c = change_rates(reference, other, i);
        acc.all_changed += c.all_changed;
        acc.any_changed += c.any_changed;
        acc.half_changed += c.half_changed;
    }
    ChangeRates {
        all_changed: acc.all_changed / l as f32,
        any_changed: acc.any_changed / l as f32,
        half_changed: acc.half_changed / l as f32,
    }
}

/// One point of the Fig-4 curves at rank cutoff R.
#[derive(Clone, Debug)]
pub struct ShiftRankPoint {
    pub rank: usize,
    /// Cumulative fraction of shifted experts whose quantized-model rank < R.
    pub shifted_within: f32,
    /// Cumulative fraction of total MSE logit loss carried by ranks < R.
    pub loss_within: f32,
}

/// Fig-4 analysis. `fp_logits` / `q_logits`: (tokens × n_experts) router
/// logits of the FP and quantized models on the same tokens; `k` = experts
/// selected per token. Returns one point per rank cutoff 1..=n.
pub fn shift_rank_analysis(fp_logits: &Mat, q_logits: &Mat, k: usize) -> Vec<ShiftRankPoint> {
    assert_eq!(fp_logits.rows, q_logits.rows);
    assert_eq!(fp_logits.cols, q_logits.cols);
    let n = fp_logits.cols;
    let tokens = fp_logits.rows;
    let mut shifted_at_rank = vec![0u64; n]; // rank position in q model
    let mut total_shifted = 0u64;
    let mut loss_at_rank = vec![0f64; n];
    let mut total_loss = 0f64;
    for t in 0..tokens {
        let fp_top = topk_indices(fp_logits.row(t), k);
        let q_order = topk_indices(q_logits.row(t), n); // full ranking
        let q_top: &[usize] = &q_order[..k];
        // Shifted experts: in fp_top but not q_top. Record their q-rank.
        for &e in &fp_top {
            if !q_top.contains(&e) {
                // `q_order` is a full ranking over all n experts, so every
                // fp-selected expert appears somewhere in it.
                debug_assert!(q_order.contains(&e), "expert {e} missing from full ranking");
                let Some(rank) = q_order.iter().position(|&x| x == e) else { continue };
                shifted_at_rank[rank] += 1;
                total_shifted += 1;
            }
        }
        // Loss mass per q-rank position.
        for (rank, &e) in q_order.iter().enumerate() {
            let d = (fp_logits.at(t, e) - q_logits.at(t, e)) as f64;
            loss_at_rank[rank] += d * d;
            total_loss += d * d;
        }
    }
    let mut out = Vec::with_capacity(n);
    let mut cum_shift = 0u64;
    let mut cum_loss = 0f64;
    for r in 0..n {
        cum_shift += shifted_at_rank[r];
        cum_loss += loss_at_rank[r];
        out.push(ShiftRankPoint {
            rank: r + 1,
            shifted_within: if total_shifted == 0 {
                0.0
            } else {
                cum_shift as f32 / total_shifted as f32
            },
            loss_within: if total_loss == 0.0 { 0.0 } else { (cum_loss / total_loss) as f32 },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::hooks::TokenSelection;

    fn rec(selections: Vec<Vec<u16>>) -> SelectionRecord {
        let mut r = SelectionRecord::with_layers(1);
        for e in selections {
            let scores = vec![0.5; e.len()];
            r.layers[0].push(TokenSelection { experts: e, scores });
        }
        r
    }

    #[test]
    fn change_rates_basics() {
        let a = rec(vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]);
        let b = rec(vec![
            vec![0, 1], // unchanged
            vec![2, 4], // one changed (half)
            vec![6, 7], // all changed
            vec![7, 6], // order differs but same set -> unchanged
        ]);
        let c = change_rates(&a, &b, 0);
        assert!((c.any_changed - 0.5).abs() < 1e-6);
        assert!((c.all_changed - 0.25).abs() < 1e-6);
        assert!((c.half_changed - 0.5).abs() < 1e-6);
    }

    #[test]
    fn identical_records_zero_rates() {
        let a = rec(vec![vec![0, 1], vec![2, 3]]);
        let c = change_rates(&a, &a.clone(), 0);
        assert_eq!(c, ChangeRates::default());
    }

    #[test]
    fn shift_rank_monotone_and_bounded() {
        let mut rng = crate::tensor::Pcg64::seeded(61);
        let fp = Mat::randn(50, 16, 1.0, &mut rng);
        // Quantized logits = fp + noise.
        let mut q = fp.clone();
        for v in q.data.iter_mut() {
            *v += rng.gaussian() * 0.3;
        }
        let pts = shift_rank_analysis(&fp, &q, 2);
        assert_eq!(pts.len(), 16);
        for w in pts.windows(2) {
            assert!(w[1].shifted_within >= w[0].shifted_within);
            assert!(w[1].loss_within >= w[0].loss_within - 1e-6);
        }
        assert!((pts[15].shifted_within - 1.0).abs() < 1e-6);
        assert!((pts[15].loss_within - 1.0).abs() < 1e-6);
        // No expert can shift into rank < k (ranks 0..k are the selected set).
        assert_eq!(pts[1].shifted_within, 0.0);
    }

    #[test]
    fn fig4_premise_shifted_concentrate_near_topk() {
        // With small perturbations, shifted experts should overwhelmingly be
        // near the top of the distribution — the paper's Fig-4 observation.
        let mut rng = crate::tensor::Pcg64::seeded(62);
        let fp = Mat::randn(200, 64, 1.0, &mut rng);
        let mut q = fp.clone();
        for v in q.data.iter_mut() {
            *v += rng.gaussian() * 0.15;
        }
        let pts = shift_rank_analysis(&fp, &q, 6);
        // >90% of shifted experts within top-16 of 64 ...
        assert!(pts[15].shifted_within > 0.9, "{}", pts[15].shifted_within);
        // ... while top-16 carries well under 80% of the loss mass.
        assert!(pts[15].loss_within < 0.8, "{}", pts[15].loss_within);
    }
}
