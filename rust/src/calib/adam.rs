//! Minimal Adam optimizer for router calibration (the routers are tiny —
//! d_model × n_experts — so a dependency-free implementation is plenty).

/// Adam state over a flat parameter vector.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(n_params: usize, lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; n_params], v: vec![0.0; n_params], t: 0 }
    }

    /// One update step: params -= lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = ||x - target||^2
        let target = [3.0f32, -2.0, 0.5];
        let mut x = [0.0f32; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ti) in x.iter().zip(&target) {
            assert!((xi - ti).abs() < 1e-2, "{xi} vs {ti}");
        }
    }

    #[test]
    fn zero_grad_no_move() {
        let mut x = [1.0f32, 2.0];
        let mut opt = Adam::new(2, 0.1);
        opt.step(&mut x, &[0.0, 0.0]);
        assert_eq!(x, [1.0, 2.0]);
    }
}
