//! QESC — Quantization with Expert-Selection Calibration (paper §4).
//!
//! * [`loss`] — TopK-MSE (Eq. 5) and plain MSE router-calibration losses.
//! * [`adam`] — the small Adam optimizer used to fit router weights.
//! * [`shift`] — expert-shift metrics: change-rates 1/2/3 (Fig 6) and the
//!   shifted-expert rank / loss-mass analysis behind Fig 4.
//! * [`qesc`] — the layer-by-layer pipeline (Fig 3): quantize MHSA →
//!   calibrate router → quantize experts, per layer, so selection shift
//!   never accumulates across layers.

pub mod adam;
pub mod loss;
pub mod qesc;
pub mod shift;

pub use adam::Adam;
pub use loss::{mse_loss_grad, topk_mse_loss_grad, LossType};
pub use qesc::{qesc_compress, CompressReport, QescConfig};
pub use shift::{change_rates, shift_rank_analysis, ChangeRates};
