//! Small self-contained utilities: minimal JSON, binary tensor IO, a
//! timing/statistics harness (the offline registry has no serde/criterion).

pub mod binio;
pub mod env;
pub mod json;
pub mod timing;

pub use json::Json;
pub use timing::{bench, BenchResult, Timer};
