//! Minimal JSON value type + parser + serializer.
//!
//! Used for the AOT artifact manifest and experiment result files. Covers
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); no serde in the offline registry, so we own this ~300 lines.
//!
//! Two access layers: the `as_*` accessors return `Option` for
//! shape-probing, and the `req*` accessors return [`anyhow::Result`] with
//! the missing/mistyped key named in the error — use the latter when a
//! document (a manifest, a results file) is *required* to have a field, so
//! a corrupt file surfaces as a propagated error instead of a panic or a
//! silently-defaulted value. [`load`]/[`save`] wrap file IO the same way,
//! with the path in the error chain.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// Parser recursion bound: documents nested deeper than this are rejected
/// instead of overflowing the stack (a hand-rolled recursive-descent
/// parser's failure mode on e.g. a 100k-`[`-deep attack file).
const MAX_DEPTH: usize = 256;

/// A JSON value. Object keys are sorted (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object. On a non-object this is a no-op in release
    /// (a debug assertion catches the misuse in development) — report
    /// builders run on the serve path and must not unwind mid-batch.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        debug_assert!(matches!(self, Json::Obj(_)), "Json::set on non-object");
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly. Named for symmetry with `to_pretty`, not as a
    /// `Display` shadow — `Json` deliberately has no `Display` impl, so
    /// serialization is always an explicit choice of compact vs pretty.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> std::result::Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Required object field: [`Json::get`] with the key named in the error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing required key `{key}`"))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("key `{key}` is not a string"))
    }

    /// Required number field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("key `{key}` is not a number"))
    }

    /// Required non-negative integer field.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        let n = self.req_f64(key)?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(anyhow!("key `{key}` is not a non-negative integer (got {n})"));
        }
        Ok(n as usize)
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("key `{key}` is not an array"))
    }
}

/// Read and parse a JSON file, with the path in the error chain.
pub fn load(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("{e}").context(format!("parsing {}", path.display())))
}

/// Pretty-print a JSON document to a file (creating parent directories),
/// with the path in the error chain.
pub fn save(path: &Path, v: &Json) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, v.to_pretty()).with_context(|| format!("writing {}", path.display()))
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container-nesting depth, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    /// Bounded container entry: rejects pathological nesting before the
    /// recursion can overflow the stack.
    fn enter(&mut self) -> std::result::Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        Ok(())
    }

    fn object(&mut self) -> std::result::Result<Json, String> {
        self.enter()?;
        let v = self.object_body();
        self.depth -= 1;
        v
    }

    fn object_body(&mut self) -> std::result::Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> std::result::Result<Json, String> {
        self.enter()?;
        let v = self.array_body();
        self.depth -= 1;
        v
    }

    fn array_body(&mut self) -> std::result::Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        debug_assert!(self.i <= self.b.len(), "parser cursor past end");
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Self {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("truthy").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn nesting_is_bounded_not_stack_overflowed() {
        // Deep-but-legal nests parse; past MAX_DEPTH is a parse error,
        // not a stack overflow.
        let deep_ok = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(Json::parse(&deep_ok).is_ok());
        let deep_bad = format!("{}1{}", "[".repeat(300), "]".repeat(300));
        let err = Json::parse(&deep_bad).unwrap_err();
        assert!(err.contains("nesting deeper"), "unexpected error: {err}");
        // Wide-but-shallow documents must not trip the bound (the depth
        // counter has to come back down between siblings).
        let wide = format!("[{}]", vec!["[]"; 400].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn req_accessors_name_the_key() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        let err = format!("{:#}", v.req("missing").unwrap_err());
        assert!(err.contains("missing"), "error must name the key: {err}");
        let err = format!("{:#}", v.req_str("n").unwrap_err());
        assert!(err.contains("`n`"), "error must name the key: {err}");
        assert!(v.req_usize("f").is_err(), "1.5 is not a usize");
    }

    #[test]
    fn load_errors_carry_the_path() {
        let dir = std::env::temp_dir().join(format!("eac_json_test_{}", std::process::id()));
        let p = dir.join("sub").join("doc.json");
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("doc.json"), "error must carry the path: {err}");
        let mut v = Json::obj();
        v.set("k", Json::from(1.0));
        save(&p, &v).unwrap(); // creates parent dirs
        assert_eq!(load(&p).unwrap(), v);
        let corrupt = dir.join("bad.json");
        std::fs::write(&corrupt, "{not json").unwrap();
        let err = format!("{:#}", load(&corrupt).unwrap_err());
        assert!(err.contains("bad.json") && err.contains("parsing"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
