//! Binary tensor/dataset IO shared with the Python build path.
//!
//! Format (little-endian throughout), written by `python/compile/pretrain.py`
//! and read here:
//!
//! ```text
//! file      := magic(u32=0x454d4f45 "EOME") version(u32) n_entries(u32)
//!              entry*
//! entry     := name_len(u32) name(utf8 bytes) dtype(u32) ndim(u32)
//!              dims(u64 * ndim) payload
//! dtype     := 0 = f32, 1 = u32, 2 = u8
//! ```
//!
//! Two readers share the format: [`TensorFile::load`] materializes every
//! payload (the historical whole-checkpoint path), while
//! [`IndexedTensorFile::open`] parses only the entry descriptors — name,
//! dims, dtype, payload byte range — and leaves the payloads on disk, so a
//! single tensor can be fetched later by byte range. The indexed reader is
//! what lets the tiered [`crate::model::store::ExpertStore`] serve a model
//! whose experts are loaded on demand instead of resident up front. Both
//! readers are unified under the [`TensorSource`] trait so the weight
//! loaders are written once.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
#[cfg(not(unix))]
use std::io::{Seek, SeekFrom};
use std::path::{Path, PathBuf};
#[cfg(not(unix))]
use std::sync::Mutex;

pub const MAGIC: u32 = 0x454d4f45;
pub const VERSION: u32 = 1;

/// A named tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    U32(Vec<u32>),
    U8(Vec<u8>),
}

impl Payload {
    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::U32(v) => v.len(),
            Payload::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            Payload::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u8(&self) -> Option<&[u8]> {
        match self {
            Payload::U8(v) => Some(v),
            _ => None,
        }
    }
}

/// Named tensor with shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub dims: Vec<usize>,
    pub payload: Payload,
}

/// An ordered bundle of named tensors.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub entries: BTreeMap<String, Entry>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_f32(&mut self, name: &str, dims: Vec<usize>, data: Vec<f32>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::F32(data) });
    }

    pub fn put_u32(&mut self, name: &str, dims: Vec<usize>, data: Vec<u32>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::U32(data) });
    }

    pub fn put_u8(&mut self, name: &str, dims: Vec<usize>, data: Vec<u8>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::U8(data) });
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn get_f32(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let e = self.get(name)?;
        let d = e.payload.as_f32().with_context(|| format!("tensor '{name}' not f32"))?;
        Ok((&e.dims, d))
    }

    pub fn get_u32(&self, name: &str) -> Result<(&[usize], &[u32])> {
        let e = self.get(name)?;
        let d = e.payload.as_u32().with_context(|| format!("tensor '{name}' not u32"))?;
        Ok((&e.dims, d))
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, e) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            let dtype: u32 = match e.payload {
                Payload::F32(_) => 0,
                Payload::U32(_) => 1,
                Payload::U8(_) => 2,
            };
            out.extend_from_slice(&dtype.to_le_bytes());
            out.extend_from_slice(&(e.dims.len() as u32).to_le_bytes());
            for &d in &e.dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            match &e.payload {
                Payload::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Payload::U32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Payload::U8(v) => out.extend_from_slice(v),
            }
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { b: bytes, i: 0 };
        if c.u32()? != MAGIC {
            bail!("bad magic (not an EAC-MoE tensor file)");
        }
        let ver = c.u32()?;
        if ver != VERSION {
            bail!("unsupported version {ver}");
        }
        let n = c.u32()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let name_len = c.u32()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec()).context("bad name utf8")?;
            let dtype = c.u32()?;
            let ndim = c.u32()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(c.u64()? as usize);
            }
            let count: usize = dims.iter().product();
            // `take` hands back exactly the requested bytes, so the
            // chunks_exact(4) element indexing below stays in bounds.
            debug_assert!(count.checked_mul(4).is_some(), "tensor payload size overflow");
            let payload = match dtype {
                0 => {
                    let raw = c.take(count * 4)?;
                    Payload::F32(
                        raw.chunks_exact(4)
                            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                            .collect(),
                    )
                }
                1 => {
                    let raw = c.take(count * 4)?;
                    Payload::U32(
                        raw.chunks_exact(4)
                            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                            .collect(),
                    )
                }
                2 => Payload::U8(c.take(count)?.to_vec()),
                _ => bail!("unknown dtype {dtype}"),
            };
            entries.insert(name, Entry { dims, payload });
        }
        Ok(TensorFile { entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }
}

/// A place tensors can be fetched from by name — either a fully resident
/// [`TensorFile`] (fetch = clone) or an [`IndexedTensorFile`] (fetch =
/// byte-range disk read). The weight loaders in `model::weights` are
/// generic over this, so the resident and tiered paths decode tensors with
/// the same (shape-checked) code.
pub trait TensorSource {
    /// Whether an entry with this name exists (no payload access).
    fn contains(&self, name: &str) -> bool;

    /// Fetch one entry, payload included.
    fn fetch(&self, name: &str) -> Result<Entry>;

    fn fetch_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let e = self.fetch(name)?;
        match e.payload {
            Payload::F32(v) => Ok((e.dims, v)),
            _ => bail!("tensor '{name}' not f32"),
        }
    }

    fn fetch_u32(&self, name: &str) -> Result<(Vec<usize>, Vec<u32>)> {
        let e = self.fetch(name)?;
        match e.payload {
            Payload::U32(v) => Ok((e.dims, v)),
            _ => bail!("tensor '{name}' not u32"),
        }
    }

    fn fetch_u8(&self, name: &str) -> Result<(Vec<usize>, Vec<u8>)> {
        let e = self.fetch(name)?;
        match e.payload {
            Payload::U8(v) => Ok((e.dims, v)),
            _ => bail!("tensor '{name}' not u8"),
        }
    }
}

impl TensorSource for TensorFile {
    fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    fn fetch(&self, name: &str) -> Result<Entry> {
        self.get(name).cloned()
    }
}

/// Descriptor of one on-disk entry: shape, dtype, and the byte range its
/// payload occupies in the file.
#[derive(Clone, Debug)]
pub struct IndexEntry {
    pub dims: Vec<usize>,
    pub dtype: u32,
    /// Absolute file offset of the first payload byte.
    pub offset: u64,
    /// Payload length in bytes.
    pub byte_len: usize,
}

/// A [`TensorFile`] opened *by index*: the header and entry descriptors are
/// parsed eagerly (buffered, and validated against the file length, so
/// truncation is caught at open time), but payloads stay on disk until
/// [`IndexedTensorFile::read_entry`] fetches one by byte range. This is the
/// storage backend of the tiered expert store: a multi-GB checkpoint costs
/// only its descriptor table in memory, and one expert's tensors are read
/// with three or four small positional reads — on unix via `read_exact_at`
/// on a shared handle (no cursor, no lock), so concurrent cache misses to
/// different experts overlap their IO.
#[derive(Debug)]
pub struct IndexedTensorFile {
    file: std::fs::File,
    /// Non-unix fallback only: serializes seek+read on the shared cursor.
    #[cfg(not(unix))]
    io_lock: Mutex<()>,
    path: PathBuf,
    pub index: BTreeMap<String, IndexEntry>,
}

fn dtype_size(dtype: u32) -> Option<usize> {
    match dtype {
        0 | 1 => Some(4),
        2 => Some(1),
        _ => None,
    }
}

impl IndexedTensorFile {
    /// Parse the descriptor table, skipping over payloads. Every entry's
    /// payload range is checked against the file length, so a truncated or
    /// corrupt file fails here with a contextful error rather than at some
    /// later mid-serve fetch.
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let file_len =
            file.metadata().with_context(|| format!("stat {}", path.display()))?.len();
        // Descriptor fields are 4- and 8-byte reads; a BufReader keeps the
        // walk to a handful of syscalls even for many-thousand-entry
        // checkpoints. Payloads are skipped with seek_relative, which
        // stays inside the buffer when it can.
        let mut f = std::io::BufReader::new(&file);
        let mut pos: u64 = 0;
        fn read_exact<R: Read>(f: &mut R, pos: &mut u64, n: usize) -> Result<Vec<u8>> {
            let mut buf = vec![0u8; n];
            f.read_exact(&mut buf)
                .with_context(|| format!("truncated tensor file at byte {pos}"))?;
            *pos += n as u64;
            Ok(buf)
        }
        let u32_of = |b: &[u8]| u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let head = read_exact(&mut f, &mut pos, 12)?;
        if u32_of(&head[0..4]) != MAGIC {
            bail!("bad magic (not an EAC-MoE tensor file): {}", path.display());
        }
        let ver = u32_of(&head[4..8]);
        if ver != VERSION {
            bail!("unsupported version {ver}");
        }
        let n = u32_of(&head[8..12]) as usize;
        let mut index = BTreeMap::new();
        for i in 0..n {
            let name_len = u32_of(&read_exact(&mut f, &mut pos, 4)?) as usize;
            // Bound variable-length reads by the file size before allocating,
            // so a corrupt length field errors instead of attempting a
            // multi-GB allocation.
            anyhow::ensure!(
                pos + name_len as u64 <= file_len,
                "truncated tensor file: entry {i} name ({name_len} B at {pos}) past EOF"
            );
            let name = String::from_utf8(read_exact(&mut f, &mut pos, name_len)?)
                .with_context(|| format!("entry {i}: bad name utf8"))?;
            let dtype = u32_of(&read_exact(&mut f, &mut pos, 4)?);
            let Some(dsize) = dtype_size(dtype) else {
                bail!("entry '{name}': unknown dtype {dtype}");
            };
            let ndim = u32_of(&read_exact(&mut f, &mut pos, 4)?) as usize;
            anyhow::ensure!(
                pos + (ndim as u64) * 8 <= file_len,
                "truncated tensor file: entry '{name}' dims ({ndim} axes at {pos}) past EOF"
            );
            let mut dims = Vec::with_capacity(ndim);
            let raw = read_exact(&mut f, &mut pos, ndim * 8)?;
            for d in raw.chunks_exact(8) {
                dims.push(u64::from_le_bytes([
                    d[0], d[1], d[2], d[3], d[4], d[5], d[6], d[7],
                ]) as usize);
            }
            let count = dims.iter().try_fold(1usize, |a, &d| a.checked_mul(d));
            let byte_len = count
                .and_then(|c| c.checked_mul(dsize))
                .with_context(|| format!("entry '{name}': dims {dims:?} overflow"))?;
            let offset = pos;
            // checked_add: a crafted byte_len near u64::MAX must not wrap
            // past file_len and sneak a bogus entry into the index.
            let end = offset
                .checked_add(byte_len as u64)
                .filter(|&end| end <= file_len)
                .with_context(|| {
                    format!(
                        "truncated tensor file: entry '{name}' payload ({byte_len} B at \
                         {offset}) extends past EOF ({file_len} B) in {}",
                        path.display()
                    )
                })?;
            // Validated above: end <= file_len, so the payload length fits
            // a real file size and the i64 cast cannot overflow.
            f.seek_relative(byte_len as i64)
                .with_context(|| format!("seek past '{name}'"))?;
            pos = end;
            index.insert(name, IndexEntry { dims, dtype, offset, byte_len });
        }
        drop(f);
        Ok(IndexedTensorFile {
            file,
            #[cfg(not(unix))]
            io_lock: Mutex::new(()),
            path: path.to_path_buf(),
            index,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// On-disk payload bytes of one entry (no IO).
    pub fn entry_bytes(&self, name: &str) -> Result<usize> {
        Ok(self
            .index
            .get(name)
            .with_context(|| format!("tensor '{name}' missing from {}", self.path.display()))?
            .byte_len)
    }

    /// Positional read of `buf.len()` bytes at `offset`. On unix this is a
    /// lock-free `pread` on the shared handle (no cursor), so concurrent
    /// reads overlap; elsewhere a mutex serializes seek+read.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(buf, offset)
        }
        #[cfg(not(unix))]
        {
            let _guard = self.io_lock.lock().unwrap();
            // Read/Seek are implemented for &File, so the shared handle's
            // cursor is usable under the lock without &mut self.
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(buf)
        }
    }

    /// Fetch one entry's payload by byte range.
    pub fn read_entry(&self, name: &str) -> Result<Entry> {
        let ie = self
            .index
            .get(name)
            .with_context(|| format!("tensor '{name}' missing from {}", self.path.display()))?;
        let mut raw = vec![0u8; ie.byte_len];
        self.read_exact_at(&mut raw, ie.offset)
            .with_context(|| format!("read tensor '{name}' ({} B)", ie.byte_len))?;
        debug_assert!(raw.len() == ie.byte_len, "short read survived read_exact_at");
        let payload = match ie.dtype {
            0 => Payload::F32(
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            1 => Payload::U32(
                raw.chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            2 => Payload::U8(raw),
            other => bail!("tensor '{name}': unknown dtype {other}"),
        };
        Ok(Entry { dims: ie.dims.clone(), payload })
    }
}

impl TensorSource for IndexedTensorFile {
    fn contains(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    fn fetch(&self, name: &str) -> Result<Entry> {
        self.read_entry(name)
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated tensor file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        debug_assert!(b.len() == 4);
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        debug_assert!(b.len() == 8);
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let mut tf = TensorFile::new();
        tf.put_f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        tf.put_u32("ids", vec![4], vec![7, 8, 9, 10]);
        tf.put_u8("packed", vec![3], vec![255, 0, 127]);
        let bytes = tf.to_bytes();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(back.get_f32("w").unwrap().1, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get_u32("ids").unwrap().0, &[4]);
        assert_eq!(back.get("packed").unwrap().payload.as_u8().unwrap(), &[255, 0, 127]);
    }

    #[test]
    fn rejects_truncation_and_bad_magic() {
        let mut tf = TensorFile::new();
        tf.put_f32("w", vec![2], vec![1., 2.]);
        let mut bytes = tf.to_bytes();
        bytes.truncate(bytes.len() - 3);
        assert!(TensorFile::from_bytes(&bytes).is_err());
        assert!(TensorFile::from_bytes(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eac_moe_binio_test");
        let path = dir.join("t.bin");
        let mut tf = TensorFile::new();
        tf.put_f32("x", vec![1], vec![42.0]);
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.get_f32("x").unwrap().1, &[42.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("eac_moe_binio_{tag}_{}.bin", std::process::id()))
    }

    fn sample_file() -> TensorFile {
        let mut tf = TensorFile::new();
        tf.put_f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        tf.put_u32("ids", vec![4], vec![7, 8, 9, 10]);
        tf.put_u8("packed", vec![3], vec![255, 0, 127]);
        tf
    }

    #[test]
    fn indexed_reader_matches_full_load() {
        let path = temp_path("indexed");
        let tf = sample_file();
        tf.save(&path).unwrap();
        let ix = IndexedTensorFile::open(&path).unwrap();
        // Same entry set, and every byte-range fetch equals the resident
        // entry exactly.
        assert_eq!(ix.index.len(), tf.entries.len());
        for (name, want) in &tf.entries {
            assert!(TensorSource::contains(&ix, name));
            let got = ix.read_entry(name).unwrap();
            assert_eq!(&got, want, "{name}");
        }
        // The TensorSource views agree too (trait-level fetch).
        let (d1, v1) = TensorSource::fetch_f32(&ix, "w").unwrap();
        let (d2, v2) = TensorSource::fetch_f32(&tf, "w").unwrap();
        assert_eq!((d1, v1), (d2, v2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn indexed_reader_rejects_truncated_payload_at_open() {
        let path = temp_path("trunc");
        let bytes = sample_file().to_bytes();
        // Chop into the last entry's payload: open must fail with a
        // truncation error naming the entry, not succeed and return garbage.
        std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated"), "unexpected error: {msg}");
        // Chop mid-descriptor as well.
        std::fs::write(&path, &bytes[..14]).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn indexed_reader_rejects_corrupt_header() {
        let path = temp_path("corrupt");
        // Wrong magic.
        std::fs::write(&path, [1u8; 16]).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("magic"));
        // Valid magic/version but an absurd dims count in the first entry:
        // must error (bounded by file length), not attempt a huge read.
        let mut bytes = sample_file().to_bytes();
        // First entry is "ids" (BTreeMap order): name_len@12, name@16..19,
        // dtype@19, ndim@23. Corrupt ndim to u32::MAX.
        bytes[23..27].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated") || msg.contains("overflow"), "{msg}");
        // Unknown dtype.
        let mut bytes = sample_file().to_bytes();
        bytes[19..23].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("dtype"));
        // A byte_len near u64::MAX must not wrap the EOF bound check: craft
        // a u8 entry whose single dim makes offset + byte_len overflow back
        // below file_len (the unchecked add accepted this).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'z');
        bytes.extend_from_slice(&2u32.to_le_bytes()); // dtype u8
        bytes.extend_from_slice(&1u32.to_le_bytes()); // ndim
        bytes.extend_from_slice(&(u64::MAX - 8).to_le_bytes()); // dim
        std::fs::write(&path, &bytes).unwrap();
        let err = IndexedTensorFile::open(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated") || msg.contains("overflow"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn indexed_reader_missing_entry_is_contextful() {
        let path = temp_path("missing");
        sample_file().save(&path).unwrap();
        let ix = IndexedTensorFile::open(&path).unwrap();
        let err = ix.read_entry("nope").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nope") && msg.contains("missing"), "{msg}");
        assert!(ix.entry_bytes("w").unwrap() == 24);
        assert!(ix.entry_bytes("nope").is_err());
        let _ = std::fs::remove_file(&path);
    }
}
