//! Binary tensor/dataset IO shared with the Python build path.
//!
//! Format (little-endian throughout), written by `python/compile/pretrain.py`
//! and read here:
//!
//! ```text
//! file      := magic(u32=0x454d4f45 "EOME") version(u32) n_entries(u32)
//!              entry*
//! entry     := name_len(u32) name(utf8 bytes) dtype(u32) ndim(u32)
//!              dims(u64 * ndim) payload
//! dtype     := 0 = f32, 1 = u32, 2 = u8
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: u32 = 0x454d4f45;
pub const VERSION: u32 = 1;

/// A named tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    U32(Vec<u32>),
    U8(Vec<u8>),
}

impl Payload {
    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::U32(v) => v.len(),
            Payload::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            Payload::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u8(&self) -> Option<&[u8]> {
        match self {
            Payload::U8(v) => Some(v),
            _ => None,
        }
    }
}

/// Named tensor with shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub dims: Vec<usize>,
    pub payload: Payload,
}

/// An ordered bundle of named tensors.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub entries: BTreeMap<String, Entry>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_f32(&mut self, name: &str, dims: Vec<usize>, data: Vec<f32>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::F32(data) });
    }

    pub fn put_u32(&mut self, name: &str, dims: Vec<usize>, data: Vec<u32>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::U32(data) });
    }

    pub fn put_u8(&mut self, name: &str, dims: Vec<usize>, data: Vec<u8>) {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        self.entries.insert(name.to_string(), Entry { dims, payload: Payload::U8(data) });
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn get_f32(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let e = self.get(name)?;
        let d = e.payload.as_f32().with_context(|| format!("tensor '{name}' not f32"))?;
        Ok((&e.dims, d))
    }

    pub fn get_u32(&self, name: &str) -> Result<(&[usize], &[u32])> {
        let e = self.get(name)?;
        let d = e.payload.as_u32().with_context(|| format!("tensor '{name}' not u32"))?;
        Ok((&e.dims, d))
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, e) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            let dtype: u32 = match e.payload {
                Payload::F32(_) => 0,
                Payload::U32(_) => 1,
                Payload::U8(_) => 2,
            };
            out.extend_from_slice(&dtype.to_le_bytes());
            out.extend_from_slice(&(e.dims.len() as u32).to_le_bytes());
            for &d in &e.dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            match &e.payload {
                Payload::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Payload::U32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Payload::U8(v) => out.extend_from_slice(v),
            }
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { b: bytes, i: 0 };
        if c.u32()? != MAGIC {
            bail!("bad magic (not an EAC-MoE tensor file)");
        }
        let ver = c.u32()?;
        if ver != VERSION {
            bail!("unsupported version {ver}");
        }
        let n = c.u32()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let name_len = c.u32()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec()).context("bad name utf8")?;
            let dtype = c.u32()?;
            let ndim = c.u32()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(c.u64()? as usize);
            }
            let count: usize = dims.iter().product();
            let payload = match dtype {
                0 => {
                    let raw = c.take(count * 4)?;
                    Payload::F32(
                        raw.chunks_exact(4)
                            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                            .collect(),
                    )
                }
                1 => {
                    let raw = c.take(count * 4)?;
                    Payload::U32(
                        raw.chunks_exact(4)
                            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                            .collect(),
                    )
                }
                2 => Payload::U8(c.take(count)?.to_vec()),
                _ => bail!("unknown dtype {dtype}"),
            };
            entries.insert(name, Entry { dims, payload });
        }
        Ok(TensorFile { entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated tensor file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let mut tf = TensorFile::new();
        tf.put_f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        tf.put_u32("ids", vec![4], vec![7, 8, 9, 10]);
        tf.put_u8("packed", vec![3], vec![255, 0, 127]);
        let bytes = tf.to_bytes();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(back.get_f32("w").unwrap().1, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get_u32("ids").unwrap().0, &[4]);
        assert_eq!(back.get("packed").unwrap().payload.as_u8().unwrap(), &[255, 0, 127]);
    }

    #[test]
    fn rejects_truncation_and_bad_magic() {
        let mut tf = TensorFile::new();
        tf.put_f32("w", vec![2], vec![1., 2.]);
        let mut bytes = tf.to_bytes();
        bytes.truncate(bytes.len() - 3);
        assert!(TensorFile::from_bytes(&bytes).is_err());
        assert!(TensorFile::from_bytes(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eac_moe_binio_test");
        let path = dir.join("t.bin");
        let mut tf = TensorFile::new();
        tf.put_f32("x", vec![1], vec![42.0]);
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.get_f32("x").unwrap().1, &[42.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
