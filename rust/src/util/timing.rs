//! Timing / micro-benchmark harness (criterion isn't in the offline
//! registry, so `benches/*.rs` use `harness = false` and call [`bench`]).

use std::time::{Duration, Instant};

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Statistics from one benchmark run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    /// Human-friendly one-liner.
    pub fn line(&self) -> String {
        let (v, unit) = humanize(self.mean_ns);
        let (md, md_u) = humanize(self.median_ns);
        format!(
            "{:<44} {:>9.3} {}  (median {:.3} {}, p95 {:.3} {}, n={})",
            self.name,
            v,
            unit,
            md,
            md_u,
            humanize(self.p95_ns).0,
            humanize(self.p95_ns).1,
            self.iters
        )
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "us")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s ")
    }
}

/// Run `f` repeatedly: warm up for ~10% of the budget, then sample until the
/// time budget (default 2s, override with EAC_MOE_BENCH_MS) or `max_iters`.
/// Prints and returns stats.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let budget_ms: u64 = crate::util::env::bench_ms().unwrap_or(2000);
    let budget = Duration::from_millis(budget_ms);
    // Warmup: at least one call, up to 10% of budget.
    let warm_deadline = Instant::now() + budget / 10;
    loop {
        f();
        if Instant::now() >= warm_deadline {
            break;
        }
    }
    let mut samples: Vec<f64> = Vec::new();
    let deadline = Instant::now() + budget;
    let max_iters = 100_000;
    while Instant::now() < deadline && samples.len() < max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let pct = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    let res = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: pct(0.5),
        p05_ns: pct(0.05),
        p95_ns: pct(0.95),
        std_ns: var.sqrt(),
    };
    println!("{}", res.line());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("EAC_MOE_BENCH_MS", "30");
        let mut acc = 0u64;
        let r = bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.median_ns);
    }
}
