//! The designated `EAC_MOE_*` configuration read site.
//!
//! Every `EAC_MOE_*` environment variable is read here and nowhere else —
//! mechanically enforced by the `env-read-site` xtask lint rule. The PR 3
//! lesson behind the rule: scattered `std::env::var` calls let one process
//! re-read configuration mid-run and half-reconfigure itself. Consumers
//! whose value must not change after first use latch it behind their own
//! `OnceLock` (the global pool's thread count, the SIMD dispatch level);
//! the accessors here deliberately do not cache, so those consumers' first
//! read — and tests that mutate variables with `std::env::set_var` —
//! observe the current environment.

use std::path::PathBuf;

fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// `EAC_MOE_NO_SIMD`: any value other than empty or `0` pins the scalar
/// kernels. Latched by `tensor/simd.rs` detection at first kernel call.
pub fn no_simd() -> bool {
    var("EAC_MOE_NO_SIMD").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// `EAC_MOE_THREADS`: requested worker-pool size. `None` when unset or
/// unparseable (callers fall back to the machine's parallelism). Latched
/// by the process-global pool at construction.
pub fn threads() -> Option<usize> {
    var("EAC_MOE_THREADS").and_then(|v| v.parse().ok())
}

/// `EAC_MOE_BENCH_MS`: per-case time budget for the bench harness
/// (`util/timing.rs` defaults to 2000 when unset).
pub fn bench_ms() -> Option<u64> {
    var("EAC_MOE_BENCH_MS").and_then(|v| v.parse().ok())
}

/// `EAC_MOE_BENCH_SCALE`: problem-size multiplier for the Table-style
/// bench sweeps (CI smoke runs use a small fraction).
pub fn bench_scale() -> Option<f64> {
    var("EAC_MOE_BENCH_SCALE").and_then(|v| v.parse().ok())
}

/// `EAC_MOE_ARTIFACTS`: root directory of the AOT artifact manifest.
pub fn artifacts_dir() -> Option<PathBuf> {
    var("EAC_MOE_ARTIFACTS").map(PathBuf::from)
}

/// `EAC_MOE_EXPERT_BUDGET_MB`: tiered-ExpertStore byte budget for the
/// integration tests' tight-budget pass. A set-but-unparseable value is a
/// configuration error and panics loudly — silently ignoring it would
/// turn the CI budget pass into a no-op that still reports green.
pub fn expert_budget_mb() -> Option<f64> {
    var("EAC_MOE_EXPERT_BUDGET_MB").map(|v| {
        v.parse().unwrap_or_else(|_| {
            panic!("EAC_MOE_EXPERT_BUDGET_MB must be a number (MB), got `{v}`")
        })
    })
}

/// `EAC_MOE_MERGE_THRESHOLD`: expert-merge cosine threshold for the
/// integration tests' merged-model rerun (`tests/integration_serving.rs`
/// applies `prune::merge` at this threshold before serving). Same
/// loud-failure contract as the budget: a set-but-unparseable value
/// panics instead of silently serving the unmerged model green.
pub fn merge_threshold() -> Option<f32> {
    var("EAC_MOE_MERGE_THRESHOLD").map(|v| {
        v.parse().unwrap_or_else(|_| {
            panic!("EAC_MOE_MERGE_THRESHOLD must be a number in (0, 1], got `{v}`")
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // Only variables no other lib test reads are mutated here, so the
    // process-wide environment can't race another test's latch.

    #[test]
    fn bench_scale_parses_and_ignores_garbage() {
        std::env::set_var("EAC_MOE_BENCH_SCALE", "0.5");
        assert_eq!(bench_scale(), Some(0.5));
        std::env::set_var("EAC_MOE_BENCH_SCALE", "nope");
        assert_eq!(bench_scale(), None);
        std::env::remove_var("EAC_MOE_BENCH_SCALE");
        assert_eq!(bench_scale(), None);
    }

    #[test]
    fn merge_threshold_rejects_garbage_loudly() {
        std::env::set_var("EAC_MOE_MERGE_THRESHOLD", "0.7");
        assert_eq!(merge_threshold(), Some(0.7));
        std::env::set_var("EAC_MOE_MERGE_THRESHOLD", "high");
        let r = std::panic::catch_unwind(merge_threshold);
        std::env::remove_var("EAC_MOE_MERGE_THRESHOLD");
        assert!(r.is_err(), "unparseable threshold must panic, not be ignored");
        assert_eq!(merge_threshold(), None);
    }

    #[test]
    fn expert_budget_rejects_garbage_loudly() {
        std::env::set_var("EAC_MOE_EXPERT_BUDGET_MB", "12.5");
        assert_eq!(expert_budget_mb(), Some(12.5));
        std::env::set_var("EAC_MOE_EXPERT_BUDGET_MB", "garbage");
        let r = std::panic::catch_unwind(expert_budget_mb);
        std::env::remove_var("EAC_MOE_EXPERT_BUDGET_MB");
        assert!(r.is_err(), "unparseable budget must panic, not be ignored");
    }
}
