//! Expert-selection analysis (paper §3.3, Fig 2, Appendix A.11):
//! per-dataset ES frequency profiles, pairwise cosine similarity, and
//! sparsity statistics.

use crate::data::corpus::{CorpusGen, DatasetSpec};
use crate::model::hooks::Hooks;
use crate::model::Model;
use crate::tensor::ops::cosine;

/// The flattened ES frequency profile P(d) of one dataset (Eq. 3).
#[derive(Clone, Debug)]
pub struct EsProfile {
    pub dataset: String,
    pub family: &'static str,
    /// Flattened per-layer frequencies, length n_layers * n_experts.
    pub profile: Vec<f32>,
    /// Per-layer frequencies (kept for the Fig 10/11 dumps).
    pub per_layer: Vec<Vec<f32>>,
}

/// Record ES frequencies for a model over one dataset generator.
pub fn es_frequencies(
    model: &Model,
    spec: &DatasetSpec,
    n_seqs: usize,
    seq_len: usize,
    seed: u64,
) -> EsProfile {
    let cfg = model.cfg();
    let mut gen = CorpusGen::new(spec, seed);
    let hooks = Hooks::recording(cfg.n_layers);
    for _ in 0..n_seqs {
        let seq = gen.sequence(seq_len);
        model.forward_with_hooks(&seq, &hooks);
    }
    // `Hooks::recording` installed the selection cell above; the empty
    // fallback record only triggers if that contract breaks.
    let rec = hooks.take_selections().unwrap_or_default();
    debug_assert!(!rec.layers.is_empty(), "recording hooks captured selections");
    EsProfile {
        dataset: spec.name.to_string(),
        family: spec.family.name(),
        profile: rec.flat_frequency(cfg.n_experts),
        per_layer: (0..cfg.n_layers).map(|l| rec.frequency(l, cfg.n_experts)).collect(),
    }
}

/// Pairwise cosine similarity matrix over profiles (Eq. 4 / Fig 2).
pub fn es_similarity_matrix(profiles: &[EsProfile]) -> Vec<Vec<f32>> {
    let n = profiles.len();
    let mut m = vec![vec![0f32; n]; n];
    for i in 0..n {
        for j in 0..n {
            m[i][j] = cosine(&profiles[i].profile, &profiles[j].profile);
        }
    }
    m
}

/// Sparsity diagnostic (Appendix A.11): per layer, the max and min expert
/// frequency; sparse routing shows max >> balanced (1/N) >> min.
pub fn sparsity_stats(profile: &EsProfile) -> Vec<(f32, f32)> {
    profile
        .per_layer
        .iter()
        .map(|f| {
            let mx = f.iter().cloned().fold(0.0f32, f32::max);
            let mn = f.iter().cloned().fold(1.0f32, f32::min);
            (mx, mn)
        })
        .collect()
}

/// Mean intra-family vs inter-family similarity from a similarity matrix.
pub fn intra_inter_summary(profiles: &[EsProfile], sim: &[Vec<f32>]) -> (f32, f32) {
    let mut intra = (0f64, 0usize);
    let mut inter = (0f64, 0usize);
    for i in 0..profiles.len() {
        for j in 0..i {
            if profiles[i].family == profiles[j].family {
                intra.0 += sim[i][j] as f64;
                intra.1 += 1;
            } else {
                inter.0 += sim[i][j] as f64;
                inter.1 += 1;
            }
        }
    }
    (
        (intra.0 / intra.1.max(1) as f64) as f32,
        (inter.0 / inter.1.max(1) as f64) as f32,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::DATASETS;
    use crate::model::{ModelConfig, Weights};

    #[test]
    fn profiles_and_similarity_shapes() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 8,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 512,
            max_seq: 64,
        };
        let m = Model::new(Weights::init(&cfg, 41));
        let profiles: Vec<EsProfile> =
            DATASETS[..4].iter().map(|d| es_frequencies(&m, d, 2, 24, 3)).collect();
        assert_eq!(profiles[0].profile.len(), 2 * 8);
        let sim = es_similarity_matrix(&profiles);
        for i in 0..4 {
            assert!((sim[i][i] - 1.0).abs() < 1e-5);
            for j in 0..4 {
                assert!(sim[i][j] >= -1.0 - 1e-5 && sim[i][j] <= 1.0 + 1e-5);
                assert!((sim[i][j] - sim[j][i]).abs() < 1e-5);
            }
        }
        let stats = sparsity_stats(&profiles[0]);
        assert_eq!(stats.len(), 2);
        for (mx, mn) in stats {
            assert!(mx >= mn);
        }
    }

    /// Hand-built profile with a known flat frequency vector (no model).
    fn fixture(name: &str, family: &'static str, profile: Vec<f32>) -> EsProfile {
        EsProfile { dataset: name.to_string(), family, per_layer: vec![profile.clone()], profile }
    }

    /// Pinned cosine values: identical profiles → 1, orthogonal (disjoint
    /// support) → 0, anti-correlated (mean-centered mirror) → the exact
    /// hand-computed negative value.
    #[test]
    fn similarity_matrix_pinned_fixtures() {
        let a = fixture("a", "web", vec![0.5, 0.5, 0.0, 0.0]);
        let b = fixture("b", "web", vec![0.5, 0.5, 0.0, 0.0]); // identical to a
        let c = fixture("c", "code", vec![0.0, 0.0, 0.5, 0.5]); // orthogonal to a
        // cos(d, a) = (0.5*0.1 + 0.5*0.1) / (|a| * |d|)
        //           = 0.1 / (sqrt(0.5) * sqrt(0.34)) = 0.24253563
        let d = fixture("d", "code", vec![0.1, 0.1, 0.4, 0.4]);
        let sim = es_similarity_matrix(&[a, b, c, d]);
        assert!((sim[0][1] - 1.0).abs() < 1e-6, "identical: {}", sim[0][1]);
        assert!(sim[0][2].abs() < 1e-6, "orthogonal: {}", sim[0][2]);
        assert!((sim[0][3] - 0.242_536).abs() < 1e-5, "partial overlap: {}", sim[0][3]);
        for i in 0..4 {
            assert!((sim[i][i] - 1.0).abs() < 1e-6);
            for j in 0..4 {
                assert!((sim[i][j] - sim[j][i]).abs() < 1e-7, "symmetry at ({i},{j})");
            }
        }
    }

    /// Anti-correlated profiles: cosine of [1,-1] vs [-1,1] is exactly -1.
    /// (Selection frequencies are nonnegative, but the matrix itself is
    /// generic — pin the negative branch of the f64 accumulator too.)
    #[test]
    fn similarity_matrix_anti_correlated_pins_minus_one() {
        let p = fixture("p", "web", vec![1.0, -1.0]);
        let q = fixture("q", "web", vec![-1.0, 1.0]);
        let sim = es_similarity_matrix(&[p, q]);
        assert!((sim[0][1] + 1.0).abs() < 1e-6, "anti-correlated: {}", sim[0][1]);
    }

    /// intra/inter means over a 2-family fixture, hand-computed:
    /// intra pairs: (a,b)=1.0 and (c,d)=cos(c,d); inter pairs: the four
    /// cross-family cosines, all 0 or the known partial value.
    #[test]
    fn intra_inter_summary_pinned() {
        let profiles = vec![
            fixture("a", "web", vec![0.5, 0.5, 0.0, 0.0]),
            fixture("b", "web", vec![0.5, 0.5, 0.0, 0.0]),
            fixture("c", "code", vec![0.0, 0.0, 0.5, 0.5]),
            fixture("d", "code", vec![0.1, 0.1, 0.4, 0.4]),
        ];
        let sim = es_similarity_matrix(&profiles);
        let (intra, inter) = intra_inter_summary(&profiles, &sim);
        // intra = mean(1.0, cos(c,d)); cos(c,d) = (0.2+0.2)/(sqrt(0.5)*sqrt(0.34))
        //       = 0.97014250 → intra = 0.98507125
        assert!((intra - 0.985_071).abs() < 1e-5, "intra {intra}");
        // inter = mean(cos(a,c)=0, cos(a,d)=0.24253563, cos(b,c)=0, cos(b,d)=0.24253563)
        //       = 0.12126781
        assert!((inter - 0.121_268).abs() < 1e-5, "inter {inter}");
        assert!(intra > inter, "families separate in the fixture");
    }

    /// Degenerate inputs: a single family yields zero inter pairs (the
    /// max(1) guard), and a zero profile cosines to 0 against everything.
    #[test]
    fn intra_inter_summary_degenerate_inputs() {
        let profiles = vec![
            fixture("a", "web", vec![1.0, 0.0]),
            fixture("z", "web", vec![0.0, 0.0]), // zero profile → cosine 0
        ];
        let sim = es_similarity_matrix(&profiles);
        assert_eq!(sim[0][1], 0.0);
        let (intra, inter) = intra_inter_summary(&profiles, &sim);
        assert_eq!(intra, 0.0);
        assert_eq!(inter, 0.0); // no inter pairs; guarded division
    }
}
