//! Zero-shot likelihood scoring (the LM-Harness protocol): for each item,
//! score every choice by the sum of log-probabilities of its continuation
//! tokens given context, pick the argmax, count accuracy. Timing is
//! recorded so pruning speedups (Table 3) come from the same code path.

use crate::data::tasks::{TaskItem, ZeroShotTask};
use crate::model::hooks::Hooks;
use crate::model::Model;
use crate::tensor::ops::log_softmax_into;
use std::time::Instant;

/// Per-task evaluation result.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: String,
    pub accuracy: f32,
    pub n_items: usize,
    pub wall_secs: f64,
}

/// Whole-suite result.
#[derive(Clone, Debug, Default)]
pub struct SuiteResult {
    pub tasks: Vec<TaskResult>,
}

impl SuiteResult {
    pub fn mean_accuracy(&self) -> f32 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.accuracy).sum::<f32>() / self.tasks.len() as f32
    }

    pub fn total_secs(&self) -> f64 {
        self.tasks.iter().map(|t| t.wall_secs).sum()
    }
}

/// Score one item: log-likelihood of each choice continuation.
pub fn score_item<F: Fn() -> Hooks>(model: &Model, item: &TaskItem, hooks: &F) -> usize {
    let vocab = model.cfg().vocab;
    let mut scratch = vec![0f32; vocab];
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in item.choices.iter().enumerate() {
        let mut seq = item.context.clone();
        seq.extend_from_slice(choice);
        let logits = model.forward_with_hooks(&seq, &hooks());
        let mut ll = 0f64;
        // Predict each continuation token from its preceding position.
        let start = item.context.len();
        for (k, &tok) in choice.iter().enumerate() {
            let pos = start + k - 1;
            log_softmax_into(logits.row(pos), &mut scratch);
            ll += scratch[tok as usize] as f64;
        }
        if ll > best.0 {
            best = (ll, ci);
        }
    }
    best.1
}

/// Evaluate one task with per-forward hooks.
pub fn eval_task<F: Fn() -> Hooks>(model: &Model, task: &ZeroShotTask, hooks: F) -> TaskResult {
    let t0 = Instant::now();
    let mut correct = 0usize;
    for item in &task.items {
        if score_item(model, item, &hooks) == item.correct {
            correct += 1;
        }
    }
    TaskResult {
        name: task.name.to_string(),
        accuracy: 100.0 * correct as f32 / task.items.len().max(1) as f32,
        n_items: task.items.len(),
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Evaluate the whole suite.
pub fn eval_suite<F: Fn() -> Hooks>(model: &Model, suite: &[ZeroShotTask], hooks: F) -> SuiteResult {
    SuiteResult { tasks: suite.iter().map(|t| eval_task(model, t, &hooks)).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::zero_shot_suite;
    use crate::model::{ModelConfig, Weights};

    #[test]
    fn eval_runs_and_is_deterministic() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 512,
            max_seq: 64,
        };
        let m = Model::new(Weights::init(&cfg, 37));
        let suite = zero_shot_suite(3, 5);
        let r1 = eval_suite(&m, &suite[..2], Hooks::none);
        let r2 = eval_suite(&m, &suite[..2], Hooks::none);
        assert_eq!(r1.tasks[0].accuracy, r2.tasks[0].accuracy);
        assert_eq!(r1.tasks.len(), 2);
        assert!(r1.mean_accuracy() >= 0.0 && r1.mean_accuracy() <= 100.0);
        assert!(r1.total_secs() > 0.0);
    }
}
