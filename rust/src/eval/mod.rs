//! Evaluation stack: perplexity, zero-shot likelihood scoring, and
//! expert-selection analysis (Fig 2 / Fig 10-13).

pub mod es_analysis;
pub mod ppl;
pub mod zeroshot;

pub use es_analysis::{es_frequencies, es_similarity_matrix, EsProfile};
pub use ppl::{perplexity, perplexity_with_hooks};
pub use zeroshot::{eval_task, eval_suite, SuiteResult, TaskResult};
