//! Evaluation stack: perplexity, zero-shot likelihood scoring,
//! expert-selection analysis (Fig 2 / Fig 10-13), and expert *weight*
//! similarity/utilization analysis for the merging axis.

pub mod es_analysis;
pub mod expert_sim;
pub mod ppl;
pub mod zeroshot;

pub use es_analysis::{es_frequencies, es_similarity_matrix, EsProfile};
pub use expert_sim::{analyze_expert_sim, weight_similarity_matrix, ExpertSimReport};
pub use ppl::{perplexity, perplexity_with_hooks};
pub use zeroshot::{eval_task, eval_suite, SuiteResult, TaskResult};
