//! Perplexity evaluation (the paper's WikiText2 PPL column).

use crate::model::hooks::Hooks;
use crate::model::Model;
use crate::tensor::ops::log_softmax_into;

/// Perplexity of the model over token sequences: exp(mean NLL) where the
/// NLL is over next-token predictions within each sequence.
pub fn perplexity(model: &Model, seqs: &[Vec<u32>]) -> f64 {
    perplexity_with_hooks(model, seqs, || Hooks::none())
}

/// Perplexity with per-sequence hooks (PESF passes a fresh mask factory).
pub fn perplexity_with_hooks<F: Fn() -> Hooks>(model: &Model, seqs: &[Vec<u32>], hooks: F) -> f64 {
    let mut total_nll = 0f64;
    let mut count = 0usize;
    let vocab = model.cfg().vocab;
    let mut scratch = vec![0f32; vocab];
    for seq in seqs {
        if seq.len() < 2 {
            continue;
        }
        let logits = model.forward_with_hooks(seq, &hooks());
        for t in 0..seq.len() - 1 {
            log_softmax_into(logits.row(t), &mut scratch);
            total_nll -= scratch[seq[t + 1] as usize] as f64;
            count += 1;
        }
    }
    (total_nll / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};

    fn tiny() -> Model {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        Model::new(Weights::init(&cfg, 31))
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        // An untrained model ~ uniform predictions: PPL ≈ vocab size.
        let m = tiny();
        let seqs: Vec<Vec<u32>> = vec![(0..30).map(|i| (i * 5) % 32).collect()];
        let ppl = perplexity(&m, &seqs);
        assert!(ppl > 8.0 && ppl < 80.0, "ppl={ppl}");
    }

    #[test]
    fn short_sequences_skipped() {
        let m = tiny();
        let ppl = perplexity(&m, &[vec![1], vec![2, 3, 4, 5]]);
        assert!(ppl.is_finite());
    }
}
