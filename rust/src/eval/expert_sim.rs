//! Expert *weight* similarity and utilization analysis — the measurement
//! side of the expert-merging axis (`prune::merge`), and the MC#-style
//! pseudo- vs native-MoE diagnostic (SNIPPETS.md §3).
//!
//! Two signals per MoE layer:
//!
//! * **Weight similarity** — pairwise cosine over each expert's
//!   concatenated dense `w1‖w2‖w3`
//!   ([`crate::model::ExpertWeights::concat_dense`]). High off-diagonal
//!   mass means experts are redundant in weight space and merging will be
//!   near-lossless; the per-threshold "mergeable pair" counts predict what
//!   `prune::merge` would collapse.
//! * **Utilization** — Eq.-3 selection frequencies from a recording
//!   forward pass over a seeded synthetic corpus, plus the raw counts
//!   PESF's Eq.-6 thresholds on.
//!
//! The pseudo-MoE flag follows the chuk-mlx exemplar: a router whose
//! weight matrix has low effective rank (gate logits live in a small
//! subspace) or whose experts are mostly pairwise-similar is behaving
//! like a dense FFN with extra steps — merging is the right compression,
//! not per-expert quantization effort.

use crate::data::corpus::DatasetSpec;
use crate::eval::es_analysis::es_frequencies;
use crate::model::{LayerWeights, Model};
use crate::tensor::linalg::effective_rank;
use crate::tensor::ops::cosine;
use crate::util::json::Json;

/// Effective-rank tolerance for the router weight matrix (singular values
/// below `tol * sigma_max` don't count toward the gate-logit rank).
const ROUTER_RANK_TOL: f32 = 1e-3;

/// Off-diagonal mean similarity above which a layer's experts are "mostly
/// redundant" (the MC# >70%-similarity observation).
const REDUNDANT_SIM: f32 = 0.7;

/// One MoE layer's similarity/utilization analysis.
#[derive(Clone, Debug)]
pub struct ExpertSimLayer {
    pub layer: usize,
    /// Routed expert count as the router sees it ([`LayerWeights::n_routed`]).
    pub n_experts: usize,
    /// Pairwise weight-cosine matrix, `n_experts x n_experts`.
    pub sim: Vec<Vec<f32>>,
    /// Mean / max off-diagonal similarity.
    pub mean_offdiag: f32,
    pub max_offdiag: f32,
    /// Pairs (i<j) at cosine >= 0.9 / >= 0.7 — what `prune::merge` would
    /// consider collapsing at those thresholds.
    pub mergeable_at_090: usize,
    pub mergeable_at_070: usize,
    /// Eq.-3 selection frequency per expert (sums to 1 when any token routed).
    pub utilization: Vec<f32>,
    /// Effective rank of the router weight matrix (gate-logit rank proxy).
    pub router_rank: usize,
    /// Low router rank or mostly-redundant experts: this layer routes like
    /// a pseudo-MoE.
    pub pseudo_moe: bool,
}

/// Whole-model analysis, emitted by `analyze --expert-sim`.
#[derive(Clone, Debug)]
pub struct ExpertSimReport {
    pub model: String,
    pub dataset: String,
    pub layers: Vec<ExpertSimLayer>,
    /// Majority of layers flagged pseudo.
    pub pseudo_moe: bool,
}

/// Pairwise weight-cosine matrix over one layer's resident routed experts.
pub fn weight_similarity_matrix(layer: &LayerWeights) -> Vec<Vec<f32>> {
    let flats: Vec<Vec<f32>> = layer.experts().iter().map(|e| e.concat_dense()).collect();
    let n = flats.len();
    let mut m = vec![vec![0f32; n]; n];
    for i in 0..n {
        m[i][i] = 1.0;
        for j in 0..i {
            let c = cosine(&flats[i], &flats[j]);
            m[i][j] = c;
            m[j][i] = c;
        }
    }
    m
}

/// Run the full per-layer analysis: weight similarity from the resident
/// weights, utilization from a recording forward pass over `n_seqs`
/// sequences of `spec`. Requires a resident (non-tiered) model — the
/// analysis reads every expert's weights.
pub fn analyze_expert_sim(
    model: &Model,
    spec: &DatasetSpec,
    n_seqs: usize,
    seq_len: usize,
    seed: u64,
) -> ExpertSimReport {
    let cfg = model.cfg();
    let profile = es_frequencies(model, spec, n_seqs, seq_len, seed);
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for (li, layer) in model.weights.layers.iter().enumerate() {
        let n = layer.n_routed();
        assert_eq!(
            layer.experts().len(),
            n,
            "layer {li}: expert-sim analysis needs resident experts (store=resident)"
        );
        let sim = weight_similarity_matrix(layer);
        let (mut sum, mut mx, mut pairs) = (0f64, f32::NEG_INFINITY, 0usize);
        let (mut at90, mut at70) = (0usize, 0usize);
        for i in 0..n {
            for j in 0..i {
                sum += sim[i][j] as f64;
                mx = mx.max(sim[i][j]);
                pairs += 1;
                if sim[i][j] >= 0.9 {
                    at90 += 1;
                }
                if sim[i][j] >= 0.7 {
                    at70 += 1;
                }
            }
        }
        let mean_offdiag = if pairs == 0 { 0.0 } else { (sum / pairs as f64) as f32 };
        let max_offdiag = if pairs == 0 { 0.0 } else { mx };
        let router_rank = effective_rank(&layer.router, ROUTER_RANK_TOL);
        let pseudo_moe = router_rank * 2 < n || mean_offdiag > REDUNDANT_SIM;
        // The recorded frequency row is width n: merged layers route over
        // merged ids, so old-id slots past n never appear in the record.
        let mut utilization = profile.per_layer[li].clone();
        utilization.truncate(n);
        layers.push(ExpertSimLayer {
            layer: li,
            n_experts: n,
            sim,
            mean_offdiag,
            max_offdiag,
            mergeable_at_090: at90,
            mergeable_at_070: at70,
            utilization,
            router_rank,
            pseudo_moe,
        });
    }
    let pseudo_count = layers.iter().filter(|l| l.pseudo_moe).count();
    ExpertSimReport {
        model: cfg.name.clone(),
        dataset: spec.name.to_string(),
        pseudo_moe: pseudo_count * 2 > layers.len(),
        layers,
    }
}

impl ExpertSimReport {
    /// Machine-readable document for `results/analyze_expert_sim.json`.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("model", Json::Str(self.model.clone()));
        root.set("dataset", Json::Str(self.dataset.clone()));
        root.set("pseudo_moe", Json::Bool(self.pseudo_moe));
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut o = Json::obj();
                o.set("layer", Json::Num(l.layer as f64));
                o.set("n_experts", Json::Num(l.n_experts as f64));
                o.set("mean_offdiag_sim", Json::Num(l.mean_offdiag as f64));
                o.set("max_offdiag_sim", Json::Num(l.max_offdiag as f64));
                o.set("mergeable_pairs_at_0.9", Json::Num(l.mergeable_at_090 as f64));
                o.set("mergeable_pairs_at_0.7", Json::Num(l.mergeable_at_070 as f64));
                o.set("router_rank", Json::Num(l.router_rank as f64));
                o.set("pseudo_moe", Json::Bool(l.pseudo_moe));
                o.set(
                    "similarity",
                    Json::Arr(
                        l.sim
                            .iter()
                            .map(|row| {
                                Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect())
                            })
                            .collect(),
                    ),
                );
                o.set(
                    "utilization",
                    Json::Arr(l.utilization.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                o
            })
            .collect();
        root.set("layers", Json::Arr(layers));
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::DATASETS;
    use crate::model::{ModelConfig, Weights};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 512,
            max_seq: 64,
        }
    }

    /// Duplicate expert `src` into `dst` (exact copy) on one layer.
    fn duplicate_expert(w: &mut Weights, li: usize, src: usize, dst: usize) {
        let copy = (*w.layers[li].expert_arc(src)).clone();
        *w.layers[li].expert_mut(dst) = copy;
    }

    #[test]
    fn duplicated_experts_hit_similarity_one() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 51);
        duplicate_expert(&mut w, 0, 0, 1);
        let sim = weight_similarity_matrix(&w.layers[0]);
        assert!((sim[0][1] - 1.0).abs() < 1e-6, "copied pair cosine {}", sim[0][1]);
        assert!((sim[1][0] - 1.0).abs() < 1e-6);
        // Independently initialized experts are near-orthogonal.
        assert!(sim[2][3].abs() < 0.5, "random pair cosine {}", sim[2][3]);
        for (i, row) in sim.iter().enumerate() {
            assert!((row[i] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn analysis_counts_mergeable_pairs_and_emits_json() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 52);
        duplicate_expert(&mut w, 0, 0, 1);
        let m = Model::new(w);
        let rep = analyze_expert_sim(&m, &DATASETS[0], 2, 24, 9);
        assert_eq!(rep.layers.len(), 2);
        let l0 = &rep.layers[0];
        assert!(l0.mergeable_at_090 >= 1, "copied pair counted at 0.9");
        assert!(l0.max_offdiag > 0.99);
        assert_eq!(l0.utilization.len(), cfg.n_experts);
        assert_eq!(l0.sim.len(), cfg.n_experts);
        assert!(l0.router_rank >= 1 && l0.router_rank <= cfg.n_experts);
        let j = rep.to_json();
        let layers = j.get("layers").and_then(|l| l.as_arr()).expect("layers array");
        assert_eq!(layers.len(), 2);
        assert!(layers[0].get("mergeable_pairs_at_0.9").is_some());
        assert!(layers[0].get("utilization").is_some());
        assert!(j.get("pseudo_moe").is_some());
    }

    /// A rank-1 router (all rows identical up to scale) is flagged pseudo.
    #[test]
    fn low_rank_router_flags_pseudo() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 53);
        for li in 0..w.layers.len() {
            let r = &mut w.layers[li].router;
            for row in 0..r.rows {
                let base = r.at(row, 0);
                for c in 0..r.cols {
                    *r.at_mut(row, c) = base;
                }
            }
        }
        let m = Model::new(w);
        let rep = analyze_expert_sim(&m, &DATASETS[0], 1, 16, 9);
        for l in &rep.layers {
            assert_eq!(l.router_rank, 1);
            assert!(l.pseudo_moe);
        }
        assert!(rep.pseudo_moe);
    }
}
