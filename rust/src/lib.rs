//! # EAC-MoE — Expert-Selection Aware Compressor for MoE LLMs
//!
//! Rust + JAX + Pallas reproduction of *EAC-MoE* (ACL 2025): compression of
//! Mixture-of-Experts language models via
//!
//! * **QESC** — Quantization with Expert-Selection Calibration: layer-by-layer
//!   GPTQ weight quantization interleaved with router calibration (TopK-MSE)
//!   that undoes quantization-induced *expert-shift* (see [`calib`]).
//! * **PESF** — Pruning based on Expert-Selection Frequency: dynamic,
//!   per-sequence expert pruning during prefill (see [`prune`]).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack: Pallas
//! kernels (L1) and a JAX model (L2) are AOT-compiled to HLO artifacts at
//! build time (`make artifacts`) and executed from Rust through PJRT
//! ([`runtime`]); Python never runs on the request path. A fully native
//! forward path ([`model`]) mirrors the AOT graph for compression-time
//! activation capture and artifact-free testing.
//!
//! ## Quantized execution path
//!
//! Every projection/expert matrix is a [`model::WeightMat`] — `Dense`
//! (f32, blocked GEMM) or `Packed` ([`quant::PackedMat`] sub-byte codes +
//! per-group scale/zero, executed by the fused group-dequant GEMM in
//! [`quant::fused`]). QESC emits `Packed` matrices, so a compressed model
//! serves directly from its low-bit storage: the packed codes are the
//! *only* resident copy of those weights, prefill and kv-decode dispatch
//! through [`model::WeightMat::matmul`], and the fused kernel unpacks
//! each K-tile into an f32 strip exactly once per call, reused across the
//! batch dimension (never the whole matrix per column).
//!
//! ## Threading model
//!
//! All compute parallelism rides the persistent scoped worker pool in
//! [`tensor::pool`] — no per-call thread spawns. Three surfaces use it:
//! row-parallel GEMMs, expert-level tasks in [`model::Model::moe_layer`],
//! and head-level attention tasks in prefill and batched decode, so
//! decode saturates the cores even at batch 1. Pool size is explicit
//! ([`serve::EngineConfig`] `threads`, [`model::Model::with_pool`]);
//! `EAC_MOE_THREADS` only sizes the process-global pool, read once at its
//! construction. Outputs are bit-identical at every pool size.
//!
//! ### Memory accounting
//!
//! [`model::Weights::storage_bytes`] reports the true resident footprint:
//! embeddings, norms and routers stay f32 (the router is what QESC
//! calibrates, ~0.03% of parameters), while each packed matrix counts
//! `bits/8` bytes per weight plus 5 bytes per (group, column) for its
//! f32 scale and u8 zero-point. Serving surfaces the same numbers as
//! `ServeMetrics::resident_weight_bytes` / `resident_expert_bytes`, and
//! the report tables use them in place of simulated sizes.
//!
//! ### Memory tiering
//!
//! Routed experts are reached through an [`model::ExpertStore`]:
//! `Resident` (all experts in [`model::Weights`]) or `Tiered` — packed
//! experts stay on disk behind the byte-range
//! [`util::binio::IndexedTensorFile`] reader and are cached under a hard
//! byte budget with selection-frequency-weighted LRU eviction (the same
//! Eq. 6 counts PESF thresholds). Outputs are bit-identical at every
//! budget; `serve --expert-budget-mb` bounds expert memory end to end.
//! See [`model::store`] for the design.

// Every unsafe operation inside an unsafe fn still needs its own unsafe
// block (and SAFETY comment) — the fn signature alone is not a license.
#![deny(unsafe_op_in_unsafe_fn)]
// Items marked `pub` that are not actually reachable from outside the
// crate should say `pub(crate)` so the public API surface stays honest.
#![warn(unreachable_pub)]

pub mod calib;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod prune;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
