//! # EAC-MoE — Expert-Selection Aware Compressor for MoE LLMs
//!
//! Rust + JAX + Pallas reproduction of *EAC-MoE* (ACL 2025): compression of
//! Mixture-of-Experts language models via
//!
//! * **QESC** — Quantization with Expert-Selection Calibration: layer-by-layer
//!   GPTQ weight quantization interleaved with router calibration (TopK-MSE)
//!   that undoes quantization-induced *expert-shift* (see [`calib`]).
//! * **PESF** — Pruning based on Expert-Selection Frequency: dynamic,
//!   per-sequence expert pruning during prefill (see [`prune`]).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack: Pallas
//! kernels (L1) and a JAX model (L2) are AOT-compiled to HLO artifacts at
//! build time (`make artifacts`) and executed from Rust through PJRT
//! ([`runtime`]); Python never runs on the request path. A fully native
//! forward path ([`model`]) mirrors the AOT graph for compression-time
//! activation capture and artifact-free testing.

pub mod calib;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod prune;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
