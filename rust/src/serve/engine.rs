//! The serving engine: worker threads drain the batcher and run PESF-aware
//! prefill (+ optional greedy decode) over the model.
//!
//! PESF integration (paper §5, extended past its Limitations): the mask is
//! computed from the router's selections on the request's own sequence
//! (Eq. 6) and applied to the *prefill* MoE layers — and then **carried
//! into decode**. Each live sequence owns a
//! [`crate::prune::pesf::PesfDecodeState`]: its prefill-derived
//! `layer × expert` mask rides every [`Model::decode_step_batch`] call via
//! `Hooks::seq_expert_masks` (per batch row, so mixed batches prune each
//! sequence by its own statistics), and a rolling selection-frequency
//! window refreshes the mask every `refresh_every` generated tokens (Eq. 6
//! applied online). With `alpha = 0` the masks are all-false and decode is
//! bit-identical to the unpruned path. EES/ODP plug in as per-token
//! selection filters instead (prefill only) and report their actual
//! selection-drop rate.
//!
//! Serving shape (the "fast as the hardware allows" hot path): a drained
//! batch is processed as a unit. Each request's prompt is forwarded
//! **exactly once** — [`Model::prefill_into_cache`] exports the prefill's
//! per-layer K/V straight into the decode cache, so there is no second
//! token-by-token pass over the prompt. Decode then advances all live
//! sequences together through [`Model::decode_step_batch`], which gathers
//! tokens routed to the same expert across the whole batch into one GEMM;
//! sequences retire as they finish and queued requests are admitted into
//! the freed slots (continuous batching).
//!
//! **Chunked prefill** ([`EngineConfig::prefill_chunk`] > 0): instead of
//! forwarding a whole prompt in one monolithic pass, prompts advance in
//! fixed token-budget chunks via [`Model::prefill_chunk_into_cache`],
//! interleaved round-robin with decode steps — admitting a long prompt no
//! longer freezes every active sequence for its full prefill. Chunking
//! changes *scheduling only*: the per-chunk attention reuses the same
//! GEMM partial-sum chains as the monolithic pass, so logits, KV rows,
//! `mean_logprob` and every generated token are bit-identical at any
//! chunk size (pinned by tests). Chunking engages only where that pin can
//! hold: `PrunePolicy::None` (PESF's Eq. 6 threshold depends on the
//! per-call sequence length) and f32 KV (int8 rows are requantized per
//! export). Other configurations fall back to monolithic prefill.
//!
//! **Streaming** ([`Request::stream`]): each sequence emits
//! [`StreamEvent::Started`] when its first token commits (TTFT),
//! [`StreamEvent::Token`] per decoded token, and [`StreamEvent::Finished`]
//! with the full [`Response`]. The blocking [`Engine::serve`] collects
//! whole responses exactly as before — streaming is an additive surface.
//! Per-request TTFT and inter-token gaps derive from one shared `Instant`
//! per decode step (not per-row clock reads) and aggregate into
//! [`ServeMetrics::ttft`] / [`ServeMetrics::itl`] percentiles.
//!
//! **SLO admission**: the batcher drains by priority / deadline / tenant
//! round-robin (see `serve::batcher`), and workers shed requests whose
//! deadline already passed at admission ([`FinishReason::DeadlineExceeded`])
//! without running prefill. [`Engine::serve_timed`] replays an open-loop
//! arrival schedule (see `serve::workload`) against the running engine.
//!
//! Requests the model cannot forward (over-long prompts, empty prompts,
//! out-of-vocabulary token ids) are rejected at admission with a
//! [`FinishReason`] instead of panicking a worker — one malformed request
//! can no longer abort the engine and lose every in-flight response.
//! Compute parallelism (GEMM rows, experts, attention heads) comes from
//! the model's persistent [`crate::tensor::ThreadPool`], sized via
//! [`EngineConfig::threads`].

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServeMetrics;
use super::request::{FinishReason, Request, Response, StreamEvent, StreamSink};
use super::workload::TimedRequest;
use crate::model::hooks::{FilterDropStats, Hooks, SelectionFilter, SelectionRecord};
use crate::model::{KvCache, KvPrecision, Model};
use crate::prune::ees::EesPruner;
use crate::prune::odp::OdpPruner;
use crate::prune::pesf::{PesfConfig, PesfDecodeState};
use crate::tensor::ops::log_softmax_into;
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which dynamic pruning to apply. PESF prunes prefill *and* decode (the
/// mask follows each sequence through the batched decode loop, refreshed
/// online per [`PesfConfig`]); EES/ODP filter selections during prefill.
#[derive(Clone, Copy, Debug)]
pub enum PrunePolicy {
    None,
    Pesf(PesfConfig),
    Ees(EesPruner),
    Odp(OdpPruner),
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub batch: BatchPolicy,
    pub workers: usize,
    pub prune: PrunePolicy,
    /// Compute-parallelism (GEMM rows, experts, attention heads) for the
    /// served model: `Some(n)` builds a dedicated n-thread
    /// [`crate::tensor::ThreadPool`] for this engine; `None` keeps the
    /// model's pool (the process-global one for `Model::new`, sized from
    /// `EAC_MOE_THREADS` once at that pool's construction). Orthogonal to
    /// `workers`, which is how many batches progress concurrently.
    /// Outputs are bit-identical at every pool size.
    pub threads: Option<usize>,
    /// KV-cache storage precision: 32 (f32, the default — bit-identical
    /// serving) or 8 (symmetric int8 per head per position, ~4x smaller
    /// resident decode caches; CLI `serve --kv-bits 8`).
    pub kv_bits: u8,
    /// Prefill chunk size in tokens: 0 (default) runs each prompt as one
    /// monolithic pass; N > 0 advances prompts N tokens at a time,
    /// interleaved with decode steps, so a long prompt cannot stall
    /// running sequences for its whole prefill. Scheduling-only — outputs
    /// are bit-identical at any chunk size. Requires `PrunePolicy::None`
    /// and f32 KV; other configurations silently stay monolithic.
    pub prefill_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: BatchPolicy::default(),
            workers: 2,
            prune: PrunePolicy::None,
            threads: None,
            kv_bits: 32,
            prefill_chunk: 0,
        }
    }
}

/// The serving engine. `Model` is shared read-only across workers.
pub struct Engine {
    model: Arc<Model>,
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(mut model: Model, cfg: EngineConfig) -> Self {
        if let Some(n) = cfg.threads {
            model.pool = Arc::new(crate::tensor::ThreadPool::new(n));
        }
        Engine { model: Arc::new(model), cfg }
    }

    /// Serve a closed set of requests to completion; returns responses
    /// (unordered) and aggregated metrics. This is the offline-benchmark
    /// entry point: every request is pushed as fast as the queue bound
    /// allows (blocking on backpressure rather than shedding).
    pub fn serve(&self, requests: Vec<Request>) -> (Vec<Response>, ServeMetrics) {
        let cap = requests.len();
        self.serve_inner(cap, move |batcher| {
            for mut req in requests {
                // Offline entry point, closed request set: honor the queue
                // bound by waiting for the workers to drain a slot rather
                // than shedding (an online producer would retry or shed
                // itself). The batcher is only closed after the producer
                // returns, so rejection here always means "queue full".
                while let Err(r) = batcher.push(req) {
                    req = r;
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        })
    }

    /// Serve an open-loop timed arrival schedule (e.g. from
    /// `serve::workload`): each request is pushed at its `at_secs` offset
    /// from the call start, with `arrival` re-stamped at the actual push
    /// so queue/TTFT measure true in-system time, and any deadline budget
    /// applied relative to that arrival. Backpressure briefly blocks the
    /// producer; requests whose deadline lapses while queued are shed by
    /// the workers at admission ([`FinishReason::DeadlineExceeded`]).
    pub fn serve_timed(&self, arrivals: Vec<TimedRequest>) -> (Vec<Response>, ServeMetrics) {
        let cap = arrivals.len();
        self.serve_inner(cap, move |batcher| {
            let t0 = Instant::now();
            for tr in arrivals {
                let offset = Duration::from_secs_f64(tr.at_secs.max(0.0));
                let elapsed = t0.elapsed();
                if offset > elapsed {
                    std::thread::sleep(offset - elapsed);
                }
                let mut req = tr.req;
                let now = Instant::now();
                req.arrival = now;
                if let Some(budget) = tr.deadline_budget {
                    req.deadline = Some(now + budget);
                }
                while let Err(r) = batcher.push(req) {
                    req = r;
                    std::thread::sleep(Duration::from_micros(20));
                }
            }
        })
    }

    /// Shared serve loop: spawn workers, run `producer` to feed the
    /// batcher, close it, join, and assemble metrics.
    fn serve_inner(
        &self,
        cap: usize,
        producer: impl FnOnce(&Batcher),
    ) -> (Vec<Response>, ServeMetrics) {
        let batcher = Arc::new(Batcher::new(self.cfg.batch));
        let responses = Arc::new(Mutex::new(Vec::with_capacity(cap)));
        let prompt_tokens = Arc::new(AtomicUsize::new(0));
        let generated_tokens = Arc::new(AtomicUsize::new(0));
        // Expert-store traffic counters are cumulative on the store;
        // snapshot here so this run's metrics report its own hits/misses,
        // and re-seat the occupancy high-water mark so peak is this run's
        // own (an engine can serve several times, e.g. warmup + trials).
        let store0 = self.model.expert_store_stats();
        self.model.reset_expert_peak();
        let kv = if self.cfg.kv_bits == 8 { KvPrecision::Int8 } else { KvPrecision::F32 };
        let peak_kv = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        // Engine workers block on the batcher condvar between batches, so
        // they must NOT ride the compute pool (they would starve the GEMM
        // tasks that each batch fans out onto it). Scoped OS threads are
        // the right tool here; the pool-only rule is for compute.
        // xtask-allow: no-raw-thread — blocking serve workers, not compute
        std::thread::scope(|s| {
            let mut workers = Vec::new();
            for _ in 0..self.cfg.workers.max(1) {
                let b = batcher.clone();
                let out = responses.clone();
                let model = self.model.clone();
                let prune = self.cfg.prune;
                let max_batch = self.cfg.batch.max_batch;
                let chunk = self.cfg.prefill_chunk;
                let prompt = prompt_tokens.clone();
                let generated = generated_tokens.clone();
                let peak = peak_kv.clone();
                workers.push(s.spawn(move || {
                    let ctx = WorkerCtx {
                        model: &model,
                        prune,
                        kv,
                        chunk,
                        max_batch,
                        prompt_tokens: &prompt,
                        generated_tokens: &generated,
                        peak_kv: &peak,
                    };
                    while let Some(batch) = b.next_batch() {
                        process_batch(&ctx, batch, &b, &out);
                    }
                }));
            }
            producer(&batcher);
            batcher.close();
            for w in workers {
                // A worker that panicked poisons nothing the results need;
                // re-throw its panic rather than unwinding with a generic
                // `Any` unwrap message.
                if let Err(p) = w.join() {
                    std::panic::resume_unwind(p);
                }
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let resps = match Arc::try_unwrap(responses) {
            Ok(m) => m.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner),
            // The scope joined every worker, so no other clone can remain;
            // if one somehow does, drain through the lock instead of
            // unwinding with the metrics half-built.
            Err(shared) => std::mem::take(&mut *shared.lock().unwrap()),
        };
        let store = self.model.expert_store_stats();
        let mut metrics = ServeMetrics {
            wall_secs: wall,
            total_requests: resps.len(),
            prompt_tokens: prompt_tokens.load(Ordering::Relaxed),
            generated_tokens: generated_tokens.load(Ordering::Relaxed),
            // True resident footprint of the weights being served: packed
            // experts report packed bytes, and under a tiered store only
            // the cached experts count — so a QESC model under a budget
            // shows the real memory held, not a simulated size.
            resident_weight_bytes: self.model.resident_weight_bytes(),
            resident_expert_bytes: store.resident_bytes,
            peak_resident_expert_bytes: store.peak_resident_bytes,
            total_expert_bytes: store.total_bytes,
            expert_budget_bytes: store.budget_bytes,
            expert_hits: store.hits - store0.hits,
            expert_misses: store.misses - store0.misses,
            expert_evictions: store.evictions - store0.evictions,
            expert_load_stall_secs: store.load_stall_secs - store0.load_stall_secs,
            // Logical parameter count comes from the config so a tiered
            // model (whose Weights hold no routed experts) still reports
            // the full-model f32 equivalent.
            fp32_weight_bytes: self.model.cfg().param_count() * 4,
            // KV-cache storage: high-water mark of resident cache bytes
            // across any one batch's live sequences (chunked growth means
            // this is actual allocation, not the max_seq worst case).
            peak_kv_cache_bytes: peak_kv.load(Ordering::Relaxed),
            kv_bits: self.cfg.kv_bits,
            // Total routed experts actually served (sum of per-layer
            // widths): under expert merging this is smaller than
            // n_layers * n_experts and is the denominator that makes the
            // merged model's footprint legible in the summary line.
            routed_expert_count: self.model.weights.layers.iter().map(|l| l.n_routed()).sum(),
            original_expert_count: self.model.cfg().n_layers * self.model.cfg().n_experts,
            ..Default::default()
        };
        let mut prune_sum = 0f32;
        let mut prefilled = 0usize;
        let mut decode_prune_sum = 0f32;
        let mut decoded = 0usize;
        for r in &resps {
            // Admission rejections never ran a prefill or decode; they
            // only contribute queue/e2e samples. Averaging their
            // `prune_rate: 0.0` in with real prefills understated the
            // prune rate, so they are excluded from that mean too.
            if !r.finish_reason.is_rejection() {
                metrics.prefill.record(r.prefill_secs);
                metrics.ttft.record(r.ttft_secs);
                prune_sum += r.prune_rate;
                prefilled += 1;
            }
            // Every decode-requested response records into the decode
            // percentiles — including requests whose whole budget was the
            // prefill's next token (decode_secs == 0.0), which the old
            // `> 0.0` guard silently dropped, biasing the percentiles
            // against the fastest requests. Prefill-only and rejected
            // requests have empty `generated` and stay out.
            if !r.generated.is_empty() {
                metrics.decode.record(r.decode_secs);
            }
            for &gap in &r.itl_secs {
                metrics.itl.record(gap);
            }
            if r.finish_reason == FinishReason::DeadlineExceeded {
                metrics.deadline_shed += 1;
            }
            // Decode-phase prune rate averages over requests that took at
            // least one batched decode step (the first generated token is
            // the prefill's own next-token, not a decode step).
            if r.generated.len() > 1 {
                decode_prune_sum += r.decode_prune_rate;
                decoded += 1;
            }
            metrics.queue.record(r.queue_secs);
            metrics.e2e.record(r.e2e_secs);
        }
        metrics.mean_prune_rate = prune_sum / prefilled.max(1) as f32;
        metrics.mean_decode_prune_rate = decode_prune_sum / decoded.max(1) as f32;
        (resps, metrics)
    }
}

/// Per-worker shared context for [`process_batch`] (read-only model plus
/// the engine-wide counters every batch contributes to).
struct WorkerCtx<'a> {
    model: &'a Model,
    prune: PrunePolicy,
    kv: KvPrecision,
    /// Prefill chunk size (0 = monolithic).
    chunk: usize,
    max_batch: usize,
    prompt_tokens: &'a AtomicUsize,
    generated_tokens: &'a AtomicUsize,
    peak_kv: &'a AtomicUsize,
}

/// A sequence that survived prefill and still has decode budget.
struct DecodeSeq {
    resp: Response,
    decode_tokens: usize,
    /// Next token to commit to `resp.generated` (and then feed to decode).
    cur: u32,
    /// Sum of the batched decode-step durations this sequence took part
    /// in — accumulated per step so prefills of requests admitted
    /// mid-loop don't inflate other sequences' decode latency.
    decode_secs: f64,
    /// Request arrival, for true arrival-to-completion e2e.
    arrival: Instant,
    /// Timestamp of this sequence's last committed token: the shared
    /// step `Instant` (or prefill completion for the first token).
    /// Inter-token gaps derive from these shared stamps, so equal-length
    /// batch-mates report identical gaps.
    last_token_at: Instant,
    /// Per-token event sink (None = blocking-collect only).
    stream: Option<StreamSink>,
    /// Decode-time PESF: this sequence's mask + rolling-window state
    /// (None for unpruned policies).
    pesf: Option<PesfDecodeState>,
    /// Sum over decode steps of the mask prune fraction in effect.
    decode_prune_sum: f64,
    decode_steps: usize,
}

impl DecodeSeq {
    /// Commit `cur` to the output (emitting a [`StreamEvent::Token`]),
    /// then decide whether the sequence is done: budget reached →
    /// `Length`; KV cache at capacity with budget left → `CacheFull`
    /// (truncation, now observable instead of silent).
    fn commit_and_check(&mut self, cache_len: usize, max_seq: usize) -> Option<FinishReason> {
        self.resp.generated.push(self.cur);
        if let Some(s) = &self.stream {
            s.send(StreamEvent::Token {
                id: self.resp.id,
                token: self.cur,
                index: self.resp.generated.len() - 1,
            });
        }
        if self.resp.generated.len() >= self.decode_tokens {
            Some(FinishReason::Length)
        } else if cache_len >= max_seq {
            Some(FinishReason::CacheFull)
        } else {
            None
        }
    }

    fn finish(mut self, reason: FinishReason) -> Response {
        self.resp.finish_reason = reason;
        self.resp.decode_secs = self.decode_secs;
        if self.decode_steps > 0 {
            self.resp.decode_prune_rate =
                (self.decode_prune_sum / self.decode_steps as f64) as f32;
        }
        self.resp.e2e_secs = self.arrival.elapsed().as_secs_f64();
        if let Some(s) = &self.stream {
            s.send(StreamEvent::Finished(Box::new(self.resp.clone())));
        }
        self.resp
    }
}

/// A prompt mid-chunked-prefill: its cache holds `consumed` of
/// `req.tokens.len()` positions; `mean_lp_sum` accumulates next-token
/// log-probs in ascending position order (the same f32 addition sequence
/// as the monolithic pass, so the final mean is bitwise identical).
struct PrefillingSeq {
    req: Request,
    cache: KvCache,
    consumed: usize,
    mean_lp_sum: f32,
    prefill_secs: f64,
    /// Queue wait measured when the request was admitted (entered the
    /// worker), matching the monolithic path's measurement point.
    queue_secs: f64,
}

/// One worker batch's mutable state: live decode rows (`caches` stays
/// index-aligned with `active`), prompts mid-chunked-prefill, and
/// completed responses.
struct BatchState {
    active: Vec<DecodeSeq>,
    caches: Vec<KvCache>,
    prefilling: Vec<PrefillingSeq>,
    finished: Vec<Response>,
    /// Round-robin cursor over `prefilling` so concurrent long prompts
    /// share the interleaved chunk slots fairly.
    pf_cursor: usize,
}

/// Emit the terminal stream event (if any) and record the response.
fn finish_response(resp: Response, stream: Option<&StreamSink>, finished: &mut Vec<Response>) {
    if let Some(s) = stream {
        s.send(StreamEvent::Finished(Box::new(resp.clone())));
    }
    finished.push(resp);
}

/// A response for a request that never reached the model (admission
/// rejection or deadline shed): empty output, zero compute timings.
fn rejection_response(req: &Request, reason: FinishReason) -> Response {
    Response {
        id: req.id,
        next_token: 0,
        generated: Vec::new(),
        finish_reason: reason,
        mean_logprob: 0.0,
        queue_secs: req.arrival.elapsed().as_secs_f64(),
        prefill_secs: 0.0,
        decode_secs: 0.0,
        e2e_secs: req.arrival.elapsed().as_secs_f64(),
        ttft_secs: 0.0,
        itl_secs: Vec::new(),
        prune_rate: 0.0,
        decode_prune_rate: 0.0,
    }
}

/// Admit one drained request into the batch: shed if its deadline already
/// passed, reject if the model cannot forward it, otherwise start its
/// prefill — chunked (queued into `st.prefilling`) when the engine is
/// configured for it, else the monolithic single pass.
fn admit(ctx: &WorkerCtx<'_>, req: Request, st: &mut BatchState) {
    let max_seq = ctx.model.cfg().max_seq;
    let vocab = ctx.model.cfg().vocab;
    // Load shedding: a request whose SLO deadline lapsed while queued
    // gets no prefill — its caller has already timed out, so the compute
    // goes to requests that can still meet their deadline.
    if req.expired(Instant::now()) {
        let resp = rejection_response(&req, FinishReason::DeadlineExceeded);
        finish_response(resp, req.stream.as_ref(), &mut st.finished);
        return;
    }
    // Admission validation: a prompt the model cannot forward finishes
    // here with a rejection reason instead of tripping the forward
    // pass's asserts inside a worker — which would abort the engine
    // and lose every in-flight request.
    let reject = if req.tokens.len() > max_seq {
        Some(FinishReason::PromptTooLong)
    } else if req.tokens.is_empty() {
        Some(FinishReason::EmptyPrompt)
    } else if req.tokens.iter().any(|&t| t as usize >= vocab) {
        Some(FinishReason::InvalidToken)
    } else {
        None
    };
    if let Some(reason) = reject {
        let resp = rejection_response(&req, reason);
        finish_response(resp, req.stream.as_ref(), &mut st.finished);
        return;
    }
    ctx.prompt_tokens.fetch_add(req.tokens.len(), Ordering::Relaxed);
    // Chunked prefill engages only where bit-identity to the monolithic
    // pass holds (see module docs): no dynamic pruning (PESF's threshold
    // is per-call sequence-length dependent) and f32 KV.
    let chunkable = ctx.chunk > 0
        && matches!(ctx.prune, PrunePolicy::None)
        && ctx.kv == KvPrecision::F32;
    if chunkable {
        let queue_secs = req.arrival.elapsed().as_secs_f64();
        let cache = KvCache::with_precision(ctx.model.cfg(), ctx.kv);
        st.prefilling.push(PrefillingSeq {
            req,
            cache,
            consumed: 0,
            mean_lp_sum: 0.0,
            prefill_secs: 0.0,
            queue_secs,
        });
        return;
    }
    match prefill_request(ctx.model, ctx.prune, ctx.kv, &req) {
        (mut resp, None) => {
            let t_first = Instant::now();
            resp.ttft_secs = (t_first - req.arrival).as_secs_f64();
            if let Some(s) = &req.stream {
                s.send(StreamEvent::Started {
                    id: resp.id,
                    next_token: resp.next_token,
                    ttft_secs: resp.ttft_secs,
                });
            }
            resp.e2e_secs = req.arrival.elapsed().as_secs_f64();
            finish_response(resp, req.stream.as_ref(), &mut st.finished);
        }
        (mut resp, Some(handoff)) => {
            let t_first = Instant::now();
            resp.ttft_secs = (t_first - req.arrival).as_secs_f64();
            if let Some(s) = &req.stream {
                s.send(StreamEvent::Started {
                    id: resp.id,
                    next_token: resp.next_token,
                    ttft_secs: resp.ttft_secs,
                });
            }
            let mut seq = DecodeSeq {
                resp,
                decode_tokens: req.decode_tokens,
                cur: handoff.next,
                decode_secs: 0.0,
                arrival: req.arrival,
                last_token_at: t_first,
                stream: req.stream.clone(),
                pesf: handoff.pesf,
                decode_prune_sum: 0.0,
                decode_steps: 0,
            };
            // The first generated token (the prefill's greedy next) may
            // already exhaust the budget or the cache.
            match seq.commit_and_check(handoff.cache.len, max_seq) {
                Some(reason) => st.finished.push(seq.finish(reason)),
                None => {
                    st.active.push(seq);
                    st.caches.push(handoff.cache);
                }
            }
        }
    }
}

/// Advance one chunked prefill by up to `ctx.chunk` tokens. Accumulates
/// the next-token log-prob sum over the chunk's rows in ascending
/// position order; returns the greedy next token once the final prompt
/// position has been forwarded (prefill complete).
fn advance_chunk(ctx: &WorkerCtx<'_>, ps: &mut PrefillingSeq) -> Option<u32> {
    let tokens = &ps.req.tokens;
    let len = tokens.len();
    let start = ps.consumed;
    let end = (start + ctx.chunk).min(len);
    let t0 = Instant::now();
    let logits =
        ctx.model.prefill_chunk_into_cache(&tokens[start..end], &Hooks::none(), &mut ps.cache);
    ps.prefill_secs += t0.elapsed().as_secs_f64();
    let vocab = ctx.model.cfg().vocab;
    let mut scratch = vec![0f32; vocab];
    let mut next = None;
    for (r, p) in (start..end).enumerate() {
        if p + 1 < len {
            // Same position order and f32 addition sequence as the
            // monolithic diagnostic loop → bitwise-identical mean.
            log_softmax_into(logits.row(r), &mut scratch);
            ps.mean_lp_sum += scratch[tokens[p + 1] as usize];
        } else {
            next = Some(crate::tensor::ops::topk_indices(logits.row(r), 1)[0] as u32);
        }
    }
    ps.consumed = end;
    next
}

/// A chunked prefill just produced its final-position logits: assemble
/// the response scaffold (TTFT stamps here — the first token commits
/// now) and either finish (prefill-only) or enter the decode batch.
fn finish_prefill(ctx: &WorkerCtx<'_>, ps: PrefillingSeq, next: u32, st: &mut BatchState) {
    let max_seq = ctx.model.cfg().max_seq;
    let len = ps.req.tokens.len();
    let t_first = Instant::now();
    let mean_lp = if len > 1 { ps.mean_lp_sum / (len - 1) as f32 } else { 0.0 };
    let mut resp = Response {
        id: ps.req.id,
        next_token: next,
        generated: Vec::with_capacity(ps.req.decode_tokens),
        finish_reason: FinishReason::Length,
        mean_logprob: mean_lp,
        queue_secs: ps.queue_secs,
        prefill_secs: ps.prefill_secs,
        decode_secs: 0.0,
        e2e_secs: 0.0, // stamped at completion
        ttft_secs: (t_first - ps.req.arrival).as_secs_f64(),
        itl_secs: Vec::new(),
        prune_rate: 0.0,
        decode_prune_rate: 0.0,
    };
    if let Some(s) = &ps.req.stream {
        s.send(StreamEvent::Started {
            id: resp.id,
            next_token: next,
            ttft_secs: resp.ttft_secs,
        });
    }
    if ps.req.decode_tokens == 0 {
        resp.e2e_secs = ps.req.arrival.elapsed().as_secs_f64();
        finish_response(resp, ps.req.stream.as_ref(), &mut st.finished);
        return;
    }
    let mut seq = DecodeSeq {
        resp,
        decode_tokens: ps.req.decode_tokens,
        cur: next,
        decode_secs: 0.0,
        arrival: ps.req.arrival,
        last_token_at: t_first,
        stream: ps.req.stream.clone(),
        pesf: None,
        decode_prune_sum: 0.0,
        decode_steps: 0,
    };
    match seq.commit_and_check(ps.cache.len, max_seq) {
        Some(reason) => st.finished.push(seq.finish(reason)),
        None => {
            st.active.push(seq);
            st.caches.push(ps.cache);
        }
    }
}

/// Process one drained batch as a unit: admit each request (starting its
/// prefill — monolithic, or chunked and interleaved), then run the
/// continuous batched decode loop, admitting queued requests into freed
/// slots. With chunking, each loop iteration runs one decode step for
/// every live sequence and one chunk for one prefilling prompt, so long
/// prompts make progress without stalling token generation.
fn process_batch(ctx: &WorkerCtx<'_>, batch: Vec<Request>, batcher: &Batcher, out: &Mutex<Vec<Response>>) {
    let max_seq = ctx.model.cfg().max_seq;
    let mut st = BatchState {
        active: Vec::new(),
        caches: Vec::new(),
        prefilling: Vec::new(),
        finished: Vec::new(),
        pf_cursor: 0,
    };
    let note_kv = |st: &BatchState| {
        let live: usize = st.caches.iter().map(|c| c.bytes()).sum::<usize>()
            + st.prefilling.iter().map(|p| p.cache.bytes()).sum::<usize>();
        ctx.peak_kv.fetch_max(live, Ordering::Relaxed);
    };

    for req in batch {
        admit(ctx, req, &mut st);
    }
    note_kv(&st);

    // Continuous batched greedy decode: one token for every live sequence
    // per iteration, all through a single decode_step_batch call. Under
    // PESF each row carries its own sequence's expert mask, and the step's
    // routing record feeds every sequence's rolling frequency window.
    let pesf_decode = matches!(ctx.prune, PrunePolicy::Pesf(_));
    // Frozen-mask mode (refresh_every == 0) never reads the rolling
    // window, so skip the per-step routing record entirely — recording
    // (and the observe() it would feed) is pure hot-loop overhead there.
    let pesf_refresh = matches!(ctx.prune, PrunePolicy::Pesf(pc) if pc.refresh_every > 0);
    let n_layers = ctx.model.cfg().n_layers;
    while !st.active.is_empty() || !st.prefilling.is_empty() {
        if !st.active.is_empty() {
            let toks: Vec<u32> = st.active.iter().map(|s| s.cur).collect();
            let step_hooks = if pesf_decode {
                Hooks {
                    seq_expert_masks: Some(
                        st.active.iter().map(|s| s.pesf.as_ref().map(|p| p.mask())).collect(),
                    ),
                    record_selections: pesf_refresh
                        .then(|| RefCell::new(SelectionRecord::with_layers(n_layers))),
                    ..Default::default()
                }
            } else {
                Hooks::none()
            };
            let t_step = Instant::now();
            let logits = ctx.model.decode_step_batch(&toks, &mut st.caches, &step_hooks);
            // One shared timestamp per step: every row's token committed
            // "now", so per-row ITL gaps and summed decode_secs derive
            // from the same clock reads (no per-row skew).
            let t_done = Instant::now();
            let step_secs = (t_done - t_step).as_secs_f64();
            let step_record = step_hooks.take_selections();
            for (b, seq) in st.active.iter_mut().enumerate() {
                seq.decode_secs += step_secs;
                seq.resp.itl_secs.push((t_done - seq.last_token_at).as_secs_f64());
                seq.last_token_at = t_done;
                seq.cur = crate::tensor::ops::topk_indices(logits.row(b), 1)[0] as u32;
                if let Some(p) = seq.pesf.as_mut() {
                    // Account the mask that was in effect for this step,
                    // then feed the step's routing into the window
                    // (possibly refreshing the mask for the next step).
                    seq.decode_prune_sum += p.prune_rate() as f64;
                    seq.decode_steps += 1;
                    if let Some(rec) = &step_record {
                        p.observe(rec.token_experts(b));
                    }
                }
            }
            // Commit and retire (swap_remove keeps `caches` aligned with
            // `active`; per-row outputs are batch-order independent).
            let mut b = 0;
            while b < st.active.len() {
                match st.active[b].commit_and_check(st.caches[b].len, max_seq) {
                    Some(reason) => {
                        let seq = st.active.swap_remove(b);
                        st.caches.swap_remove(b);
                        st.finished.push(seq.finish(reason));
                    }
                    None => b += 1,
                }
            }
        }
        // Interleave one prefill chunk per loop iteration, round-robin
        // across waiting prompts: a long prompt costs running decodes one
        // chunk of latency per step, never its whole prefill.
        if !st.prefilling.is_empty() {
            let i = st.pf_cursor % st.prefilling.len();
            match advance_chunk(ctx, &mut st.prefilling[i]) {
                Some(next) => {
                    let ps = st.prefilling.swap_remove(i);
                    st.pf_cursor = i;
                    finish_prefill(ctx, ps, next, &mut st);
                }
                None => st.pf_cursor = i + 1,
            }
        }
        note_kv(&st);
        // Admit queued requests into freed slots so the decode batch stays
        // full (continuous batching) instead of draining to stragglers.
        let live = st.active.len() + st.prefilling.len();
        if live < ctx.max_batch {
            for req in batcher.try_take(ctx.max_batch - live) {
                admit(ctx, req, &mut st);
            }
        }
    }

    let gen: usize = st.finished.iter().map(|r| r.generated.len()).sum();
    ctx.generated_tokens.fetch_add(gen, Ordering::Relaxed);
    out.lock().unwrap().extend(st.finished);
}

/// What a decode-bound request carries out of its prefill: the KV cache
/// exported by that same pass, the greedy next token to seed the decode
/// loop, and (under PESF) the sequence's online pruning state.
struct PrefillHandoff {
    cache: KvCache,
    next: u32,
    pesf: Option<PesfDecodeState>,
}

/// Prefill one request (single forward pass — PESF/EES/ODP hooks applied
/// per policy). Returns the response scaffold and, when the request wants
/// decode, the [`PrefillHandoff`] produced by that same pass. TTFT is
/// stamped by the caller (the token "commits" at admission, not here).
fn prefill_request(
    model: &Model,
    prune: PrunePolicy,
    kv: KvPrecision,
    req: &Request,
) -> (Response, Option<PrefillHandoff>) {
    let queue_secs = req.arrival.elapsed().as_secs_f64();
    let mcfg = model.cfg();
    // Only decode requests pay for a cache allocation (chunked: the cache
    // grows with the sequence, at the engine's configured precision).
    let mut cache =
        if req.decode_tokens > 0 { Some(KvCache::with_precision(mcfg, kv)) } else { None };
    let t0 = Instant::now();
    let run = |hooks: &Hooks, cache: &mut Option<KvCache>| match cache {
        Some(c) => model.prefill_into_cache(&req.tokens, hooks, c),
        None => model.forward_with_hooks(&req.tokens, hooks),
    };
    let mut pesf_state: Option<PesfDecodeState> = None;
    let (logits, prune_rate) = match prune {
        PrunePolicy::None => (run(&Hooks::none(), &mut cache), 0.0),
        PrunePolicy::Pesf(pc) => {
            // Single-pass PESF: the mask is derived per layer between
            // routing and expert dispatch (Eq. 6; Appendix A.1). Decode
            // continues from this (pruned) prefill's exported KV. For
            // decode requests the same pass also records the routing, so
            // the sequence's decode-time mask + rolling window seed from
            // the prompt statistics without any extra forward.
            let mut hooks = crate::prune::pesf::pesf_hooks(mcfg.n_layers, pc);
            if cache.is_some() {
                hooks.record_selections =
                    Some(RefCell::new(SelectionRecord::with_layers(mcfg.n_layers)));
            }
            let logits = run(&hooks, &mut cache);
            if let Some(rec) = hooks.record_selections.take() {
                // Per-layer routed widths: merged layers route (and mask)
                // over merged ids, which can be fewer than cfg.n_experts.
                let widths: Vec<usize> =
                    model.weights.layers.iter().map(|l| l.n_routed()).collect();
                pesf_state = Some(PesfDecodeState::from_prefill_widths(
                    &rec.into_inner(),
                    &widths,
                    mcfg.top_k,
                    pc,
                ));
            }
            let stats = crate::prune::pesf::PesfStats {
                // pesf_hooks always installs the counter; degrade to a 0.0
                // prune rate rather than unwinding mid-batch if a future
                // hook construction stops doing so.
                pruned_per_layer: hooks.pesf_pruned.map(RefCell::into_inner).unwrap_or_default(),
                n_experts: mcfg.n_experts,
            };
            (logits, stats.prune_rate())
        }
        PrunePolicy::Ees(p) => run_filtered(p.filter(), &mut cache, &run),
        PrunePolicy::Odp(p) => run_filtered(p.filter(), &mut cache, &run),
    };
    let prefill_secs = t0.elapsed().as_secs_f64();

    // Diagnostics: mean next-token log-prob over the prompt + greedy next.
    let vocab = mcfg.vocab;
    let mut scratch = vec![0f32; vocab];
    let mut mean_lp = 0f32;
    if req.tokens.len() > 1 {
        for t in 0..req.tokens.len() - 1 {
            log_softmax_into(logits.row(t), &mut scratch);
            mean_lp += scratch[req.tokens[t + 1] as usize];
        }
        mean_lp /= (req.tokens.len() - 1) as f32;
    }
    let last = logits.row(logits.rows - 1);
    let next_token = crate::tensor::ops::topk_indices(last, 1)[0] as u32;

    let resp = Response {
        id: req.id,
        next_token,
        generated: Vec::with_capacity(req.decode_tokens),
        finish_reason: FinishReason::Length,
        mean_logprob: mean_lp,
        queue_secs,
        prefill_secs,
        decode_secs: 0.0,
        e2e_secs: 0.0, // stamped at completion (finish / prefill-only admit)
        ttft_secs: 0.0, // stamped by the caller when the first token commits
        itl_secs: Vec::new(),
        prune_rate,
        decode_prune_rate: 0.0,
    };
    let handoff =
        cache.map(|c| PrefillHandoff { cache: c, next: next_token, pesf: pesf_state });
    (resp, handoff)
}

/// Run one prefill pass with a per-token selection filter (EES/ODP) and
/// drop-rate accounting installed. Returns the pass output plus the
/// measured fraction of selected expert slots the filter dropped.
fn run_filtered<T>(
    filter: SelectionFilter,
    cache: &mut Option<KvCache>,
    run: &impl Fn(&Hooks, &mut Option<KvCache>) -> T,
) -> (T, f32) {
    let hooks = Hooks {
        selection_filter: Some(filter),
        filter_drops: Some(RefCell::new(FilterDropStats::default())),
        ..Default::default()
    };
    let out = run(&hooks, cache);
    let rate = hooks.filter_drops.map(|d| d.into_inner().rate()).unwrap_or(0.0);
    (out, rate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};
    use crate::serve::BatchPolicy;

    fn tiny() -> Model {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 8,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 64,
            max_seq: 128,
        };
        Model::new(Weights::init(&cfg, 51))
    }

    fn reqs(n: u64, len: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i, (0..len as u32).map(|t| (t * 3 + i as u32) % 64).collect())).collect()
    }

    #[test]
    fn serves_all_requests_once() {
        let e = Engine::new(tiny(), EngineConfig { workers: 3, ..Default::default() });
        let (resps, metrics) = e.serve(reqs(20, 16));
        assert_eq!(resps.len(), 20);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
        assert_eq!(metrics.total_requests, 20);
        assert_eq!(metrics.prompt_tokens, 20 * 16);
        assert_eq!(metrics.generated_tokens, 0);
        assert_eq!(metrics.total_tokens(), 20 * 16);
        assert!(metrics.throughput_tokens_per_sec() > 0.0);
    }

    #[test]
    fn pesf_policy_reports_pruning() {
        let cfg = EngineConfig {
            prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.9, ..Default::default() }),
            workers: 1,
            ..Default::default()
        };
        let e = Engine::new(tiny(), cfg);
        // Decode rides the PESF-pruned prefill's exported KV, and each
        // sequence's mask follows it through the batched decode loop
        // (decode-time PESF; extends the paper's Limitations).
        let rs: Vec<Request> = reqs(4, 32).into_iter().map(|r| r.with_decode(4)).collect();
        let (resps, metrics) = e.serve(rs);
        assert_eq!(resps.len(), 4);
        assert!(resps.iter().all(|r| r.generated.len() == 4));
        // With alpha=0.9 on a random router, some experts must get pruned
        // — in prefill and in the decode steps that follow.
        assert!(metrics.mean_prune_rate > 0.0);
        assert!(metrics.mean_decode_prune_rate > 0.0);
        assert!(resps.iter().all(|r| r.decode_prune_rate > 0.0));
        assert_eq!(metrics.generated_tokens, 16);
    }

    #[test]
    fn rejected_requests_do_not_dilute_prune_rate() {
        // Regression: admission-rejected responses carry prune_rate 0.0
        // and used to be averaged in, understating the real prune rate.
        let model = tiny();
        let max_seq = model.cfg().max_seq;
        let e = Engine::new(
            model,
            EngineConfig {
                prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.9, ..Default::default() }),
                workers: 1,
                ..Default::default()
            },
        );
        let mut rs = reqs(6, 32);
        rs.push(Request::new(100, (0..(max_seq + 1) as u32).map(|t| t % 64).collect()));
        rs.push(Request::new(101, vec![]));
        rs.push(Request::new(102, vec![1, 64]));
        let (resps, metrics) = e.serve(rs);
        assert_eq!(resps.len(), 9);
        let valid: Vec<_> = resps.iter().filter(|r| !r.finish_reason.is_rejection()).collect();
        assert_eq!(valid.len(), 6);
        let want: f32 = valid.iter().map(|r| r.prune_rate).sum::<f32>() / valid.len() as f32;
        assert!(want > 0.0);
        assert!(
            (metrics.mean_prune_rate - want).abs() < 1e-6,
            "mean_prune_rate {} must average valid prefills only ({want})",
            metrics.mean_prune_rate
        );
        // The diluted (buggy) mean would be strictly lower.
        let diluted = valid.iter().map(|r| r.prune_rate).sum::<f32>() / resps.len() as f32;
        assert!(metrics.mean_prune_rate > diluted);
    }

    #[test]
    fn ees_and_odp_report_actual_prune_rate() {
        // Regression: both policies hardcoded prune_rate 0.0 even though
        // their selection filters drop experts. A threshold of 1.0 makes
        // EES drop the weakest expert for (almost) every token.
        let ees = crate::prune::ees::EesPruner { threshold: 1.0 };
        let e = Engine::new(
            tiny(),
            EngineConfig { prune: PrunePolicy::Ees(ees), workers: 1, ..Default::default() },
        );
        let (resps, metrics) = e.serve(reqs(4, 24));
        assert!(metrics.mean_prune_rate > 0.0, "EES must report its drop rate");
        // EES drops at most 1 of top_k=2 selections per token.
        assert!(metrics.mean_prune_rate <= 0.5 + 1e-6);
        assert!(resps.iter().all(|r| r.prune_rate > 0.0));

        // ODP with an infinite-protection threshold behaves like EES;
        // with norm_threshold 0 every token is protected -> rate 0.
        let odp = OdpPruner { ratio_threshold: 1.0, norm_threshold: f32::INFINITY };
        let e = Engine::new(
            tiny(),
            EngineConfig { prune: PrunePolicy::Odp(odp), workers: 1, ..Default::default() },
        );
        let (_, m_odp) = e.serve(reqs(4, 24));
        assert!(m_odp.mean_prune_rate > 0.0, "ODP must report its drop rate");
        let odp_all_protected = OdpPruner { ratio_threshold: 1.0, norm_threshold: 0.0 };
        let e = Engine::new(
            tiny(),
            EngineConfig {
                prune: PrunePolicy::Odp(odp_all_protected),
                workers: 1,
                ..Default::default()
            },
        );
        let (_, m_prot) = e.serve(reqs(4, 24));
        assert_eq!(m_prot.mean_prune_rate, 0.0, "fully protected tokens drop nothing");
    }

    #[test]
    fn decode_generates_tokens_and_counts_them() {
        let e = Engine::new(tiny(), EngineConfig::default());
        let reqs = vec![Request::new(0, vec![1, 2, 3, 4]).with_decode(5)];
        let (resps, metrics) = e.serve(reqs);
        assert_eq!(resps[0].generated.len(), 5);
        assert_eq!(resps[0].generated[0], resps[0].next_token);
        assert_eq!(resps[0].finish_reason, FinishReason::Length);
        // The metrics bugfix: generated tokens are counted, separately
        // from prompt tokens, and feed decode_tokens_per_sec.
        assert_eq!(metrics.prompt_tokens, 4);
        assert_eq!(metrics.generated_tokens, 5);
        assert_eq!(metrics.total_tokens(), 9);
        assert!(metrics.decode_tokens_per_sec() > 0.0);
        assert_eq!(metrics.decode.count(), 1);
    }

    #[test]
    fn cache_full_truncation_is_observable() {
        // Prompt fills the cache to max_seq - 2: room to append exactly 2
        // decode tokens. Generated = [next, g1, g2] then the cache is full
        // with budget left -> CacheFull with 3 of 10 requested tokens.
        let model = tiny();
        let max_seq = model.cfg().max_seq;
        let e = Engine::new(model, EngineConfig { workers: 1, ..Default::default() });
        let prompt: Vec<u32> = (0..(max_seq - 2) as u32).map(|t| t % 64).collect();
        let (resps, _) = e.serve(vec![Request::new(0, prompt.clone()).with_decode(10)]);
        assert_eq!(resps[0].finish_reason, FinishReason::CacheFull);
        assert_eq!(resps[0].generated.len(), 3);

        // Prompt at exactly max_seq: only the prefill's next token fits.
        let e = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let prompt: Vec<u32> = (0..max_seq as u32).map(|t| t % 64).collect();
        let (resps, _) = e.serve(vec![Request::new(0, prompt).with_decode(10)]);
        assert_eq!(resps[0].finish_reason, FinishReason::CacheFull);
        assert_eq!(resps[0].generated.len(), 1);

        // Budget that exactly fits reports Length, not CacheFull.
        let e = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let prompt: Vec<u32> = (0..(max_seq - 2) as u32).map(|t| t % 64).collect();
        let (resps, _) = e.serve(vec![Request::new(0, prompt).with_decode(3)]);
        assert_eq!(resps[0].finish_reason, FinishReason::Length);
        assert_eq!(resps[0].generated.len(), 3);
    }

    #[test]
    fn packed_model_serves_and_reports_real_memory() {
        let dense = tiny();
        let mut packed_w = dense.weights.clone();
        packed_w.pack_experts_rtn(4, 16);
        let e_dense = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let e_packed =
            Engine::new(Model::new(packed_w), EngineConfig { workers: 1, ..Default::default() });
        let (resps_d, md) = e_dense.serve(reqs(6, 16));
        let (resps_p, mp) = e_packed.serve(reqs(6, 16));
        assert_eq!(resps_p.len(), 6);
        assert!(resps_p.iter().all(|r| r.mean_logprob.is_finite()));
        // Dense engine: resident == f32 size. Packed engine: experts shrank.
        assert_eq!(md.resident_weight_bytes, md.fp32_weight_bytes);
        assert!(mp.resident_weight_bytes < md.resident_weight_bytes);
        assert!(mp.resident_expert_bytes < md.resident_expert_bytes / 3);
        assert!(mp.weight_compression_ratio() > 1.5);
        assert!(mp.summary().contains("MB"));
        // 4-bit RTN barely perturbs outputs on this tiny model: both
        // engines must serve every request with finite diagnostics.
        assert_eq!(resps_d.len(), resps_p.len());
    }

    #[test]
    fn overlong_prompt_finishes_at_admission_without_killing_batch() {
        // Regression: a prompt longer than max_seq used to trip
        // forward_full's assert inside a worker, and the join().unwrap()
        // turned that into a whole-engine abort. It must now finish at
        // admission while every other request in the batch serves
        // normally.
        let model = tiny();
        let max_seq = model.cfg().max_seq;
        let e = Engine::new(model, EngineConfig { workers: 2, ..Default::default() });
        let mut rs: Vec<Request> =
            reqs(4, 16).into_iter().map(|r| r.with_decode(3)).collect();
        rs.push(
            Request::new(100, (0..(max_seq + 1) as u32).map(|t| t % 64).collect())
                .with_decode(5),
        );
        rs.push(Request::new(101, vec![]).with_decode(2));
        // Token 64 is out of vocab (vocab = 64): would index the embedding
        // table out of bounds if it reached prefill.
        rs.push(Request::new(102, vec![1, 2, 64]).with_decode(2));
        let (resps, metrics) = e.serve(rs);
        assert_eq!(resps.len(), 7, "every request gets a response");
        let bad = resps.iter().find(|r| r.id == 100).unwrap();
        assert_eq!(bad.finish_reason, FinishReason::PromptTooLong);
        assert!(bad.generated.is_empty());
        assert!(bad.finish_reason.is_rejection());
        let empty = resps.iter().find(|r| r.id == 101).unwrap();
        assert_eq!(empty.finish_reason, FinishReason::EmptyPrompt);
        assert!(empty.generated.is_empty());
        let oov = resps.iter().find(|r| r.id == 102).unwrap();
        assert_eq!(oov.finish_reason, FinishReason::InvalidToken);
        assert!(oov.generated.is_empty());
        for r in resps.iter().filter(|r| r.id < 100) {
            assert_eq!(r.finish_reason, FinishReason::Length);
            assert_eq!(r.generated.len(), 3);
        }
        // Rejected prompts were never forwarded: not counted as prefill
        // work, and absent from the prefill latency percentiles.
        assert_eq!(metrics.prompt_tokens, 4 * 16);
        assert_eq!(metrics.prefill.count(), 4);
        assert_eq!(metrics.e2e.count(), 7);
    }

    #[test]
    fn admission_finished_decode_requests_record_decode_stats() {
        // A decode budget of 1 is exhausted by the prefill's own next
        // token: the request finishes at admission with decode_secs == 0.
        // The old `decode_secs > 0.0` guard dropped exactly these (the
        // fastest decodes) from the percentiles.
        let e = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let rs: Vec<Request> = reqs(3, 8).into_iter().map(|r| r.with_decode(1)).collect();
        let (resps, metrics) = e.serve(rs);
        assert!(resps.iter().all(|r| r.generated.len() == 1));
        assert!(resps.iter().all(|r| r.decode_secs == 0.0));
        assert_eq!(metrics.decode.count(), 3);
        assert_eq!(metrics.decode.percentile_ms(0.5), 0.0);
        assert_eq!(metrics.generated_tokens, 3);
    }

    #[test]
    fn kv8_serving_generates_and_reports_smaller_peak_cache() {
        let weights = tiny().weights;
        let run = |kv_bits: u8| {
            let e = Engine::new(
                Model::new(weights.clone()),
                EngineConfig { workers: 1, kv_bits, ..Default::default() },
            );
            let rs: Vec<Request> = reqs(4, 24).into_iter().map(|r| r.with_decode(8)).collect();
            e.serve(rs)
        };
        let (r32, m32) = run(32);
        let (r8, m8) = run(8);
        assert_eq!(m32.kv_bits, 32);
        assert_eq!(m8.kv_bits, 8);
        assert!(r8.iter().all(|r| r.generated.len() == 8));
        assert!(r8.iter().all(|r| r.mean_logprob.is_finite()));
        assert_eq!(r32.len(), r8.len());
        assert!(m32.peak_kv_cache_bytes > 0, "f32 peak must be tracked");
        assert!(
            m8.peak_kv_cache_bytes * 2 < m32.peak_kv_cache_bytes,
            "int8 peak {} !<< f32 peak {}",
            m8.peak_kv_cache_bytes,
            m32.peak_kv_cache_bytes
        );
        assert!(m8.summary().contains("kv=8bit"));
        assert!(m32.summary().contains("kv=32bit"));
    }

    #[test]
    fn peak_kv_bytes_reflect_chunked_growth_not_max_seq() {
        // tiny() has max_seq 128; a short decode workload should peak at
        // one 64-row chunk per cache, well under the eager worst case.
        let model = tiny();
        let mcfg = model.cfg().clone();
        let e = Engine::new(model, EngineConfig { workers: 1, ..Default::default() });
        let (_, m) = e.serve(vec![Request::new(0, vec![1, 2, 3, 4]).with_decode(4)]);
        let eager = mcfg.n_layers * mcfg.max_seq * mcfg.d_model * 2 * 4;
        assert!(m.peak_kv_cache_bytes > 0);
        assert!(
            m.peak_kv_cache_bytes < eager,
            "peak {} must be under the eager max_seq allocation {eager}",
            m.peak_kv_cache_bytes
        );
    }

    #[test]
    fn explicit_thread_pool_size_matches_default_outputs() {
        // EngineConfig::threads is a scheduling knob only: generated
        // tokens and diagnostics are bit-identical across pool sizes.
        let weights = tiny().weights;
        let mut baseline: Option<Vec<(u64, Vec<u32>, u32, f32)>> = None;
        for threads in [Some(1usize), Some(2), Some(8), None] {
            let e = Engine::new(
                Model::new(weights.clone()),
                EngineConfig { workers: 2, threads, ..Default::default() },
            );
            let rs: Vec<Request> = reqs(6, 12).into_iter().map(|r| r.with_decode(4)).collect();
            let (mut out, _) = e.serve(rs);
            out.sort_by_key(|r| r.id);
            let got: Vec<(u64, Vec<u32>, u32, f32)> = out
                .into_iter()
                .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob))
                .collect();
            match &baseline {
                None => baseline = Some(got),
                Some(want) => assert_eq!(&got, want, "outputs differ at threads={threads:?}"),
            }
        }
    }

    #[test]
    fn deterministic_outputs_across_worker_counts_and_batch_sizes() {
        // Batched serve must be bit-identical to the single-request path:
        // same generated decode tokens (not just next_token) regardless of
        // worker count or max_batch, for dense and packed weights alike.
        let dense = tiny().weights;
        let mut packed = dense.clone();
        packed.pack_experts_rtn(4, 16);
        for weights in [dense, packed] {
            let mut baseline: Option<Vec<(u64, Vec<u32>, u32, f32)>> = None;
            for (workers, max_batch) in [(1usize, 1usize), (1, 4), (4, 4)] {
                let e = Engine::new(
                    Model::new(weights.clone()),
                    EngineConfig {
                        workers,
                        batch: BatchPolicy { max_batch, ..Default::default() },
                        ..Default::default()
                    },
                );
                let rs: Vec<Request> =
                    reqs(8, 12).into_iter().map(|r| r.with_decode(6)).collect();
                let (mut out, _) = e.serve(rs);
                out.sort_by_key(|r| r.id);
                let got: Vec<(u64, Vec<u32>, u32, f32)> = out
                    .into_iter()
                    .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob))
                    .collect();
                assert!(got.iter().all(|(_, g, _, _)| g.len() == 6));
                match &baseline {
                    None => baseline = Some(got),
                    Some(want) => assert_eq!(
                        &got, want,
                        "outputs differ at workers={workers} max_batch={max_batch}"
                    ),
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_serving_matches_monolithic() {
        // Chunk size is a scheduling knob only: generated tokens,
        // next_token and mean_logprob are bit-identical to the monolithic
        // (chunk = 0) path at every chunk size, for dense and packed
        // weights, including a prefill-only request in the mix.
        let dense = tiny().weights;
        let mut packed = dense.clone();
        packed.pack_experts_rtn(4, 16);
        for weights in [dense, packed] {
            let run = |chunk: usize| {
                let e = Engine::new(
                    Model::new(weights.clone()),
                    EngineConfig { workers: 1, prefill_chunk: chunk, ..Default::default() },
                );
                let mut rs: Vec<Request> =
                    reqs(6, 11).into_iter().map(|r| r.with_decode(5)).collect();
                rs.push(Request::new(50, (0..9u32).map(|t| (t * 5 + 2) % 64).collect()));
                let (mut out, _) = e.serve(rs);
                out.sort_by_key(|r| r.id);
                out.into_iter()
                    .map(|r| (r.id, r.generated, r.next_token, r.mean_logprob))
                    .collect::<Vec<_>>()
            };
            let base = run(0);
            assert_eq!(base.len(), 7);
            for chunk in [1usize, 3, 5, 11, 64] {
                assert_eq!(run(chunk), base, "chunk={chunk} must be bit-identical");
            }
        }
    }

    #[test]
    fn expired_requests_shed_without_prefill() {
        let e = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let mut rs: Vec<Request> = reqs(3, 12).into_iter().map(|r| r.with_decode(2)).collect();
        let mut expired = Request::new(90, vec![1, 2, 3]).with_decode(4);
        // A deadline equal to arrival has always already passed by the
        // time a worker picks the request up.
        expired.deadline = Some(expired.arrival);
        rs.push(expired);
        let (resps, metrics) = e.serve(rs);
        assert_eq!(resps.len(), 4, "shed requests still get a response");
        let shed = resps.iter().find(|r| r.id == 90).unwrap();
        assert_eq!(shed.finish_reason, FinishReason::DeadlineExceeded);
        assert!(shed.finish_reason.is_rejection());
        assert!(shed.generated.is_empty());
        assert_eq!(shed.ttft_secs, 0.0);
        assert_eq!(shed.prefill_secs, 0.0);
        // Never forwarded: no prompt tokens counted, no prefill or TTFT
        // sample recorded — only the shed counter.
        assert_eq!(metrics.prompt_tokens, 3 * 12);
        assert_eq!(metrics.prefill.count(), 3);
        assert_eq!(metrics.ttft.count(), 3);
        assert_eq!(metrics.deadline_shed, 1);
        for r in resps.iter().filter(|r| r.id < 90) {
            assert_eq!(r.generated.len(), 2);
        }
    }

    #[test]
    fn streaming_events_match_blocking_response() {
        let e = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let (sink, rx) = StreamSink::channel();
        let req = Request::new(7, vec![1, 2, 3, 4]).with_decode(4).with_stream(sink);
        let (resps, _) = e.serve(vec![req]);
        let resp = &resps[0];
        assert_eq!(resp.generated.len(), 4);
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 2 + resp.generated.len(), "Started + Tokens + Finished");
        match &events[0] {
            StreamEvent::Started { id, next_token, ttft_secs } => {
                assert_eq!(*id, 7);
                assert_eq!(*next_token, resp.next_token);
                assert_eq!(*ttft_secs, resp.ttft_secs);
            }
            other => panic!("expected Started first, got {other:?}"),
        }
        let toks: Vec<(u32, usize)> = events
            .iter()
            .filter_map(|ev| match ev {
                StreamEvent::Token { token, index, .. } => Some((*token, *index)),
                _ => None,
            })
            .collect();
        assert_eq!(toks.iter().map(|&(t, _)| t).collect::<Vec<_>>(), resp.generated);
        assert_eq!(
            toks.iter().map(|&(_, i)| i).collect::<Vec<_>>(),
            (0..resp.generated.len()).collect::<Vec<_>>()
        );
        match events.last().unwrap() {
            StreamEvent::Finished(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.generated, resp.generated);
                assert_eq!(r.finish_reason, resp.finish_reason);
                assert_eq!(r.itl_secs, resp.itl_secs);
            }
            other => panic!("expected Finished last, got {other:?}"),
        }

        // A rejected request emits only Finished.
        let (sink, rx) = StreamSink::channel();
        let (resps, _) = e.serve(vec![Request::new(8, vec![]).with_stream(sink)]);
        assert_eq!(resps[0].finish_reason, FinishReason::EmptyPrompt);
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert!(matches!(&events[0], StreamEvent::Finished(r) if r.id == 8));
    }

    #[test]
    fn step_timing_shared_across_rows() {
        // Satellite fix: one Instant per decode step, shared by every row
        // — TTFT/ITL derive from those shared stamps, and decode_secs
        // stays consistent with the summed step times.
        let e = Engine::new(
            tiny(),
            EngineConfig {
                workers: 1,
                batch: BatchPolicy {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(200),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let rs: Vec<Request> = reqs(4, 8).into_iter().map(|r| r.with_decode(6)).collect();
        let (resps, metrics) = e.serve(rs);
        for r in &resps {
            assert_eq!(r.generated.len(), 6);
            assert_eq!(r.itl_secs.len(), 5, "one gap per decode step");
            assert!(r.ttft_secs > 0.0);
            assert!(r.itl_secs.iter().all(|&g| g >= 0.0));
            // The gaps cover at least the batched step compute this row
            // took part in (they also absorb inter-step overhead).
            let itl_sum: f64 = r.itl_secs.iter().sum();
            assert!(
                itl_sum >= r.decode_secs - 1e-9,
                "itl sum {itl_sum} < decode_secs {}",
                r.decode_secs
            );
        }
        // Equal-length batch-mates share every step timestamp: gaps after
        // the first (whose start is each row's own prefill completion)
        // are bit-identical f64s, as is the summed step time.
        let first = &resps[0];
        for r in &resps[1..] {
            assert_eq!(r.itl_secs[1..], first.itl_secs[1..]);
            assert_eq!(r.decode_secs, first.decode_secs);
        }
        assert_eq!(metrics.itl.count(), 4 * 5);
        assert_eq!(metrics.ttft.count(), 4);
        assert!(metrics.summary().contains("ttft"));
    }
}
