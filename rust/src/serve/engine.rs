//! The serving engine: worker threads drain the batcher and run PESF-aware
//! prefill (+ optional greedy decode) over the model.
//!
//! PESF integration (paper §5 + Limitations): the mask is computed from the
//! router's selections on the request's own sequence (Eq. 6) and applied to
//! the *prefill* MoE layers; decode runs unpruned. EES/ODP plug in as
//! per-token selection filters instead.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServeMetrics;
use super::request::{Request, Response};
use crate::model::hooks::Hooks;
use crate::model::{KvCache, Model};
use crate::prune::ees::EesPruner;
use crate::prune::odp::OdpPruner;
use crate::prune::pesf::PesfConfig;
use crate::tensor::ops::log_softmax_into;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which dynamic pruning to apply during prefill.
#[derive(Clone, Copy, Debug)]
pub enum PrunePolicy {
    None,
    Pesf(PesfConfig),
    Ees(EesPruner),
    Odp(OdpPruner),
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub batch: BatchPolicy,
    pub workers: usize,
    pub prune: PrunePolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { batch: BatchPolicy::default(), workers: 2, prune: PrunePolicy::None }
    }
}

/// The serving engine. `Model` is shared read-only across workers.
pub struct Engine {
    model: Arc<Model>,
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(model: Model, cfg: EngineConfig) -> Self {
        Engine { model: Arc::new(model), cfg }
    }

    /// Serve a closed set of requests to completion; returns responses
    /// (unordered) and aggregated metrics. This is the offline-benchmark
    /// entry; [`Engine::serve_streaming`] is the long-running variant.
    pub fn serve(&self, requests: Vec<Request>) -> (Vec<Response>, ServeMetrics) {
        let batcher = Arc::new(Batcher::new(self.cfg.batch));
        let responses = Arc::new(Mutex::new(Vec::with_capacity(requests.len())));
        let token_count = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        std::thread::scope(|s| {
            let mut workers = Vec::new();
            for _ in 0..self.cfg.workers.max(1) {
                let b = batcher.clone();
                let out = responses.clone();
                let model = self.model.clone();
                let prune = self.cfg.prune;
                let tokens = token_count.clone();
                workers.push(s.spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        for req in batch {
                            let resp = process_request(&model, prune, &req);
                            tokens.fetch_add(req.tokens.len(), Ordering::Relaxed);
                            out.lock().unwrap().push(resp);
                        }
                    }
                }));
            }
            for req in requests {
                batcher.push(req);
            }
            batcher.close();
            for w in workers {
                w.join().unwrap();
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let resps = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
        let mut metrics = ServeMetrics {
            wall_secs: wall,
            total_requests: resps.len(),
            total_tokens: token_count.load(Ordering::Relaxed),
            // True resident footprint of the weights being served: packed
            // experts report packed bytes, so a QESC model shows the real
            // memory win (not a simulated one).
            resident_weight_bytes: self.model.weights.storage_bytes(),
            resident_expert_bytes: self.model.weights.expert_storage_bytes(),
            fp32_weight_bytes: self.model.weights.param_count() * 4,
            ..Default::default()
        };
        let mut prune_sum = 0f32;
        for r in &resps {
            metrics.prefill.record(r.prefill_secs);
            metrics.queue.record(r.queue_secs);
            metrics.e2e.record(r.queue_secs + r.prefill_secs);
            prune_sum += r.prune_rate;
        }
        metrics.mean_prune_rate = prune_sum / resps.len().max(1) as f32;
        (resps, metrics)
    }
}

/// Process one request: PESF two-phase prefill (or filter-based pruning),
/// then optional greedy decode.
fn process_request(model: &Model, prune: PrunePolicy, req: &Request) -> Response {
    let queue_secs = req.arrival.elapsed().as_secs_f64();
    let mcfg = model.cfg();
    let t0 = Instant::now();
    let (logits, prune_rate) = match prune {
        PrunePolicy::None => (model.forward(&req.tokens), 0.0),
        PrunePolicy::Pesf(pc) => {
            // Single-pass PESF: the mask is derived per layer between
            // routing and expert dispatch (Eq. 6; Appendix A.1).
            let hooks = crate::prune::pesf::pesf_hooks(mcfg.n_layers, pc);
            let logits = model.forward_with_hooks(&req.tokens, &hooks);
            let stats = crate::prune::pesf::PesfStats {
                pruned_per_layer: hooks.pesf_pruned.unwrap().into_inner(),
                n_experts: mcfg.n_experts,
            };
            (logits, stats.prune_rate())
        }
        PrunePolicy::Ees(p) => {
            let hooks = Hooks { selection_filter: Some(p.filter()), ..Default::default() };
            (model.forward_with_hooks(&req.tokens, &hooks), 0.0)
        }
        PrunePolicy::Odp(p) => {
            let hooks = Hooks { selection_filter: Some(p.filter()), ..Default::default() };
            (model.forward_with_hooks(&req.tokens, &hooks), 0.0)
        }
    };
    let prefill_secs = t0.elapsed().as_secs_f64();

    // Diagnostics: mean next-token log-prob over the prompt + greedy next.
    let vocab = mcfg.vocab;
    let mut scratch = vec![0f32; vocab];
    let mut mean_lp = 0f32;
    if req.tokens.len() > 1 {
        for t in 0..req.tokens.len() - 1 {
            log_softmax_into(logits.row(t), &mut scratch);
            mean_lp += scratch[req.tokens[t + 1] as usize];
        }
        mean_lp /= (req.tokens.len() - 1) as f32;
    }
    let last = logits.row(logits.rows - 1);
    let next_token = crate::tensor::ops::topk_indices(last, 1)[0] as u32;

    // Optional greedy decode (PESF disabled here, per the paper).
    let mut generated = Vec::with_capacity(req.decode_tokens);
    if req.decode_tokens > 0 {
        let mut cache = KvCache::new(mcfg);
        // Refill the cache with the prompt (decode path re-computation;
        // prefill KV export is a further optimization, see DESIGN §Perf).
        let mut tok = *req.tokens.first().unwrap_or(&0);
        for &t in &req.tokens {
            model.decode_step(t, &mut cache, &Hooks::none());
            tok = t;
        }
        let _ = tok;
        let mut cur = next_token;
        for _ in 0..req.decode_tokens {
            generated.push(cur);
            if cache.len >= mcfg.max_seq {
                break;
            }
            let logits = model.decode_step(cur, &mut cache, &Hooks::none());
            cur = crate::tensor::ops::topk_indices(&logits, 1)[0] as u32;
        }
    }

    Response {
        id: req.id,
        next_token,
        generated,
        mean_logprob: mean_lp,
        queue_secs,
        prefill_secs,
        prune_rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};

    fn tiny() -> Model {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 8,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 64,
            max_seq: 128,
        };
        Model::new(Weights::init(&cfg, 51))
    }

    fn reqs(n: u64, len: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i, (0..len as u32).map(|t| (t * 3 + i as u32) % 64).collect())).collect()
    }

    #[test]
    fn serves_all_requests_once() {
        let e = Engine::new(tiny(), EngineConfig { workers: 3, ..Default::default() });
        let (resps, metrics) = e.serve(reqs(20, 16));
        assert_eq!(resps.len(), 20);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
        assert_eq!(metrics.total_requests, 20);
        assert_eq!(metrics.total_tokens, 20 * 16);
        assert!(metrics.throughput_tokens_per_sec() > 0.0);
    }

    #[test]
    fn pesf_policy_reports_pruning() {
        let cfg = EngineConfig {
            prune: PrunePolicy::Pesf(PesfConfig { alpha: 0.9 }),
            workers: 1,
            ..Default::default()
        };
        let e = Engine::new(tiny(), cfg);
        let (resps, metrics) = e.serve(reqs(4, 32));
        assert_eq!(resps.len(), 4);
        // With alpha=0.9 on a random router, some experts must get pruned.
        assert!(metrics.mean_prune_rate > 0.0);
    }

    #[test]
    fn decode_generates_tokens() {
        let e = Engine::new(tiny(), EngineConfig::default());
        let reqs = vec![Request::new(0, vec![1, 2, 3, 4]).with_decode(5)];
        let (resps, _) = e.serve(reqs);
        assert_eq!(resps[0].generated.len(), 5);
        assert_eq!(resps[0].generated[0], resps[0].next_token);
    }

    #[test]
    fn packed_model_serves_and_reports_real_memory() {
        let dense = tiny();
        let mut packed_w = dense.weights.clone();
        packed_w.pack_experts_rtn(4, 16);
        let e_dense = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let e_packed =
            Engine::new(Model::new(packed_w), EngineConfig { workers: 1, ..Default::default() });
        let (resps_d, md) = e_dense.serve(reqs(6, 16));
        let (resps_p, mp) = e_packed.serve(reqs(6, 16));
        assert_eq!(resps_p.len(), 6);
        assert!(resps_p.iter().all(|r| r.mean_logprob.is_finite()));
        // Dense engine: resident == f32 size. Packed engine: experts shrank.
        assert_eq!(md.resident_weight_bytes, md.fp32_weight_bytes);
        assert!(mp.resident_weight_bytes < md.resident_weight_bytes);
        assert!(mp.resident_expert_bytes < md.resident_expert_bytes / 3);
        assert!(mp.weight_compression_ratio() > 1.5);
        assert!(mp.summary().contains("MB"));
        // 4-bit RTN barely perturbs outputs on this tiny model: both
        // engines must serve every request with finite diagnostics.
        assert_eq!(resps_d.len(), resps_p.len());
    }

    #[test]
    fn deterministic_outputs_across_worker_counts() {
        let e1 = Engine::new(tiny(), EngineConfig { workers: 1, ..Default::default() });
        let e4 = Engine::new(tiny(), EngineConfig { workers: 4, ..Default::default() });
        let (mut r1, _) = e1.serve(reqs(8, 12));
        let (mut r4, _) = e4.serve(reqs(8, 12));
        r1.sort_by_key(|r| r.id);
        r4.sort_by_key(|r| r.id);
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.next_token, b.next_token);
            assert!((a.mean_logprob - b.mean_logprob).abs() < 1e-5);
        }
    }
}
