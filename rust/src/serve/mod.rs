//! Serving engine (L3): request queue, SLO-aware continuous batcher,
//! PESF-integrated prefill executor, streaming, and latency/throughput
//! metrics.
//!
//! The engine owns the request lifecycle: requests enter a bounded queue,
//! the batcher forms batches under a max-size/max-wait policy — draining
//! by priority, then deadline, round-robin across tenants — worker
//! threads run prefill (monolithic, or chunked and interleaved with
//! decode steps via [`EngineConfig::prefill_chunk`]), and PESF masks are
//! derived per sequence before the MoE layers execute — so pruned experts
//! never run, which is where the Table-3/4 speedups come from.
//!
//! Decode is served from the prefill's own KV export
//! ([`crate::model::Model::prefill_into_cache`]): the prompt is forwarded
//! exactly once, and a worker advances its whole batch one token per step
//! through [`crate::model::Model::decode_step_batch`], with finished
//! sequences retiring and queued requests admitted into the freed slots.
//! Under PESF the pruning follows each sequence into that loop: its
//! `layer × expert` mask rides every decode step (per batch row) and is
//! refreshed online from a rolling selection-frequency window
//! ([`crate::prune::pesf::PesfDecodeState`]), so the advertised prune
//! rate is paid out where serving spends its time — `ServeMetrics`
//! reports the prefill- and decode-phase rates separately.
//!
//! The streaming/SLO surface: each [`Request`] may carry a priority, a
//! deadline (expired requests are shed as
//! [`FinishReason::DeadlineExceeded`] without running prefill), a tenant
//! (fairness domain), and a [`StreamSink`] emitting
//! [`StreamEvent`]s per token. TTFT and inter-token gaps derive from one
//! shared `Instant` per decode step and aggregate into p50/p95/p99 in
//! [`ServeMetrics`]. `workload` builds open-loop Poisson arrival
//! schedules (or replays JSON traces) for
//! [`Engine::serve_timed`].

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod workload;

pub use batcher::{Batcher, BatchPolicy};
pub use engine::{Engine, EngineConfig, PrunePolicy};
pub use metrics::{LatencyStats, ServeMetrics};
pub use request::{FinishReason, Request, RequestId, Response, StreamEvent, StreamSink};
pub use workload::{LenDist, TimedRequest, WorkloadSpec};
