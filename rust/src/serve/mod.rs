//! Serving engine (L3): request queue, continuous batcher, PESF-integrated
//! prefill executor, and latency/throughput metrics.
//!
//! The engine owns the request lifecycle: requests enter a bounded queue,
//! the batcher forms batches under a max-size/max-wait policy, worker
//! threads run prefill (native or PJRT-backed), and PESF masks are derived
//! per sequence before the MoE layers execute — so pruned experts never run,
//! which is where the Table-3/4 speedups come from.
//!
//! Decode is served from the prefill's own KV export
//! ([`crate::model::Model::prefill_into_cache`]): the prompt is forwarded
//! exactly once, and a worker advances its whole batch one token per step
//! through [`crate::model::Model::decode_step_batch`], with finished
//! sequences retiring and queued requests admitted into the freed slots.
//! Under PESF the pruning follows each sequence into that loop: its
//! `layer × expert` mask rides every decode step (per batch row) and is
//! refreshed online from a rolling selection-frequency window
//! ([`crate::prune::pesf::PesfDecodeState`]), so the advertised prune
//! rate is paid out where serving spends its time — `ServeMetrics`
//! reports the prefill- and decode-phase rates separately.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;

pub use batcher::{Batcher, BatchPolicy};
pub use engine::{Engine, EngineConfig, PrunePolicy};
pub use metrics::{LatencyStats, ServeMetrics};
pub use request::{FinishReason, Request, RequestId, Response};
