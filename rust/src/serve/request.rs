//! Request / response types for the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// A prefill (context-scoring) request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Number of tokens to greedily decode after prefill (0 = prefill
    /// only). Under `PrunePolicy::Pesf` the sequence's expert mask follows
    /// it into the batched decode loop (decode-time PESF — this extends
    /// the paper, whose Limitations disable PESF during generation).
    pub decode_tokens: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, tokens: Vec<u32>) -> Self {
        Request { id, tokens, decode_tokens: 0, arrival: Instant::now() }
    }

    pub fn with_decode(mut self, n: usize) -> Self {
        self.decode_tokens = n;
        self
    }
}

/// Why decoding stopped for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The full decode budget (`decode_tokens`) was generated. Also the
    /// reason for prefill-only requests (budget 0).
    Length,
    /// The KV cache hit `max_seq` before the budget was exhausted: the
    /// continuation is truncated (`generated.len() < decode_tokens`).
    CacheFull,
    /// Rejected at admission: the prompt exceeds the model's `max_seq`, so
    /// it was never forwarded. The request finishes immediately with empty
    /// `generated` (and meaningless `next_token`/`mean_logprob`) instead of
    /// panicking a worker and taking the whole engine — and every other
    /// in-flight request — down with it.
    PromptTooLong,
    /// Rejected at admission: an empty prompt has no last position to
    /// predict from. Same immediate-finish semantics as `PromptTooLong`.
    EmptyPrompt,
    /// Rejected at admission: a prompt token id is outside the model's
    /// vocabulary (would index the embedding table out of bounds). Same
    /// immediate-finish semantics as `PromptTooLong`.
    InvalidToken,
}

impl FinishReason {
    /// True for requests rejected at admission (never forwarded: no
    /// prefill ran, no tokens were processed).
    pub fn is_rejection(self) -> bool {
        matches!(
            self,
            FinishReason::PromptTooLong | FinishReason::EmptyPrompt | FinishReason::InvalidToken
        )
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Greedy next-token prediction after the prompt.
    pub next_token: u32,
    /// Greedily decoded continuation (len == decode_tokens unless
    /// `finish_reason` is [`FinishReason::CacheFull`]).
    pub generated: Vec<u32>,
    /// Why decoding stopped — makes KV-cache truncation observable instead
    /// of a silently short `generated`.
    pub finish_reason: FinishReason,
    /// Mean log-likelihood per predicted prompt token (diagnostic).
    pub mean_logprob: f32,
    /// Queue wait, in seconds.
    pub queue_secs: f64,
    /// Prefill execution time, in seconds.
    pub prefill_secs: f64,
    /// Time this request spent in the batched decode loop, in seconds
    /// (0 for prefill-only requests).
    pub decode_secs: f64,
    /// True arrival-to-completion wall time, in seconds. Not the sum of
    /// queue + prefill + decode: it also covers time spent waiting on
    /// batch-mates (their prefills and admissions) inside the worker.
    pub e2e_secs: f64,
    /// Fraction of experts pruned for this sequence during **prefill**
    /// (PESF mask rate, or the EES/ODP selection-drop rate; 0 if
    /// disabled).
    pub prune_rate: f32,
    /// Mean fraction of experts this sequence's PESF mask pruned across
    /// its batched **decode** steps (0 if pruning is disabled or the
    /// request took no decode step).
    pub decode_prune_rate: f32,
}
