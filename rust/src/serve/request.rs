//! Request / response types for the serving engine, including the
//! streaming event surface and SLO (priority/deadline/tenant) fields.

use std::fmt;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

pub type RequestId = u64;

/// A prefill (context-scoring) request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Number of tokens to greedily decode after prefill (0 = prefill
    /// only). Under `PrunePolicy::Pesf` the sequence's expert mask follows
    /// it into the batched decode loop (decode-time PESF — this extends
    /// the paper, whose Limitations disable PESF during generation).
    pub decode_tokens: usize,
    pub arrival: Instant,
    /// Scheduling priority: higher drains first within a tenant. Ties
    /// fall back to deadline, then strict arrival order, so the default
    /// (0) preserves exact FIFO behavior.
    pub priority: u8,
    /// Optional SLO deadline. The batcher drains tighter deadlines first
    /// at equal priority, and the engine sheds requests whose deadline
    /// has already passed at admission time
    /// ([`FinishReason::DeadlineExceeded`]) instead of burning prefill
    /// compute on a response nobody is waiting for.
    pub deadline: Option<Instant>,
    /// Fairness domain. The batcher round-robins across tenants when
    /// forming batches so one tenant's burst cannot starve the others.
    pub tenant: u32,
    /// Optional per-token event sink. When set, the engine emits
    /// [`StreamEvent::Started`] when the first token commits (end of
    /// prefill), [`StreamEvent::Token`] per decoded token, and
    /// [`StreamEvent::Finished`] with the full [`Response`]. When unset,
    /// the blocking [`crate::serve::Engine::serve`] path collects whole
    /// responses exactly as before.
    pub stream: Option<StreamSink>,
}

impl Request {
    pub fn new(id: RequestId, tokens: Vec<u32>) -> Self {
        Request {
            id,
            tokens,
            decode_tokens: 0,
            arrival: Instant::now(),
            priority: 0,
            deadline: None,
            tenant: 0,
            stream: None,
        }
    }

    pub fn with_decode(mut self, n: usize) -> Self {
        self.decode_tokens = n;
        self
    }

    pub fn with_priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }

    /// Set the deadline `budget` after this request's arrival timestamp.
    pub fn with_deadline_in(mut self, budget: Duration) -> Self {
        self.deadline = Some(self.arrival + budget);
        self
    }

    pub fn with_tenant(mut self, t: u32) -> Self {
        self.tenant = t;
        self
    }

    pub fn with_stream(mut self, sink: StreamSink) -> Self {
        self.stream = Some(sink);
        self
    }

    /// True when the request carries a deadline that has already passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// Per-token streaming events, in emission order per request:
/// `Started` → zero or more `Token` → `Finished`. Rejected/shed requests
/// emit only `Finished`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// The request's first token committed (prefill completed). `ttft_secs`
    /// is arrival → this event, measured at the shared step timestamp.
    Started { id: RequestId, next_token: u32, ttft_secs: f64 },
    /// One greedily decoded token. `index` counts from 0 within
    /// `generated`.
    Token { id: RequestId, token: u32, index: usize },
    /// Terminal event carrying the complete response (also how rejected
    /// or deadline-shed requests surface: no `Started`, empty
    /// `generated`).
    Finished(Box<Response>),
}

/// Cloneable handle the engine uses to emit [`StreamEvent`]s for one
/// request. A dropped receiver is fine: sends become no-ops, the request
/// still completes through the blocking path.
#[derive(Clone)]
pub struct StreamSink {
    tx: Sender<StreamEvent>,
}

impl StreamSink {
    pub fn new(tx: Sender<StreamEvent>) -> Self {
        StreamSink { tx }
    }

    /// Build a connected sink/receiver pair.
    pub fn channel() -> (StreamSink, Receiver<StreamEvent>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (StreamSink { tx }, rx)
    }

    /// Emit one event. Errors (receiver hung up) are deliberately
    /// swallowed: a consumer that stopped listening must not take the
    /// serving worker down.
    pub fn send(&self, ev: StreamEvent) {
        let _ = self.tx.send(ev);
    }
}

impl fmt::Debug for StreamSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("StreamSink")
    }
}

/// Why decoding stopped for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The full decode budget (`decode_tokens`) was generated. Also the
    /// reason for prefill-only requests (budget 0).
    Length,
    /// The KV cache hit `max_seq` before the budget was exhausted: the
    /// continuation is truncated (`generated.len() < decode_tokens`).
    CacheFull,
    /// Rejected at admission: the prompt exceeds the model's `max_seq`, so
    /// it was never forwarded. The request finishes immediately with empty
    /// `generated` (and meaningless `next_token`/`mean_logprob`) instead of
    /// panicking a worker and taking the whole engine — and every other
    /// in-flight request — down with it.
    PromptTooLong,
    /// Rejected at admission: an empty prompt has no last position to
    /// predict from. Same immediate-finish semantics as `PromptTooLong`.
    EmptyPrompt,
    /// Rejected at admission: a prompt token id is outside the model's
    /// vocabulary (would index the embedding table out of bounds). Same
    /// immediate-finish semantics as `PromptTooLong`.
    InvalidToken,
    /// Shed at admission: the request's SLO deadline had already passed
    /// when a worker picked it up, so no prefill ran (load shedding —
    /// compute goes to requests that can still meet their deadline).
    DeadlineExceeded,
}

impl FinishReason {
    /// True for requests rejected or shed at admission (never forwarded:
    /// no prefill ran, no tokens were processed).
    pub fn is_rejection(self) -> bool {
        matches!(
            self,
            FinishReason::PromptTooLong
                | FinishReason::EmptyPrompt
                | FinishReason::InvalidToken
                | FinishReason::DeadlineExceeded
        )
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Greedy next-token prediction after the prompt.
    pub next_token: u32,
    /// Greedily decoded continuation (len == decode_tokens unless
    /// `finish_reason` is [`FinishReason::CacheFull`]).
    pub generated: Vec<u32>,
    /// Why decoding stopped — makes KV-cache truncation observable instead
    /// of a silently short `generated`.
    pub finish_reason: FinishReason,
    /// Mean log-likelihood per predicted prompt token (diagnostic).
    pub mean_logprob: f32,
    /// Queue wait, in seconds.
    pub queue_secs: f64,
    /// Prefill execution time, in seconds.
    pub prefill_secs: f64,
    /// Time this request spent in the batched decode loop, in seconds
    /// (0 for prefill-only requests). Consistent with the sum of this
    /// request's `itl_secs` step gaps by construction: both derive from
    /// the same one-`Instant`-per-step timestamps.
    pub decode_secs: f64,
    /// True arrival-to-completion wall time, in seconds. Not the sum of
    /// queue + prefill + decode: it also covers time spent waiting on
    /// batch-mates (their prefills and admissions) inside the worker.
    pub e2e_secs: f64,
    /// Time-to-first-token: arrival → first committed token (the prefill
    /// output `next_token` counts as the first token). 0 for rejected or
    /// shed requests.
    pub ttft_secs: f64,
    /// Inter-token gaps between consecutive decoded tokens, one per gap
    /// (`generated.len() - 1` samples when at least two tokens were
    /// generated; empty otherwise). Rows of a batched step share a single
    /// step timestamp, so equal-length batch-mates report identical gaps.
    pub itl_secs: Vec<f64>,
    /// Fraction of experts pruned for this sequence during **prefill**
    /// (PESF mask rate, or the EES/ODP selection-drop rate; 0 if
    /// disabled).
    pub prune_rate: f32,
    /// Mean fraction of experts this sequence's PESF mask pruned across
    /// its batched **decode** steps (0 if pruning is disabled or the
    /// request took no decode step).
    pub decode_prune_rate: f32,
}
