//! Request / response types for the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// A prefill (context-scoring) request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Number of tokens to greedily decode after prefill (0 = prefill only;
    /// the paper measures context latency, decode is provided for
    /// completeness — PESF is disabled during decode per the Limitations).
    pub decode_tokens: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, tokens: Vec<u32>) -> Self {
        Request { id, tokens, decode_tokens: 0, arrival: Instant::now() }
    }

    pub fn with_decode(mut self, n: usize) -> Self {
        self.decode_tokens = n;
        self
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Greedy next-token prediction after the prompt.
    pub next_token: u32,
    /// Greedily decoded continuation (len == decode_tokens).
    pub generated: Vec<u32>,
    /// Mean log-likelihood per predicted prompt token (diagnostic).
    pub mean_logprob: f32,
    /// Queue wait, in seconds.
    pub queue_secs: f64,
    /// Prefill execution time, in seconds.
    pub prefill_secs: f64,
    /// Fraction of experts PESF pruned for this sequence (0 if disabled).
    pub prune_rate: f32,
}
