//! Continuous batcher: forms batches from the request queue under a
//! max-batch-size / max-wait policy (the standard serving tradeoff:
//! larger batches amortize work, waiting adds latency).

use super::request::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue-depth bound: [`Batcher::push`] rejects once this many
    /// requests are waiting (backpressure instead of unbounded memory
    /// growth under a producer that outruns the engine). The default is
    /// effectively unbounded, preserving the original accept-everything
    /// behavior.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2), max_queue: usize::MAX }
    }
}

/// Thread-safe request queue with batch draining.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    queue: VecDeque<Request>,
    closed: bool,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            state: Mutex::new(QueueState { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. On rejection — the batcher is closed, or the
    /// queue is at [`BatchPolicy::max_queue`] depth — the request is
    /// handed back so the caller decides whether to retry, shed, or
    /// fail it.
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.queue.len() >= self.policy.max_queue {
            return Err(req);
        }
        st.queue.push_back(req);
        self.cv.notify_all();
        Ok(())
    }

    /// Close the queue: pending requests still drain, pushes are rejected.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking: wait for a batch. Returns None when closed and drained.
    /// Policy: return as soon as `max_batch` requests are available, or
    /// `max_wait` after the first request became available.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        // Wait until at least one request or closed.
        while st.queue.is_empty() && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if st.queue.is_empty() {
            return None; // closed + drained
        }
        // Wait (bounded) for the batch to fill.
        let deadline = Instant::now() + self.policy.max_wait;
        while st.queue.len() < self.policy.max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (lock, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = lock;
            if timeout.timed_out() {
                break;
            }
        }
        let n = st.queue.len().min(self.policy.max_batch);
        Some(st.queue.drain(..n).collect())
    }

    /// Non-blocking: take up to `n` queued requests immediately (possibly
    /// none). Used by the engine's continuous decode loop to admit new
    /// sequences into slots freed by retired ones, without waiting out the
    /// batch-formation policy.
    pub fn try_take(&self, n: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        let n = st.queue.len().min(n);
        st.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3])
    }

    #[test]
    fn drains_in_order_up_to_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        for i in 0..5 {
            assert!(b.push(req(i)).is_ok());
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.len(), 1);
        b.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn try_take_is_nonblocking_and_bounded() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.try_take(4).is_empty()); // empty queue: returns immediately
        for i in 0..3 {
            assert!(b.push(req(i)).is_ok());
        }
        let got = b.try_take(2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.try_take(5).len(), 1);
        assert!(b.try_take(1).is_empty());
    }

    #[test]
    fn full_queue_rejects_pushes_until_drained() {
        let b = Batcher::new(BatchPolicy { max_queue: 2, ..Default::default() });
        assert!(b.push(req(0)).is_ok());
        assert!(b.push(req(1)).is_ok());
        // At depth: rejected, and the request comes back to the caller.
        let rejected = b.push(req(2)).unwrap_err();
        assert_eq!(rejected.id, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.try_take(1).len(), 1); // free a slot
        assert!(b.push(rejected).is_ok()); // now accepted
        assert_eq!(b.try_take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn close_rejects_pushes_but_drains() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.push(req(1)).is_ok());
        b.close();
        assert!(b.push(req(2)).is_err());
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    /// Conservation: N requests pushed from many threads are delivered
    /// exactly once each (no loss, no duplication).
    #[test]
    fn prop_conservation_under_concurrency() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        }));
        let n_producers = 4;
        let per = 50u64;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let bb = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    assert!(bb.push(req(p * 1000 + i)).is_ok());
                }
            }));
        }
        let consumer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = bb.next_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        let mut want: Vec<u64> =
            (0..n_producers).flat_map(|p| (0..per).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(seen, want);
    }
}
