//! Continuous batcher: forms batches from the request queue under a
//! max-batch-size / max-wait policy (the standard serving tradeoff:
//! larger batches amortize work, waiting adds latency).
//!
//! Draining is SLO-aware: requests are held in per-tenant queues ordered
//! by (priority desc, deadline asc, arrival seq), and batches are formed
//! by round-robin across tenants so one tenant's burst cannot starve the
//! others. Default-built requests (priority 0, no deadline, tenant 0)
//! reduce to the original strict-FIFO behavior exactly.

use super::request::Request;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue-depth bound: [`Batcher::push`] rejects once this many
    /// requests are waiting (backpressure instead of unbounded memory
    /// growth under a producer that outruns the engine). The default is
    /// effectively unbounded, preserving the original accept-everything
    /// behavior.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2), max_queue: usize::MAX }
    }
}

/// Thread-safe request queue with SLO-aware, tenant-fair batch draining.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// One queued request plus its admission sequence number (the global
/// FIFO tiebreaker).
struct Entry {
    req: Request,
    seq: u64,
}

/// Scheduling order within a tenant queue: higher priority first; at
/// equal priority, earlier deadline first (deadline-less requests sort
/// after any deadline); then strict push order. Total over distinct
/// seqs, so insertion is deterministic.
fn drains_before(a: &Entry, b: &Entry) -> bool {
    if a.req.priority != b.req.priority {
        return a.req.priority > b.req.priority;
    }
    match (a.req.deadline, b.req.deadline) {
        (Some(x), Some(y)) if x != y => x < y,
        (Some(_), None) => true,
        (None, Some(_)) => false,
        _ => a.seq < b.seq,
    }
}

struct QueueState {
    /// Per-tenant queues, each held in drain order. `BTreeMap` keeps
    /// tenant iteration deterministic for the round-robin cursor.
    tenants: BTreeMap<u32, VecDeque<Entry>>,
    /// Total queued requests across tenants (the `max_queue` bound).
    total: usize,
    /// Monotonic push counter — the FIFO tiebreaker in `drains_before`.
    next_seq: u64,
    /// Round-robin position: the next drain starts at the first tenant
    /// key >= this, wrapping past the largest key.
    cursor: u32,
    closed: bool,
}

impl QueueState {
    /// Insert in drain order. For default requests (equal priority, no
    /// deadline) the scan lands at the back — exact FIFO.
    fn insert(&mut self, req: Request) {
        let e = Entry { req, seq: self.next_seq };
        self.next_seq += 1;
        let q = self.tenants.entry(e.req.tenant).or_default();
        let idx = q.partition_point(|cur| !drains_before(&e, cur));
        q.insert(idx, e);
        self.total += 1;
    }

    /// Take up to `n` requests, one per tenant per round-robin turn.
    fn drain(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < n && self.total > 0 {
            let key = self
                .tenants
                .range(self.cursor..)
                .next()
                .map(|(k, _)| *k)
                .or_else(|| self.tenants.keys().next().copied());
            let Some(k) = key else { break };
            if let Some(q) = self.tenants.get_mut(&k) {
                if let Some(e) = q.pop_front() {
                    out.push(e.req);
                    self.total -= 1;
                }
                if q.is_empty() {
                    self.tenants.remove(&k);
                }
            }
            self.cursor = k.wrapping_add(1);
        }
        out
    }
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            state: Mutex::new(QueueState {
                tenants: BTreeMap::new(),
                total: 0,
                next_seq: 0,
                cursor: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. On rejection — the batcher is closed, or the
    /// queue is at [`BatchPolicy::max_queue`] depth — the request is
    /// handed back so the caller decides whether to retry, shed, or
    /// fail it.
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.total >= self.policy.max_queue {
            return Err(req);
        }
        st.insert(req);
        self.cv.notify_all();
        Ok(())
    }

    /// Close the queue: pending requests still drain, pushes are rejected.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking: wait for a batch. Returns None when closed and drained.
    /// Policy: return as soon as `max_batch` requests are available, or
    /// `max_wait` after the first request became available.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        // Wait until at least one request or closed.
        while st.total == 0 && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if st.total == 0 {
            return None; // closed + drained
        }
        // Wait (bounded) for the batch to fill.
        let deadline = Instant::now() + self.policy.max_wait;
        while st.total < self.policy.max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (lock, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = lock;
            if timeout.timed_out() {
                break;
            }
        }
        let n = st.total.min(self.policy.max_batch);
        Some(st.drain(n))
    }

    /// Non-blocking: take up to `n` queued requests immediately (possibly
    /// none). Used by the engine's continuous decode loop to admit new
    /// sequences into slots freed by retired ones, without waiting out the
    /// batch-formation policy.
    pub fn try_take(&self, n: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        let n = st.total.min(n);
        st.drain(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3])
    }

    #[test]
    fn drains_in_order_up_to_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        for i in 0..5 {
            assert!(b.push(req(i)).is_ok());
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.len(), 1);
        b.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn try_take_is_nonblocking_and_bounded() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.try_take(4).is_empty()); // empty queue: returns immediately
        for i in 0..3 {
            assert!(b.push(req(i)).is_ok());
        }
        let got = b.try_take(2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.try_take(5).len(), 1);
        assert!(b.try_take(1).is_empty());
    }

    #[test]
    fn full_queue_rejects_pushes_until_drained() {
        let b = Batcher::new(BatchPolicy { max_queue: 2, ..Default::default() });
        assert!(b.push(req(0)).is_ok());
        assert!(b.push(req(1)).is_ok());
        // At depth: rejected, and the request comes back to the caller.
        let rejected = b.push(req(2)).unwrap_err();
        assert_eq!(rejected.id, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.try_take(1).len(), 1); // free a slot
        assert!(b.push(rejected).is_ok()); // now accepted
        assert_eq!(b.try_take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn close_rejects_pushes_but_drains() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.push(req(1)).is_ok());
        b.close();
        assert!(b.push(req(2)).is_err());
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn priority_preempts_fifo_within_tenant() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.push(req(0)).is_ok());
        assert!(b.push(req(1).with_priority(2)).is_ok());
        assert!(b.push(req(2).with_priority(1)).is_ok());
        assert!(b.push(req(3).with_priority(2)).is_ok());
        // Priority desc, FIFO within a priority level.
        assert_eq!(b.try_take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 2, 0]);
    }

    #[test]
    fn tighter_deadline_drains_first_at_equal_priority() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.push(req(0)).is_ok()); // no deadline: last
        assert!(b.push(req(1).with_deadline_in(Duration::from_secs(60))).is_ok());
        assert!(b.push(req(2).with_deadline_in(Duration::from_secs(1))).is_ok());
        // Priority still dominates deadline.
        assert!(b.push(req(3).with_priority(1)).is_ok());
        assert_eq!(b.try_take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 2, 1, 0]);
    }

    #[test]
    fn round_robin_across_tenants() {
        let b = Batcher::new(BatchPolicy::default());
        // Tenant 7 floods first; tenant 2 arrives later with two requests.
        for i in 0..4 {
            assert!(b.push(req(i).with_tenant(7)).is_ok());
        }
        assert!(b.push(req(100).with_tenant(2)).is_ok());
        assert!(b.push(req(101).with_tenant(2)).is_ok());
        // Drains alternate tenants (ascending-key rotation), FIFO inside
        // each: neither tenant waits behind the whole other queue.
        let ids: Vec<u64> = b.try_take(6).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![100, 0, 101, 1, 2, 3]);
    }

    /// Open-loop burst, satellite (i)+(ii): every pushed request is
    /// either queued (drained later) or handed back by `push` — none
    /// vanish — and a flooding tenant cannot starve a trickling one.
    #[test]
    fn burst_conserves_requests_and_bounds_starvation() {
        let b = Batcher::new(BatchPolicy { max_queue: 8, ..Default::default() });
        let mut accepted = Vec::new();
        let mut shed = Vec::new();
        let mut drained = Vec::new();
        // Tenant 1 floods 8 requests per wave against max_queue=8;
        // tenant 2 trickles one; the consumer drains small batches
        // between waves, as an engine would.
        let mut id = 0u64;
        for wave in 0..4u64 {
            match b.push(req(1000 + wave).with_tenant(2)) {
                Ok(()) => accepted.push(1000 + wave),
                Err(r) => shed.push(r.id),
            }
            for _ in 0..8 {
                match b.push(req(id).with_tenant(1)) {
                    Ok(()) => accepted.push(id),
                    Err(r) => shed.push(r.id),
                }
                id += 1;
            }
            // Fairness bound: with tenant 1 flooding a full queue, the
            // very next two-slot drain still serves tenant 2 — round
            // robin hands each tenant one slot per rotation, so a
            // trickling tenant waits O(#tenants), not O(backlog).
            let batch: Vec<u64> = b.try_take(2).iter().map(|r| r.id).collect();
            assert!(
                batch.contains(&(1000 + wave)),
                "tenant-2 starved in wave {wave}: {batch:?}"
            );
            drained.extend(batch);
        }
        while let Some(r) = b.try_take(1).pop() {
            drained.push(r.id);
        }
        // Conservation: accepted requests all drain exactly once;
        // accepted + shed account for every push.
        let mut d = drained.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), drained.len(), "duplicated delivery");
        let mut a = accepted.clone();
        a.sort_unstable();
        drained.sort_unstable();
        assert_eq!(drained, a, "accepted vs drained mismatch");
        assert_eq!(accepted.len() + shed.len(), 36);
        assert!(!shed.is_empty(), "burst should overflow max_queue=8");
    }

    /// Conservation: N requests pushed from many threads are delivered
    /// exactly once each (no loss, no duplication).
    #[test]
    fn prop_conservation_under_concurrency() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        }));
        let n_producers = 4;
        let per = 50u64;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let bb = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    assert!(bb.push(req(p * 1000 + i)).is_ok());
                }
            }));
        }
        let consumer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = bb.next_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        let mut want: Vec<u64> =
            (0..n_producers).flat_map(|p| (0..per).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(seen, want);
    }
}
