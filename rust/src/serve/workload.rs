//! Open-loop serving workloads: Poisson arrival schedules with mixed
//! prompt/output-length distributions, plus JSON trace replay.
//!
//! An *open-loop* generator decides arrival times independently of the
//! engine's progress (unlike a closed loop, where the next request waits
//! for the previous response) — which is what exposes queueing delay and
//! tail latency under bursts. [`generate`] draws inter-arrival gaps from
//! an exponential distribution (a Poisson process) using the repo's own
//! seeded [`Pcg64`], so a workload is fully reproducible from its
//! [`WorkloadSpec`]. [`load_trace`]/[`from_trace`] replay an explicit
//! schedule from a JSON file instead.
//!
//! The schedules feed [`crate::serve::Engine::serve_timed`], which
//! re-stamps each request's `arrival` at its actual push time and applies
//! the deadline budget relative to that arrival.

use super::request::Request;
use crate::tensor::rng::Pcg64;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::time::Duration;

/// One scheduled arrival: push `req` at `at_secs` after the run starts.
/// `deadline_budget` (if any) is applied relative to the actual push time
/// by [`crate::serve::Engine::serve_timed`].
#[derive(Clone, Debug)]
pub struct TimedRequest {
    pub at_secs: f64,
    pub req: Request,
    pub deadline_budget: Option<Duration>,
}

/// A discrete length distribution for prompt / decode budgets.
#[derive(Clone, Copy, Debug)]
pub enum LenDist {
    /// Every request gets exactly this length.
    Fixed(usize),
    /// Uniform over `[lo, hi]` (inclusive).
    Uniform { lo: usize, hi: usize },
    /// Short/long mixture: `short` with probability `p_short`, else
    /// `long`. The canonical chunked-prefill stressor — a few long
    /// prompts interleaved with many short ones.
    Bimodal { short: usize, long: usize, p_short: f64 },
}

impl LenDist {
    fn sample(&self, rng: &mut Pcg64) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform { lo, hi } => {
                let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
                let span = (hi - lo) as u64 + 1;
                lo + rng.below(span) as usize
            }
            LenDist::Bimodal { short, long, p_short } => {
                if rng.next_f64() < p_short {
                    short
                } else {
                    long
                }
            }
        }
    }
}

/// Parameters of a synthetic open-loop workload. Prompts are uniform
/// random token ids in `[0, vocab)` (this layer is below `data`, so no
/// corpus text — serving latency does not care what the tokens say).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// Mean arrival rate (requests/second) of the Poisson process. A
    /// non-positive or non-finite rate degenerates to "all at t = 0"
    /// (a single maximal burst).
    pub rate_per_sec: f64,
    pub prompt_len: LenDist,
    /// Decode budget per request (0 = prefill-only scoring).
    pub decode_len: LenDist,
    /// Number of fairness domains; requests are assigned round-robin
    /// (request i → tenant i mod tenants).
    pub tenants: u32,
    /// Token-id range for synthetic prompts (use the served model's
    /// vocab).
    pub vocab: usize,
    pub seed: u64,
    /// SLO budget applied to every request (arrival → deadline), if any.
    pub deadline_budget: Option<Duration>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 32,
            rate_per_sec: 50.0,
            prompt_len: LenDist::Uniform { lo: 8, hi: 64 },
            decode_len: LenDist::Fixed(8),
            tenants: 1,
            vocab: 64,
            seed: 0,
            deadline_budget: None,
        }
    }
}

/// Generate a reproducible open-loop arrival schedule: exponential
/// inter-arrival gaps (Poisson process at `rate_per_sec`), lengths drawn
/// per request from the spec's distributions, request ids `0..n`.
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = Pcg64::new(spec.seed, 17);
    let vocab = spec.vocab.max(1) as u64;
    let tenants = spec.tenants.max(1);
    let open_loop = spec.rate_per_sec.is_finite() && spec.rate_per_sec > 0.0;
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        if open_loop {
            // Exponential gap via inverse CDF; next_f64 < 1 keeps the
            // log argument strictly positive.
            t += -(1.0 - rng.next_f64()).ln() / spec.rate_per_sec;
        }
        let prompt_len = spec.prompt_len.sample(&mut rng).max(1);
        let decode_len = spec.decode_len.sample(&mut rng);
        let tokens: Vec<u32> = (0..prompt_len).map(|_| rng.below(vocab) as u32).collect();
        let req = Request::new(i as u64, tokens)
            .with_decode(decode_len)
            .with_tenant(i as u32 % tenants);
        out.push(TimedRequest { at_secs: t, req, deadline_budget: spec.deadline_budget });
    }
    out
}

/// Parse a trace document into an arrival schedule. Expected shape:
///
/// ```json
/// { "requests": [ { "at_secs": 0.0, "tokens": [1, 2, 3],
///                   "decode_tokens": 8, "tenant": 0, "priority": 0,
///                   "deadline_ms": 50.0 }, ... ] }
/// ```
///
/// `at_secs` and `tokens` are required per entry; the rest default to
/// zero / none. Malformed documents surface as errors naming the entry,
/// never a panic (this runs behind the `serve --workload` CLI).
pub fn from_trace(doc: &Json) -> Result<Vec<TimedRequest>> {
    let entries = doc.req_arr("requests")?;
    let mut out = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let ctx = || format!("trace request #{i}");
        let at_secs = e.req_f64("at_secs").with_context(ctx)?;
        if !(at_secs.is_finite() && at_secs >= 0.0) {
            return Err(anyhow!("trace request #{i}: at_secs {at_secs} must be finite and >= 0"));
        }
        let toks = e.req_arr("tokens").with_context(ctx)?;
        let mut tokens = Vec::with_capacity(toks.len());
        for t in toks {
            let v = t
                .as_f64()
                .ok_or_else(|| anyhow!("trace request #{i}: non-numeric token"))?;
            if !(v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v <= u32::MAX as f64) {
                return Err(anyhow!("trace request #{i}: token {v} is not a u32"));
            }
            tokens.push(v as u32);
        }
        let decode_tokens = match e.get("decode_tokens") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow!("trace request #{i}: decode_tokens is not an integer"))?,
            None => 0,
        };
        let tenant = match e.get("tenant") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow!("trace request #{i}: tenant is not an integer"))?
                as u32,
            None => 0,
        };
        let priority = match e.get("priority") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow!("trace request #{i}: priority is not an integer"))?
                .min(u8::MAX as usize) as u8,
            None => 0,
        };
        let deadline_budget = match e.get("deadline_ms") {
            Some(v) => {
                let ms = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("trace request #{i}: deadline_ms is not a number"))?;
                if !(ms.is_finite() && ms >= 0.0) {
                    return Err(anyhow!(
                        "trace request #{i}: deadline_ms {ms} must be finite and >= 0"
                    ));
                }
                Some(Duration::from_secs_f64(ms / 1e3))
            }
            None => None,
        };
        let req = Request::new(i as u64, tokens)
            .with_decode(decode_tokens)
            .with_tenant(tenant)
            .with_priority(priority);
        out.push(TimedRequest { at_secs, req, deadline_budget });
    }
    Ok(out)
}

/// Load and parse a trace file (see [`from_trace`] for the format).
pub fn load_trace(path: &Path) -> Result<Vec<TimedRequest>> {
    let doc = crate::util::json::load(path)?;
    from_trace(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_is_deterministic_and_monotone() {
        let spec = WorkloadSpec { n_requests: 200, rate_per_sec: 100.0, ..Default::default() };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs, "same seed must replay identically");
            assert_eq!(x.req.tokens, y.req.tokens);
            assert_eq!(x.req.decode_tokens, y.req.decode_tokens);
        }
        for w in a.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs, "arrivals must be non-decreasing");
        }
        // Mean inter-arrival ≈ 1/rate (loose statistical bound).
        let mean_gap = a.last().map(|t| t.at_secs).unwrap_or(0.0) / 199.0;
        assert!((mean_gap - 0.01).abs() < 0.004, "mean gap {mean_gap} !~ 0.01");
        // Different seeds give different schedules.
        let c = generate(&WorkloadSpec { seed: 9, ..spec });
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_secs != y.at_secs));
    }

    #[test]
    fn degenerate_rate_is_one_burst() {
        let spec =
            WorkloadSpec { n_requests: 10, rate_per_sec: 0.0, ..Default::default() };
        assert!(generate(&spec).iter().all(|t| t.at_secs == 0.0));
    }

    #[test]
    fn length_distributions_sample_in_range() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..200 {
            assert_eq!(LenDist::Fixed(7).sample(&mut rng), 7);
            let u = LenDist::Uniform { lo: 4, hi: 9 }.sample(&mut rng);
            assert!((4..=9).contains(&u));
            let b = LenDist::Bimodal { short: 2, long: 50, p_short: 0.8 }.sample(&mut rng);
            assert!(b == 2 || b == 50);
        }
        // Reversed bounds are tolerated, not a panic.
        let r = LenDist::Uniform { lo: 9, hi: 4 }.sample(&mut rng);
        assert!((4..=9).contains(&r));
    }

    #[test]
    fn workload_respects_vocab_tenants_and_deadline() {
        let spec = WorkloadSpec {
            n_requests: 24,
            vocab: 16,
            tenants: 3,
            deadline_budget: Some(Duration::from_millis(40)),
            prompt_len: LenDist::Bimodal { short: 4, long: 32, p_short: 0.75 },
            ..Default::default()
        };
        let w = generate(&spec);
        for (i, t) in w.iter().enumerate() {
            assert_eq!(t.req.id, i as u64);
            assert!(t.req.tokens.iter().all(|&tok| tok < 16));
            assert_eq!(t.req.tenant, i as u32 % 3);
            assert_eq!(t.deadline_budget, Some(Duration::from_millis(40)));
        }
        let shorts = w.iter().filter(|t| t.req.tokens.len() == 4).count();
        let longs = w.iter().filter(|t| t.req.tokens.len() == 32).count();
        assert_eq!(shorts + longs, 24, "bimodal lengths only");
        assert!(shorts > longs, "p_short=0.75 must skew short");
    }

    #[test]
    fn trace_replay_parses_fields_and_rejects_malformed() {
        let doc = Json::parse(
            r#"{"requests": [
                {"at_secs": 0.0, "tokens": [1, 2, 3], "decode_tokens": 4,
                 "tenant": 2, "priority": 1, "deadline_ms": 50},
                {"at_secs": 0.25, "tokens": [5]}
            ]}"#,
        )
        .unwrap();
        let w = from_trace(&doc).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].req.tokens, vec![1, 2, 3]);
        assert_eq!(w[0].req.decode_tokens, 4);
        assert_eq!(w[0].req.tenant, 2);
        assert_eq!(w[0].req.priority, 1);
        assert_eq!(w[0].deadline_budget, Some(Duration::from_millis(50)));
        assert_eq!(w[1].at_secs, 0.25);
        assert_eq!(w[1].req.decode_tokens, 0);
        assert_eq!(w[1].deadline_budget, None);

        let missing = Json::parse(r#"{"requests": [{"at_secs": 0.0}]}"#).unwrap();
        let err = format!("{:#}", from_trace(&missing).unwrap_err());
        assert!(err.contains("#0"), "error must name the entry: {err}");
        let bad_tok =
            Json::parse(r#"{"requests": [{"at_secs": 0.0, "tokens": [1.5]}]}"#).unwrap();
        assert!(from_trace(&bad_tok).is_err());
        let neg =
            Json::parse(r#"{"requests": [{"at_secs": -1, "tokens": [1]}]}"#).unwrap();
        assert!(from_trace(&neg).is_err());
        assert!(from_trace(&Json::obj()).is_err(), "missing requests array");
    }
}
