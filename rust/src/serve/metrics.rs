//! Serving metrics: latency percentiles, throughput, pruning telemetry.

/// Online latency statistics (stores samples; serving runs are bounded).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, secs: f64) {
        self.samples_ms.push(secs * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[((p * (s.len() - 1) as f64).round() as usize).min(s.len() - 1)]
    }
}

/// Aggregated serving-run metrics.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub prefill: LatencyStats,
    pub queue: LatencyStats,
    pub e2e: LatencyStats,
    pub total_tokens: usize,
    pub total_requests: usize,
    pub wall_secs: f64,
    /// Mean PESF prune rate across requests.
    pub mean_prune_rate: f32,
    /// True resident bytes of the served model's weights
    /// ([`crate::model::Weights::storage_bytes`]): packed experts count at
    /// their packed size, not a simulated f32 size.
    pub resident_weight_bytes: usize,
    /// Resident bytes of expert weights only (the paper's memory axis).
    pub resident_expert_bytes: usize,
    /// What the same weights would occupy fully dense in f32.
    pub fp32_weight_bytes: usize,
}

impl ServeMetrics {
    pub fn throughput_tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / self.wall_secs
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.total_requests as f64 / self.wall_secs
    }

    /// Resident-weight compression vs dense f32 (1.0 = uncompressed).
    pub fn weight_compression_ratio(&self) -> f64 {
        if self.resident_weight_bytes == 0 {
            return 1.0;
        }
        self.fp32_weight_bytes as f64 / self.resident_weight_bytes as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "reqs={} tokens={} wall={:.2}s thpt={:.0} tok/s prefill p50={:.1}ms p95={:.1}ms queue p50={:.1}ms prune={:.1}% weights={:.2}MB ({:.2}x vs f32)",
            self.total_requests,
            self.total_tokens,
            self.wall_secs,
            self.throughput_tokens_per_sec(),
            self.prefill.percentile_ms(0.5),
            self.prefill.percentile_ms(0.95),
            self.queue.percentile_ms(0.5),
            self.mean_prune_rate * 100.0,
            self.resident_weight_bytes as f64 / 1e6,
            self.weight_compression_ratio()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.record(i as f64 / 1000.0);
        }
        assert!(l.percentile_ms(0.5) <= l.percentile_ms(0.95));
        assert!((l.mean_ms() - 50.5).abs() < 1.0);
        assert_eq!(l.count(), 100);
    }

    #[test]
    fn empty_stats_are_zero() {
        let m = ServeMetrics::default();
        assert_eq!(m.throughput_tokens_per_sec(), 0.0);
        assert_eq!(m.prefill.mean_ms(), 0.0);
    }
}
