//! Top-level coordination: model loading, the compression pipeline driver,
//! and shared experiment context (calibration/eval data plumbing).

pub mod context;

pub use context::{load_or_init_model, ExperimentContext};
