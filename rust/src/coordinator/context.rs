//! Shared experiment context: loads pretrained zoo weights from
//! `artifacts/models/` (written by `python/compile/pretrain.py`), falling
//! back to seeded random initialization when artifacts are absent (tests,
//! artifact-free CI), and provides the standard calibration/eval streams.

use crate::data::corpus::WikiMixture;
use crate::model::{Model, Weights, ZooModel};
use crate::Result;
use std::path::{Path, PathBuf};

/// Where pretrained weights live.
pub fn models_dir() -> PathBuf {
    crate::runtime::artifacts::ArtifactManifest::default_root().join("models")
}

/// Load a pretrained zoo model if present, else initialize randomly.
/// Returns (model, pretrained?).
pub fn load_or_init_model(zoo: ZooModel) -> (Model, bool) {
    let path = models_dir().join(format!("{}.bin", zoo.key()));
    match Weights::load(&path, zoo.key()) {
        Ok(w) => (Model::new(w), true),
        Err(_) => (Model::new(Weights::init(&zoo.config(), zoo_seed(zoo))), false),
    }
}

/// Load strictly from a path (used by the CLI with --model-path).
pub fn load_model_from(path: &Path, name: &str) -> Result<Model> {
    Ok(Model::new(Weights::load(path, name)?))
}

fn zoo_seed(zoo: ZooModel) -> u64 {
    match zoo {
        ZooModel::MixtralMini => 101,
        ZooModel::PhiMini => 102,
        ZooModel::DeepseekMini => 103,
        ZooModel::QwenMini => 104,
    }
}

/// Standard data plumbing shared by experiments: the wiki mixture used for
/// calibration + PPL (WikiText2's role in the paper) and the eval suites.
pub struct ExperimentContext {
    /// GPTQ/QESC calibration sequences (paper: 128 × 2048 WikiText2; here
    /// scaled to the mini models).
    pub calib: Vec<Vec<u32>>,
    /// Held-out PPL sequences.
    pub ppl_eval: Vec<Vec<u32>>,
    pub seed: u64,
}

impl ExperimentContext {
    /// `scale` in (0, 1] shrinks data volumes for quick runs.
    pub fn new(seed: u64, scale: f64) -> Self {
        let scale = scale.clamp(0.05, 4.0);
        let n_calib = ((16.0 * scale).round() as usize).max(2);
        let n_eval = ((12.0 * scale).round() as usize).max(2);
        let len = ((128.0 * scale.sqrt()).round() as usize).clamp(32, 512);
        let mut calib_mix = WikiMixture::new(seed);
        let mut eval_mix = WikiMixture::new(seed + 5000);
        ExperimentContext {
            calib: calib_mix.sequences(n_calib, len),
            ppl_eval: eval_mix.sequences(n_eval, len),
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_init_when_no_artifacts() {
        // With a bogus artifacts root, load falls back to random init.
        std::env::set_var("EAC_MOE_ARTIFACTS", "/nonexistent-eac-moe");
        let (m, pretrained) = load_or_init_model(ZooModel::MixtralMini);
        std::env::remove_var("EAC_MOE_ARTIFACTS");
        assert!(!pretrained);
        assert_eq!(m.cfg().n_experts, 8);
    }

    #[test]
    fn context_scales() {
        let small = ExperimentContext::new(1, 0.1);
        let big = ExperimentContext::new(1, 1.0);
        assert!(small.calib.len() < big.calib.len());
        assert!(!small.calib.is_empty());
        // Calibration and eval streams differ.
        assert_ne!(small.calib[0], small.ppl_eval[0]);
    }
}
