//! `eac-moe` CLI — the leader entrypoint.
//!
//! Subcommands (no clap in the offline registry; args are parsed by hand):
//!
//! ```text
//! eac-moe info                          environment + artifact status
//! eac-moe compress  --model <key> --bits <2|2.5|3> [--no-calib] [--scale S]
//! eac-moe eval      --model <key> [--alpha A] [--scale S]
//! eac-moe serve     --model <key> [--pesf-alpha A] [--pesf-refresh R] [--pesf-window W]
//!                   [--requests N] [--len L] [--decode D] [--expert-budget-mb B]
//!                   [--kv-bits <32|8>] [--prefill-chunk C]
//!                   [--workload <poisson|trace.json>] [--rate R] [--deadline-ms D]
//!                   [--tenants T] [--seed S]
//! eac-moe analyze-es --model <key> [--scale S]
//! eac-moe analyze    --expert-sim --model <key> [--dataset D] [--scale S]
//! eac-moe experiment <id> [--scale S] [--from-analysis <json>]
//!                                       table1|table2|...|fig9|merge|all
//! ```

use eac_moe::coordinator::{load_or_init_model, ExperimentContext};
use eac_moe::model::ZooModel;
use eac_moe::runtime::xla_stub as xla;
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        return;
    }
    let cmd = args[0].as_str();
    let opts = parse_opts(&args[1..]);
    let result = match cmd {
        "info" => cmd_info(),
        "compress" => cmd_compress(&opts),
        "eval" => cmd_eval(&opts),
        "serve" => cmd_serve(&opts),
        "analyze-es" => cmd_analyze_es(&opts),
        "analyze" => cmd_analyze(&opts),
        "experiment" => {
            let id = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            let opts = parse_opts(&args[2..]);
            let run_opts = eac_moe::report::experiments::RunOpts {
                from_analysis: opts.get("from-analysis").map(std::path::PathBuf::from),
            };
            eac_moe::report::experiments::run_opts(id, scale(&opts), &run_opts)
        }
        "--help" | "-h" | "help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "eac-moe — Expert-Selection Aware Compressor for MoE LLMs (ACL 2025 reproduction)\n\
         \n\
         USAGE: eac-moe <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 info                         environment + artifact status\n\
         \x20 compress   --model <key> --bits <2|2.5|3> [--no-calib] [--scale S]\n\
         \x20 eval       --model <key> [--alpha A] [--scale S]\n\
         \x20 serve      --model <key> [--pesf-alpha A] [--pesf-refresh R] [--pesf-window W]\n\
         \x20            [--requests N] [--len L] [--decode D] [--workers W] [--threads T]\n\
         \x20            [--expert-budget-mb B] [--kv-bits {{32|8}}] [--prefill-chunk C]\n\
         \x20            [--workload {{poisson|<trace.json>}}] [--rate R] [--deadline-ms D]\n\
         \x20            [--tenants T] [--seed S]\n\
         \x20            (PESF prunes prefill AND decode; --pesf-refresh 0 freezes the\n\
         \x20             decode mask at prompt statistics; --alpha aliases --pesf-alpha;\n\
         \x20             --expert-budget-mb serves experts from disk under a hard cache\n\
         \x20             budget — bit-identical outputs, bounded expert memory;\n\
         \x20             --kv-bits 8 stores decode KV caches as int8 per head with\n\
         \x20             per-position scales — ~4x smaller caches, tolerance-pinned;\n\
         \x20             --prefill-chunk C interleaves prompt prefill in C-token chunks\n\
         \x20             with decode steps — same outputs, lower tail TTFT;\n\
         \x20             --workload poisson replays an open-loop Poisson burst at\n\
         \x20             --rate req/s (bimodal short/long prompts around --len, with\n\
         \x20             --deadline-ms SLO shedding across --tenants fairness domains);\n\
         \x20             --workload <trace.json> replays an explicit arrival trace)\n\
         \x20 analyze-es --model <key> [--scale S]\n\
         \x20 analyze    --expert-sim --model <key> [--dataset D] [--scale S]\n\
         \x20            (per-layer expert weight-similarity + utilization + pseudo-MoE\n\
         \x20             detection; writes results/analyze_expert_sim.json for\n\
         \x20             `prune::merge` threshold selection)\n\
         \x20 experiment <id> [--scale S]  (table1|table2|table3|table4|table5|table6|\n\
         \x20                               table7|table9|fig2|fig4|fig6|fig7|fig8|fig9|\n\
         \x20                               merge|all)\n\
         \x20            (merge also takes --from-analysis <json> to derive its\n\
         \x20             threshold sweep from an `analyze --expert-sim` result)\n\
         \n\
         MODELS: mixtral-mini | phi-mini | deepseek-mini | qwen-mini\n\
         SCALE:  data-volume multiplier for experiments (default 1.0; use 0.2 for quick runs)"
    );
}

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn model_key(opts: &HashMap<String, String>) -> ZooModel {
    let key = opts.get("model").map(|s| s.as_str()).unwrap_or("deepseek-mini");
    ZooModel::from_key(key).unwrap_or_else(|| {
        eprintln!("unknown model '{key}' (use mixtral-mini|phi-mini|deepseek-mini|qwen-mini)");
        std::process::exit(2);
    })
}

fn scale(opts: &HashMap<String, String>) -> f64 {
    opts.get("scale").and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

fn cmd_info() -> eac_moe::Result<()> {
    println!("eac-moe v{}", env!("CARGO_PKG_VERSION"));
    let root = eac_moe::runtime::ArtifactManifest::default_root();
    println!("artifacts root: {}", root.display());
    if eac_moe::runtime::ArtifactManifest::present(&root) {
        let m = eac_moe::runtime::ArtifactManifest::load(&root)?;
        println!("manifest: {} entries", m.entries.len());
    } else {
        println!("manifest: ABSENT (run `make artifacts`; native fallback paths active)");
    }
    for z in ZooModel::ALL {
        let (model, pretrained) = load_or_init_model(z);
        println!(
            "model {:<16} params={:>9}  experts={}x{} top{}+{}shared  weights={}",
            z.key(),
            model.weights.param_count(),
            model.cfg().n_layers,
            model.cfg().n_experts,
            model.cfg().top_k,
            model.cfg().n_shared,
            if pretrained { "pretrained" } else { "random-init (pretrain artifacts missing)" }
        );
    }
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("pjrt: platform={} devices={}", c.platform_name(), c.device_count()),
        Err(e) => println!("pjrt: UNAVAILABLE ({e})"),
    }
    Ok(())
}

fn cmd_compress(opts: &HashMap<String, String>) -> eac_moe::Result<()> {
    use eac_moe::calib::qesc::{qesc_compress, QescConfig};
    use eac_moe::quant::alloc::Allocator;
    let zoo = model_key(opts);
    let (model, pretrained) = load_or_init_model(zoo);
    if !pretrained {
        eprintln!("warning: using random-init weights (run `make artifacts` for pretrained)");
    }
    let ctx = ExperimentContext::new(7, scale(opts));
    let bits = opts.get("bits").map(|s| s.as_str()).unwrap_or("3");
    let k = QescConfig::default_k(model.cfg());
    let mut cfg = match bits {
        "2" => QescConfig::qesc(2, k),
        "2.5" => QescConfig {
            expert_alloc: Allocator::HalfSplit { hi: 3, lo: 2 },
            ..QescConfig::qesc(3, k)
        },
        "3" => QescConfig::qesc(3, k),
        other => anyhow::bail!("--bits must be 2, 2.5 or 3 (got {other})"),
    };
    if opts.contains_key("no-calib") {
        cfg.calib_router = false;
    }
    println!("compressing {} at expert-bits={} calib_router={}", zoo.key(), bits, cfg.calib_router);
    let t0 = std::time::Instant::now();
    let (qmodel, report) = qesc_compress(&model, &ctx.calib, &cfg);
    println!(
        "done in {:.1}s (gptq {:.1}s, router-calib {:.1}s = {:.1}%)",
        t0.elapsed().as_secs_f64(),
        report.gptq_secs,
        report.router_calib_secs,
        100.0 * report.router_calib_secs / (report.gptq_secs + report.router_calib_secs).max(1e-9)
    );
    println!(
        "storage: fp32 {:.2} MB -> packed {:.2} MB ({:.2}x)",
        report.fp_bytes as f64 / 1e6,
        report.compressed_bytes as f64 / 1e6,
        report.compression_ratio()
    );
    println!(
        "resident (measured): {:.2} MB total, experts {:.2} MB at avg {:.2} bits",
        qmodel.weights.storage_bytes() as f64 / 1e6,
        qmodel.weights.expert_storage_bytes() as f64 / 1e6,
        report.avg_expert_bits
    );
    let ppl_fp = eac_moe::eval::perplexity(&model, &ctx.ppl_eval);
    let ppl_q = eac_moe::eval::perplexity(&qmodel, &ctx.ppl_eval);
    println!("ppl: fp {ppl_fp:.3} -> quantized {ppl_q:.3}");
    if let Some(out) = opts.get("out") {
        qmodel.weights.save(std::path::Path::new(out))?;
        println!("saved compressed weights to {out}");
    }
    Ok(())
}

fn cmd_eval(opts: &HashMap<String, String>) -> eac_moe::Result<()> {
    use eac_moe::data::tasks::zero_shot_suite;
    use eac_moe::model::hooks::Hooks;
    let zoo = model_key(opts);
    let (model, _) = load_or_init_model(zoo);
    let ctx = ExperimentContext::new(11, scale(opts));
    let alpha: f32 = opts.get("alpha").and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let n_items = ((24.0 * scale(opts)) as usize).max(4);
    let suite = zero_shot_suite(n_items, 13);
    println!("evaluating {} (alpha={alpha})", zoo.key());
    let ppl = if alpha > 0.0 {
        let cfg = eac_moe::prune::pesf::PesfConfig { alpha, ..Default::default() };
        let mcfg = model.cfg().clone();
        eac_moe::eval::ppl::perplexity_with_hooks(&model, &ctx.ppl_eval, || {
            let _ = &cfg;
            Hooks::none()
        });
        // PESF PPL path: use pesf_prefill per sequence.
        let mut nll = 0f64;
        let mut cnt = 0usize;
        let mut scratch = vec![0f32; mcfg.vocab];
        for seq in &ctx.ppl_eval {
            let (logits, _) = eac_moe::prune::pesf::pesf_prefill(&model, seq, cfg);
            for t in 0..seq.len() - 1 {
                eac_moe::tensor::ops::log_softmax_into(logits.row(t), &mut scratch);
                nll -= scratch[seq[t + 1] as usize] as f64;
                cnt += 1;
            }
        }
        (nll / cnt as f64).exp()
    } else {
        eac_moe::eval::perplexity(&model, &ctx.ppl_eval)
    };
    println!("ppl: {ppl:.3}");
    let hooks_factory = || Hooks::none();
    let res = eac_moe::eval::eval_suite(&model, &suite, hooks_factory);
    let mut table = eac_moe::report::Table::new("zero-shot", &["task", "acc%", "secs"]);
    for t in &res.tasks {
        table.row(vec![t.name.clone(), format!("{:.2}", t.accuracy), format!("{:.2}", t.wall_secs)]);
    }
    table.row(vec!["MEAN".into(), format!("{:.2}", res.mean_accuracy()), format!("{:.2}", res.total_secs())]);
    table.print();
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> eac_moe::Result<()> {
    use eac_moe::serve::{workload, Engine, EngineConfig, LenDist, PrunePolicy, Request, WorkloadSpec};
    let zoo = model_key(opts);
    let (model, _) = load_or_init_model(zoo);
    // `--pesf-alpha` is the canonical spelling; `--alpha` stays as an
    // alias for older scripts.
    let alpha: f32 = opts
        .get("pesf-alpha")
        .or_else(|| opts.get("alpha"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0);
    let defaults = eac_moe::prune::pesf::PesfConfig::default();
    // Decode-time PESF knobs: refresh cadence (0 freezes the mask at
    // prompt statistics) and rolling-window length (Eq. 6's online `l`).
    let refresh_every: usize =
        opts.get("pesf-refresh").and_then(|s| s.parse().ok()).unwrap_or(defaults.refresh_every);
    let window: usize =
        opts.get("pesf-window").and_then(|s| s.parse().ok()).unwrap_or(defaults.window);
    if window == 0 {
        // A 0-token window would degenerate every refresh to single-token
        // statistics (near-total pruning); there is no "windowing off"
        // sentinel — use --pesf-refresh 0 to freeze the prompt mask.
        anyhow::bail!("--pesf-window must be >= 1 (use --pesf-refresh 0 to freeze the mask)");
    }
    let n: u64 = opts.get("requests").and_then(|s| s.parse().ok()).unwrap_or(16);
    let len: usize = opts.get("len").and_then(|s| s.parse().ok()).unwrap_or(128);
    let decode: usize = opts.get("decode").and_then(|s| s.parse().ok()).unwrap_or(0);
    let workers: usize = opts.get("workers").and_then(|s| s.parse().ok()).unwrap_or(2);
    // Compute-pool size: --threads=N builds a dedicated pool; unset keeps
    // the global pool (EAC_MOE_THREADS or machine parallelism).
    let threads: Option<usize> = opts.get("threads").and_then(|s| s.parse().ok());
    // KV-cache precision: 32 (f32, bit-identical serving) or 8 (int8 per
    // head per position, ~4x smaller decode caches).
    let kv_bits: u8 = match opts.get("kv-bits").map(|s| s.as_str()) {
        None | Some("32") => 32,
        Some("8") => 8,
        Some(other) => anyhow::bail!("--kv-bits must be 32 or 8 (got {other})"),
    };
    // Memory tiering: --expert-budget-mb=B spills the routed experts to a
    // checkpoint and serves them through the tiered ExpertStore under a
    // hard B-MB cache budget (selection-frequency-weighted LRU eviction;
    // outputs are bit-identical to unbudgeted serving at any budget).
    let model = if let Some(mb) = opts.get("expert-budget-mb") {
        let mb: f64 = mb
            .parse()
            .map_err(|_| anyhow::anyhow!("--expert-budget-mb must be a number (MB)"))?;
        anyhow::ensure!(mb > 0.0, "--expert-budget-mb must be positive");
        let budget = (mb * 1e6) as usize;
        let spill = std::env::temp_dir()
            .join(format!("eac_moe_spill_{}_{}.bin", zoo.key(), std::process::id()));
        // Routed experts are what the budget manages; shared experts stay
        // pinned resident outside it.
        let total = model.weights.routed_expert_bytes() as f64 / 1e6;
        let model = model.into_tiered(budget, &spill)?;
        // Eager unlink (works while-open on unix) so even an aborted run
        // leaves nothing behind; the store also removes its own spill on
        // drop, which covers platforms where this call fails.
        let _ = std::fs::remove_file(&spill);
        println!("expert store: tiered, budget {mb:.2} MB of {total:.2} MB routed experts");
        model
    } else {
        model
    };
    let prune = if alpha > 0.0 {
        PrunePolicy::Pesf(eac_moe::prune::pesf::PesfConfig { alpha, refresh_every, window })
    } else {
        PrunePolicy::None
    };
    // Chunked prefill: interleave prompt prefill in C-token chunks with
    // decode steps (bit-identical outputs; see serve::engine docs).
    let prefill_chunk: usize = opts.get("prefill-chunk").and_then(|s| s.parse().ok()).unwrap_or(0);
    let cfg =
        EngineConfig { workers, prune, threads, kv_bits, prefill_chunk, ..Default::default() };
    let vocab = model.cfg().vocab;
    let engine = Engine::new(model, cfg);
    // Open-loop workload mode: Poisson arrivals (or an explicit JSON
    // trace) through serve_timed, reporting tail TTFT/ITL under load.
    if let Some(mode) = opts.get("workload") {
        let arrivals = match mode.as_str() {
            "poisson" | "true" => {
                let rate: f64 = opts.get("rate").and_then(|s| s.parse().ok()).unwrap_or(50.0);
                let tenants: u32 = opts.get("tenants").and_then(|s| s.parse().ok()).unwrap_or(1);
                let seed: u64 = opts.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
                let deadline_budget = opts
                    .get("deadline-ms")
                    .and_then(|s| s.parse::<f64>().ok())
                    .filter(|ms| ms.is_finite() && *ms > 0.0)
                    .map(|ms| std::time::Duration::from_secs_f64((ms / 1e3).min(1e6)));
                let spec = WorkloadSpec {
                    n_requests: n as usize,
                    rate_per_sec: rate,
                    // Bimodal short/long mix around --len: the chunked-
                    // prefill stressor (short requests queue behind the
                    // occasional long prompt).
                    prompt_len: LenDist::Bimodal {
                        short: (len / 4).max(4),
                        long: len.max(8),
                        p_short: 0.75,
                    },
                    decode_len: LenDist::Fixed(decode),
                    tenants,
                    vocab,
                    seed,
                    deadline_budget,
                };
                workload::generate(&spec)
            }
            path => workload::load_trace(std::path::Path::new(path))?,
        };
        println!(
            "open-loop workload: {} arrivals over {:.2}s on {} (chunk={prefill_chunk}, workers={workers})",
            arrivals.len(),
            arrivals.last().map(|t| t.at_secs).unwrap_or(0.0),
            zoo.key()
        );
        let (_resps, metrics) = engine.serve_timed(arrivals);
        println!("{}", metrics.summary());
        return Ok(());
    }
    let mut mix = eac_moe::data::corpus::WikiMixture::new(21);
    let reqs: Vec<Request> =
        (0..n).map(|i| Request::new(i, mix.sequence(len)).with_decode(decode)).collect();
    println!(
        "serving {n} requests of len {len} (+{decode} decode) on {} (alpha={alpha}, workers={workers})",
        zoo.key()
    );
    let (_resps, metrics) = engine.serve(reqs);
    println!("{}", metrics.summary());
    Ok(())
}

/// `analyze --expert-sim`: per-layer expert weight-similarity /
/// utilization / pseudo-MoE analysis — the measurement side of the
/// expert-merging axis (`prune::merge` consumes the thresholds this
/// surfaces). Emits `results/analyze_expert_sim.json`.
fn cmd_analyze(opts: &HashMap<String, String>) -> eac_moe::Result<()> {
    use eac_moe::data::corpus::DATASETS;
    if !opts.contains_key("expert-sim") {
        anyhow::bail!("analyze requires a mode flag: `analyze --expert-sim` (see --help)");
    }
    let zoo = model_key(opts);
    let (model, pretrained) = load_or_init_model(zoo);
    if !pretrained {
        eprintln!(
            "warning: random-init experts are near-orthogonal; similarity \
             structure only appears on pretrained weights"
        );
    }
    let spec = match opts.get("dataset") {
        None => &DATASETS[0],
        Some(name) => DATASETS.iter().find(|d| d.name == name.as_str()).ok_or_else(|| {
            let known: Vec<&str> = DATASETS.iter().map(|d| d.name).collect();
            anyhow::anyhow!("unknown dataset '{name}' (one of: {})", known.join("|"))
        })?,
    };
    let s = scale(opts);
    let n_seqs = ((6.0 * s) as usize).max(2);
    let rep = eac_moe::eval::analyze_expert_sim(&model, spec, n_seqs, 96, 17);
    let mut table = eac_moe::report::Table::new(
        &format!("expert similarity — {} on {}", zoo.key(), spec.name),
        &["layer", "experts", "mean sim", "max sim", "pairs>=0.9", "pairs>=0.7", "rank", "pseudo"],
    );
    for l in &rep.layers {
        table.row(vec![
            format!("{}", l.layer),
            format!("{}", l.n_experts),
            format!("{:.3}", l.mean_offdiag),
            format!("{:.3}", l.max_offdiag),
            format!("{}", l.mergeable_at_090),
            format!("{}", l.mergeable_at_070),
            format!("{}", l.router_rank),
            if l.pseudo_moe { "yes".into() } else { "no".into() },
        ]);
    }
    table.print();
    println!(
        "model verdict: {} (majority of layers {} like a pseudo-MoE)",
        if rep.pseudo_moe { "PSEUDO-MoE" } else { "native MoE" },
        if rep.pseudo_moe { "route" } else { "do not route" },
    );
    eac_moe::report::save_result("analyze_expert_sim", &rep.to_json())?;
    Ok(())
}

fn cmd_analyze_es(opts: &HashMap<String, String>) -> eac_moe::Result<()> {
    use eac_moe::data::corpus::DATASETS;
    use eac_moe::eval::es_analysis::*;
    let zoo = model_key(opts);
    let (model, pretrained) = load_or_init_model(zoo);
    if !pretrained {
        eprintln!("warning: ES analysis on random-init weights shows no task structure");
    }
    let s = scale(opts);
    let n_seqs = ((6.0 * s) as usize).max(2);
    let profiles: Vec<EsProfile> =
        DATASETS.iter().map(|d| es_frequencies(&model, d, n_seqs, 96, 17)).collect();
    let sim = es_similarity_matrix(&profiles);
    let (intra, inter) = intra_inter_summary(&profiles, &sim);
    println!("ES similarity on {} ({} datasets):", zoo.key(), profiles.len());
    println!("  intra-family mean cosine: {intra:.3}");
    println!("  inter-family mean cosine: {inter:.3}");
    let mut table = eac_moe::report::Table::new(
        "pairwise cosine (first 8 datasets)",
        &["dataset", "w.grande", "piqa", "arc-c", "boolq", "hswag", "s-iqa", "obqa", "gsm8k"],
    );
    for i in 0..8.min(profiles.len()) {
        let mut row = vec![profiles[i].dataset.clone()];
        for j in 0..8.min(profiles.len()) {
            row.push(format!("{:.2}", sim[i][j]));
        }
        table.row(row);
    }
    table.print();
    Ok(())
}
