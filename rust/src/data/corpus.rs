//! Synthetic task-typed token corpora.
//!
//! The paper's §3.3 analysis spans 19 datasets in four task families
//! (QA/CR, Math, Code, French). We reproduce the *statistical structure*
//! that analysis depends on: each task family owns a distinct region of the
//! token space plus family-specific bigram dynamics, so a trained MoE
//! router develops family-specific expert preferences; datasets within a
//! family are near-identical distributions with different seeds/mixtures,
//! so intra-family expert-selection similarity is high and inter-family
//! similarity low (Fig 2).
//!
//! The generator is a seeded mixture of Markov chains over a 512-token
//! vocabulary:
//!
//! * tokens [0, 64)    — shared "function words" used by every family;
//! * tokens [64+112*f, 64+112*(f+1)) — family f's content region;
//! * each dataset d in family f uses a dataset-specific transition matrix
//!   drawn from the family prior (seeded by (f, d)).
//!
//! The same construction (same constants, same PCG64 streams) is
//! implemented in `python/compile/datagen.py`; `tests/` cross-checks via
//! golden token dumps in `artifacts/data/` when present.

use crate::tensor::Pcg64;

/// The four task families of §3.3 / Fig 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    QaCr,
    Math,
    Code,
    French,
}

impl TaskFamily {
    pub const ALL: [TaskFamily; 4] =
        [TaskFamily::QaCr, TaskFamily::Math, TaskFamily::Code, TaskFamily::French];

    pub fn index(&self) -> usize {
        match self {
            TaskFamily::QaCr => 0,
            TaskFamily::Math => 1,
            TaskFamily::Code => 2,
            TaskFamily::French => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::QaCr => "QA/CR",
            TaskFamily::Math => "Math",
            TaskFamily::Code => "Code",
            TaskFamily::French => "French",
        }
    }
}

/// One synthetic dataset: a named stream source in a family.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub family: TaskFamily,
    /// Dataset id within the family (selects the transition-matrix draw).
    pub variant: u64,
}

/// The 19 datasets of §3.3 (names mirror the paper's Appendix A.13) plus
/// the balanced "wiki" mixture used for calibration and PPL.
pub const DATASETS: &[DatasetSpec] = &[
    // QA / Commonsense-Reasoning (7)
    DatasetSpec { name: "winogrande", family: TaskFamily::QaCr, variant: 0 },
    DatasetSpec { name: "piqa", family: TaskFamily::QaCr, variant: 1 },
    DatasetSpec { name: "arc-challenge", family: TaskFamily::QaCr, variant: 2 },
    DatasetSpec { name: "boolq", family: TaskFamily::QaCr, variant: 3 },
    DatasetSpec { name: "hellaswag", family: TaskFamily::QaCr, variant: 4 },
    DatasetSpec { name: "social-iqa", family: TaskFamily::QaCr, variant: 5 },
    DatasetSpec { name: "openbookqa", family: TaskFamily::QaCr, variant: 6 },
    // Math (4)
    DatasetSpec { name: "gsm8k", family: TaskFamily::Math, variant: 0 },
    DatasetSpec { name: "mathqa", family: TaskFamily::Math, variant: 1 },
    DatasetSpec { name: "minerva-math", family: TaskFamily::Math, variant: 2 },
    DatasetSpec { name: "hendrycks-math", family: TaskFamily::Math, variant: 3 },
    // Code (4)
    DatasetSpec { name: "humaneval", family: TaskFamily::Code, variant: 0 },
    DatasetSpec { name: "mbpp", family: TaskFamily::Code, variant: 1 },
    DatasetSpec { name: "apps", family: TaskFamily::Code, variant: 2 },
    DatasetSpec { name: "conala", family: TaskFamily::Code, variant: 3 },
    // French (4)
    DatasetSpec { name: "lambada-fr", family: TaskFamily::French, variant: 0 },
    DatasetSpec { name: "xnli-fr", family: TaskFamily::French, variant: 1 },
    DatasetSpec { name: "paws-fr", family: TaskFamily::French, variant: 2 },
    DatasetSpec { name: "arc-fr", family: TaskFamily::French, variant: 3 },
];

pub const VOCAB: usize = 512;
pub const SHARED_TOKENS: usize = 64;
pub const FAMILY_SPAN: usize = 112;
/// Number of latent "topic" states per dataset chain.
const N_STATES: usize = 12;
/// Probability of emitting from the shared region.
const P_SHARED: f64 = 0.25;

/// Find a dataset by name.
pub fn dataset(name: &str) -> Option<&'static DatasetSpec> {
    DATASETS.iter().find(|d| d.name == name)
}

/// Seeded generator for one dataset's token stream.
pub struct CorpusGen {
    rng: Pcg64,
    /// Per-state emission center in the family region.
    centers: Vec<usize>,
    /// State transition matrix (N_STATES x N_STATES), row-stochastic.
    trans: Vec<f32>,
    state: usize,
    family_base: usize,
}

impl CorpusGen {
    /// Build the generator for (family, variant) with a reproducible seed.
    pub fn new(spec: &DatasetSpec, seed: u64) -> Self {
        let f = spec.family.index() as u64;
        // Family prior stream: shared across the family's datasets.
        let mut family_rng = Pcg64::new(9000 + f, 1);
        // Family-level state centers: datasets in a family share most
        // centers (high intra-family similarity) ...
        let family_base = SHARED_TOKENS + spec.family.index() * FAMILY_SPAN;
        let mut centers: Vec<usize> =
            (0..N_STATES).map(|_| family_rng.below_usize(FAMILY_SPAN)).collect();
        debug_assert!(centers.len() == N_STATES);
        // ... with a small dataset-specific twist (2 of 12 states move).
        let mut ds_rng = Pcg64::new(9100 + f * 97 + spec.variant, 2);
        for _ in 0..2 {
            let i = ds_rng.below_usize(N_STATES);
            centers[i] = ds_rng.below_usize(FAMILY_SPAN);
        }
        // Transition matrix: family prior + dataset noise.
        let mut trans = vec![0f32; N_STATES * N_STATES];
        for i in 0..N_STATES {
            let mut row_sum = 0f32;
            for j in 0..N_STATES {
                let base = family_rng.next_f32();
                let noise = 0.3 * ds_rng.next_f32();
                let sticky = if i == j { 1.5 } else { 0.0 };
                let v = (base + noise + sticky).max(1e-3);
                trans[i * N_STATES + j] = v;
                row_sum += v;
            }
            for j in 0..N_STATES {
                trans[i * N_STATES + j] /= row_sum;
            }
        }
        CorpusGen {
            rng: Pcg64::new(seed, 1000 + f * 31 + spec.variant),
            centers,
            trans,
            state: 0,
            family_base,
        }
    }

    /// Next token.
    pub fn next_token(&mut self) -> u32 {
        debug_assert!(
            self.state < N_STATES && self.trans.len() == N_STATES * N_STATES,
            "corpus chain state out of range"
        );
        // Transition.
        let row = &self.trans[self.state * N_STATES..(self.state + 1) * N_STATES];
        self.state = self.rng.sample_weighted(row);
        // Emit.
        if self.rng.next_f64() < P_SHARED {
            self.rng.below(SHARED_TOKENS as u64) as u32
        } else {
            let center = self.centers[self.state];
            // Emission: center + small jitter, wrapped within the family span.
            let jitter = self.rng.below(9) as i64 - 4;
            let pos = (center as i64 + jitter).rem_euclid(FAMILY_SPAN as i64) as usize;
            (self.family_base + pos) as u32
        }
    }

    /// Generate a sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.next_token()).collect()
    }

    /// Generate `n` sequences.
    pub fn sequences(&mut self, n: usize, len: usize) -> Vec<Vec<u32>> {
        (0..n).map(|_| self.sequence(len)).collect()
    }
}

/// The balanced "wiki" mixture: rotates through all 19 datasets —
/// the calibration / perplexity stream (WikiText2's role).
pub struct WikiMixture {
    gens: Vec<CorpusGen>,
    next: usize,
}

impl WikiMixture {
    pub fn new(seed: u64) -> Self {
        WikiMixture {
            gens: DATASETS.iter().map(|d| CorpusGen::new(d, seed)).collect(),
            next: 0,
        }
    }

    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        let i = self.next;
        self.next = (self.next + 1) % self.gens.len();
        self.gens[i].sequence(len)
    }

    pub fn sequences(&mut self, n: usize, len: usize) -> Vec<Vec<u32>> {
        (0..n).map(|_| self.sequence(len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn histogram(tokens: &[u32]) -> Vec<f32> {
        let mut h = vec![0f32; VOCAB];
        for &t in tokens {
            h[t as usize] += 1.0;
        }
        let total: f32 = h.iter().sum();
        h.iter().map(|x| x / total).collect()
    }

    #[test]
    fn tokens_in_vocab_and_region() {
        for spec in DATASETS {
            let mut g = CorpusGen::new(spec, 1);
            let seq = g.sequence(500);
            let lo = SHARED_TOKENS + spec.family.index() * FAMILY_SPAN;
            let hi = lo + FAMILY_SPAN;
            for &t in &seq {
                let t = t as usize;
                assert!(t < VOCAB);
                assert!(t < SHARED_TOKENS || (t >= lo && t < hi), "{}: token {t}", spec.name);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = dataset("gsm8k").unwrap();
        let a = CorpusGen::new(spec, 7).sequence(100);
        let b = CorpusGen::new(spec, 7).sequence(100);
        assert_eq!(a, b);
        let c = CorpusGen::new(spec, 8).sequence(100);
        assert_ne!(a, c);
    }

    #[test]
    fn intra_family_similarity_exceeds_inter() {
        // The Fig-2 premise at the token-distribution level.
        let sim = |a: &str, b: &str| {
            let ha = histogram(&CorpusGen::new(dataset(a).unwrap(), 3).sequence(4000));
            let hb = histogram(&CorpusGen::new(dataset(b).unwrap(), 4).sequence(4000));
            crate::tensor::ops::cosine(&ha, &hb)
        };
        let intra = sim("gsm8k", "mathqa");
        let inter = sim("gsm8k", "humaneval");
        assert!(intra > inter + 0.2, "intra={intra} inter={inter}");
        let intra2 = sim("piqa", "boolq");
        let inter2 = sim("piqa", "lambada-fr");
        assert!(intra2 > inter2 + 0.2, "intra={intra2} inter={inter2}");
    }

    #[test]
    fn wiki_mixture_covers_all_families() {
        let mut w = WikiMixture::new(5);
        let seqs = w.sequences(19, 64);
        let mut seen = [false; 4];
        for s in &seqs {
            for &t in s {
                if (t as usize) >= SHARED_TOKENS {
                    seen[(t as usize - SHARED_TOKENS) / FAMILY_SPAN] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn dataset_lookup() {
        assert!(dataset("hellaswag").is_some());
        assert!(dataset("nope").is_none());
        assert_eq!(DATASETS.len(), 19);
    }
}
