//! Synthetic task-typed corpora (DESIGN.md §2 substitution for the paper's
//! 19 evaluation datasets) and dataset IO shared with the Python pretrainer.

pub mod corpus;
pub mod tasks;

pub use corpus::{CorpusGen, TaskFamily, DATASETS};
pub use tasks::{ZeroShotTask, zero_shot_suite};
