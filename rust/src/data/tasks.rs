//! Synthetic zero-shot task suite — the LM-Harness substitution
//! (DESIGN.md §2): multiple-choice items scored by LM likelihood, exactly
//! the harness protocol (accuracy = argmax over per-choice log-likelihood).
//!
//! Each item is a context drawn from one dataset's generator, a *correct*
//! continuation produced by continuing the same chain, and distractors
//! drawn from other datasets (same family → hard negatives; other family →
//! easy negatives). A model that has learned the corpus statistics scores
//! well above chance; compression that damages the experts a task family
//! relies on damages that task's accuracy — the degradation signal every
//! accuracy table in the paper measures.

use super::corpus::{dataset, CorpusGen, DatasetSpec, TaskFamily, DATASETS};

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub context: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub correct: usize,
}

/// A named task: a bag of items plus its family attribution.
#[derive(Clone, Debug)]
pub struct ZeroShotTask {
    pub name: &'static str,
    pub family: TaskFamily,
    pub items: Vec<TaskItem>,
}

impl ZeroShotTask {
    pub fn chance_accuracy(&self) -> f32 {
        if self.items.is_empty() {
            return 0.0;
        }
        let k: usize = self.items.iter().map(|i| i.choices.len()).sum();
        self.items.len() as f32 / k as f32
    }
}

/// Build one task over a primary dataset.
fn build_task(
    name: &'static str,
    primary: &DatasetSpec,
    n_items: usize,
    ctx_len: usize,
    cont_len: usize,
    seed: u64,
) -> ZeroShotTask {
    let mut items = Vec::with_capacity(n_items);
    // Distractors: one continuation from each *other* family. A model that
    // has learned the corpus assigns the in-family continuation a far
    // higher likelihood — and that judgement routes through the family's
    // experts, so compression damage to those experts damages exactly this
    // task (the paper's task/expert coupling). Within-family negatives are
    // statistically near-ties by construction (the emission distributions
    // overlap), so they carry no usable signal and are not used.
    let foreign: Vec<&DatasetSpec> = TaskFamily::ALL
        .iter()
        .filter(|f| **f != primary.family)
        .filter_map(|f| DATASETS.iter().find(|d| d.family == *f))
        .collect();
    debug_assert!(
        foreign.len() == TaskFamily::ALL.len() - 1,
        "every task family must have at least one dataset"
    );
    for i in 0..n_items {
        let mut g = CorpusGen::new(primary, seed * 1000 + i as u64);
        let context = g.sequence(ctx_len);
        let correct_cont = g.sequence(cont_len); // same chain state: in-distribution
        let mut choices = vec![correct_cont];
        for (fi, spec) in foreign.iter().enumerate() {
            choices.push(
                CorpusGen::new(spec, seed * 2000 + i as u64 * 7 + fi as u64).sequence(cont_len),
            );
        }
        // Deterministically rotate the correct answer's position.
        let correct = i % choices.len();
        choices.swap(0, correct);
        items.push(TaskItem { context, choices, correct });
    }
    ZeroShotTask { name, family: primary.family, items }
}

/// Dataset lookup for the static suite tables: every name below is a
/// literal present in [`DATASETS`], so a miss is a programmer error —
/// debug-asserted, with the first dataset as the release-mode fallback.
fn d(n: &str) -> &'static DatasetSpec {
    debug_assert!(dataset(n).is_some(), "unknown dataset {n}");
    dataset(n).unwrap_or(&DATASETS[0])
}

/// The 8 zero-shot tasks of Table 2/3 (names mirror the paper's suite).
pub fn zero_shot_suite(n_items: usize, seed: u64) -> Vec<ZeroShotTask> {
    vec![
        build_task("winogrande", d("winogrande"), n_items, 24, 8, seed + 1),
        build_task("piqa", d("piqa"), n_items, 24, 8, seed + 2),
        build_task("arc-easy", d("arc-challenge"), n_items, 20, 6, seed + 3),
        build_task("arc-challenge", d("arc-challenge"), n_items, 28, 10, seed + 4),
        build_task("boolq", d("boolq"), n_items, 24, 8, seed + 5),
        build_task("hellaswag", d("hellaswag"), n_items, 24, 8, seed + 6),
        build_task("mathqa", d("mathqa"), n_items, 24, 8, seed + 7),
        build_task("mmlu", d("social-iqa"), n_items, 24, 8, seed + 8),
    ]
}

/// The "challenging tasks" of Appendix A.2: longer dependency chains,
/// content-token heavy (GSM8K / HumanEval roles).
pub fn challenging_suite(n_items: usize, seed: u64) -> Vec<ZeroShotTask> {
    vec![
        build_task("gsm8k", d("gsm8k"), n_items, 48, 16, seed + 11),
        build_task("humaneval", d("humaneval"), n_items, 48, 16, seed + 12),
    ]
}

/// Per-family probe tasks for the Table-9 overfitting experiment:
/// (hellaswag: QA/CR, mathqa: Math, lambada-fr: French, conala: Code).
pub fn table9_suite(n_items: usize, seed: u64) -> Vec<ZeroShotTask> {
    vec![
        build_task("hellaswag", d("hellaswag"), n_items, 24, 8, seed + 21),
        build_task("mathqa", d("mathqa"), n_items, 24, 8, seed + 22),
        build_task("lambada-fr", d("lambada-fr"), n_items, 24, 8, seed + 23),
        build_task("conala", d("conala"), n_items, 24, 8, seed + 24),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_shapes() {
        let suite = zero_shot_suite(5, 1);
        assert_eq!(suite.len(), 8);
        for t in &suite {
            assert_eq!(t.items.len(), 5);
            for item in &t.items {
                assert_eq!(item.choices.len(), 4);
                assert!(item.correct < 4);
                assert!(!item.context.is_empty());
            }
            assert!((t.chance_accuracy() - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic() {
        let a = zero_shot_suite(3, 9);
        let b = zero_shot_suite(3, 9);
        assert_eq!(a[0].items[0].context, b[0].items[0].context);
        assert_eq!(a[0].items[2].correct, b[0].items[2].correct);
    }

    #[test]
    fn correct_positions_rotate() {
        let suite = zero_shot_suite(8, 2);
        let positions: std::collections::BTreeSet<usize> =
            suite[0].items.iter().map(|i| i.correct).collect();
        assert!(positions.len() > 1, "correct answer position must vary");
    }

    #[test]
    fn challenging_items_are_longer() {
        let z = zero_shot_suite(2, 3);
        let c = challenging_suite(2, 3);
        assert!(c[0].items[0].context.len() > z[0].items[0].context.len());
    }
}
