//! EES — Efficient Experts Skipping (Lu et al., 2024), reproduced per the
//! paper's Appendix A.8:
//!
//! On a calibration set, record for every token the ratio between the
//! score of its *least*-contributing selected expert and its *most*-
//! contributing one; the pruning threshold is the **median** of these
//! ratios. At inference, when a token's least/most ratio falls below the
//! threshold, the least-contributing expert is dropped for that token.
//!
//! EES reduces input size for some experts rather than skipping experts
//! outright, which is why its measured speedup is modest (Table 3).

use crate::model::hooks::{Hooks, SelectionFilter, TokenSelection};
use crate::model::Model;

/// Calibrated EES pruner.
#[derive(Clone, Copy, Debug)]
pub struct EesPruner {
    /// Median least/most score ratio from calibration.
    pub threshold: f32,
}

impl EesPruner {
    /// Build the per-token selection filter for the forward pass.
    pub fn filter(&self) -> SelectionFilter {
        let threshold = self.threshold;
        Box::new(move |_layer, _token, _x, sel: &mut TokenSelection| {
            apply_ees(sel, threshold);
        })
    }
}

/// Drop the least-contributing expert if its ratio to the top expert is
/// below `threshold`. Selections are score-descending (see forward).
pub fn apply_ees(sel: &mut TokenSelection, threshold: f32) {
    if sel.experts.len() < 2 {
        return;
    }
    let top = sel.scores[0];
    let Some(&last) = sel.scores.last() else { return };
    if top > 0.0 && last / top < threshold {
        sel.experts.pop();
        sel.scores.pop();
    }
}

/// Record least/most score ratios over a calibration set and return their
/// median — EES's threshold calibration.
pub fn calibrate_ees_threshold(model: &Model, calib: &[Vec<u32>]) -> f32 {
    let n_layers = model.cfg().n_layers;
    let mut ratios: Vec<f32> = Vec::new();
    for seq in calib {
        let hooks = Hooks::recording(n_layers);
        model.forward_with_hooks(seq, &hooks);
        let rec = hooks.take_selections().unwrap_or_default();
        debug_assert!(!rec.layers.is_empty(), "recording hooks captured selections");
        for layer in &rec.layers {
            for sel in layer {
                if sel.scores.len() < 2 || sel.scores[0] <= 0.0 {
                    continue;
                }
                let Some(&last) = sel.scores.last() else { continue };
                ratios.push(last / sel.scores[0]);
            }
        }
    }
    median(&mut ratios)
}

pub(crate) fn median(xs: &mut [f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};

    fn sel(scores: Vec<f32>) -> TokenSelection {
        TokenSelection { experts: (0..scores.len() as u16).collect(), scores }
    }

    #[test]
    fn drops_only_below_threshold() {
        let mut s = sel(vec![0.6, 0.2]);
        apply_ees(&mut s, 0.5); // ratio 0.33 < 0.5 -> drop
        assert_eq!(s.experts.len(), 1);
        let mut s = sel(vec![0.5, 0.4]);
        apply_ees(&mut s, 0.5); // ratio 0.8 >= 0.5 -> keep
        assert_eq!(s.experts.len(), 2);
    }

    #[test]
    fn never_drops_the_last_expert() {
        let mut s = sel(vec![0.9]);
        apply_ees(&mut s, 0.99);
        assert_eq!(s.experts.len(), 1);
    }

    #[test]
    fn median_is_robust() {
        let mut xs = vec![0.9, 0.1, 0.5];
        assert_eq!(median(&mut xs), 0.5);
        assert_eq!(median(&mut []), 0.0);
    }

    #[test]
    fn calibration_and_inference_roundtrip() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 6,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        let model = Model::new(Weights::init(&cfg, 23));
        let calib: Vec<Vec<u32>> = vec![(0..24).map(|i| i % 32).collect()];
        let thr = calibrate_ees_threshold(&model, &calib);
        assert!(thr > 0.0 && thr <= 1.0, "threshold={thr}");
        // With the median threshold, roughly half the tokens drop an expert:
        // run a forward and count via diagnostics.
        let pruner = EesPruner { threshold: thr };
        let hooks = Hooks {
            selection_filter: Some(pruner.filter()),
            record_selections: Some(std::cell::RefCell::new(
                crate::model::hooks::SelectionRecord::with_layers(2),
            )),
            ..Default::default()
        };
        let out = model.forward_with_hooks(&calib[0], &hooks);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
