//! PESF — Pruning based on Expert-Selection Frequency (paper §5, Eq. 6).
//!
//! During prefill over a sequence of length `l`, with `N` experts per layer
//! and `K` selected per token, an expert selected `c` times is pruned when
//!
//! ```text
//! c < (l * K / N) * alpha          0 < alpha <= 1
//! ```
//!
//! i.e. when it is selected less often than `alpha` times the balanced
//! average. The decision is recomputed per sequence from the router's own
//! scores on that sequence (a single cheap counting pass — Appendix A.1
//! "PESF introduces only a single-step online computation").
//!
//! The serving integration runs the router for all layers first (cheap: the
//! router is <0.03% of parameters), derives the mask, then runs the MoE
//! layers with pruned experts skipped entirely — which is what converts the
//! pruning rate into wall-clock speedup.
//!
//! ## Decode-time PESF (extends the paper)
//!
//! The paper's Limitations section disables PESF during the generate stage:
//! its masks are frozen at prompt statistics, which drift as the
//! continuation grows. This reproduction *extends* PESF into decode, where
//! serving spends nearly all its wall-clock: each sequence carries its
//! prefill-derived mask into [`crate::model::Model::decode_step_batch`]
//! (via `Hooks::seq_expert_masks`), and [`PesfDecodeState`] keeps a
//! **rolling selection-frequency window** over the most recent `window`
//! tokens (prompt tail, then generated tokens as they arrive). Every
//! `refresh_every` decode tokens the mask is re-derived from the window by
//! the same Eq. 6 threshold — `l` is simply the window length, so Eq. 6 is
//! applied *online* instead of once at the prompt. `refresh_every = 0`
//! freezes the mask at prompt statistics. With `alpha = 0` every mask is
//! all-false and decode is bit-identical to the unpruned path (pinned by
//! `tests/integration_serving.rs`).
//!
//! CLI: `eac-moe serve --pesf-alpha A --pesf-refresh R --pesf-window W`.

use crate::model::hooks::{Hooks, SelectionRecord, SeqExpertMask};
use crate::model::Model;
use std::collections::VecDeque;
use std::sync::Arc;

/// PESF configuration.
#[derive(Clone, Copy, Debug)]
pub struct PesfConfig {
    /// Pruning threshold alpha in (0, 1]; 0 disables pruning.
    pub alpha: f32,
    /// Decode-time mask refresh cadence: re-derive the mask from the
    /// rolling window every this many generated tokens (0 = never refresh;
    /// the mask stays frozen at prompt statistics).
    pub refresh_every: usize,
    /// Rolling selection-frequency window length, in tokens — Eq. 6's `l`
    /// for the online decode-time refresh. Seeded with the prompt's last
    /// `window` tokens, then slides over generated tokens.
    pub window: usize,
}

impl Default for PesfConfig {
    fn default() -> Self {
        PesfConfig { alpha: 0.0, refresh_every: 16, window: 64 }
    }
}

impl PesfConfig {
    /// The paper's conservative sweet spot.
    pub fn conservative() -> Self {
        PesfConfig { alpha: 0.3, ..Default::default() }
    }

    /// The paper's aggressive sweet spot.
    pub fn aggressive() -> Self {
        PesfConfig { alpha: 0.7, ..Default::default() }
    }
}

/// Pruning statistics for reporting (Fig 7).
#[derive(Clone, Debug, Default)]
pub struct PesfStats {
    /// Per-layer number of pruned experts.
    pub pruned_per_layer: Vec<usize>,
    pub n_experts: usize,
}

impl PesfStats {
    /// Average fraction of experts pruned across layers.
    pub fn prune_rate(&self) -> f32 {
        if self.pruned_per_layer.is_empty() || self.n_experts == 0 {
            return 0.0;
        }
        let total: usize = self.pruned_per_layer.iter().sum();
        total as f32 / (self.pruned_per_layer.len() * self.n_experts) as f32
    }
}

/// Compute the PESF mask (layer × expert, true = prune) from a selection
/// record over one sequence. Eq. 6 with `l` = tokens recorded in the layer.
pub fn pesf_mask(
    record: &SelectionRecord,
    n_experts: usize,
    top_k: usize,
    cfg: PesfConfig,
) -> (Vec<Vec<bool>>, PesfStats) {
    let mut mask = Vec::with_capacity(record.layers.len());
    let mut stats =
        PesfStats { pruned_per_layer: Vec::with_capacity(record.layers.len()), n_experts };
    for li in 0..record.layers.len() {
        let counts = record.counts(li, n_experts);
        let l = record.n_tokens(li);
        let threshold = (l * top_k) as f32 / n_experts as f32 * cfg.alpha;
        let layer_mask: Vec<bool> =
            counts.iter().map(|&c| cfg.alpha > 0.0 && (c as f32) < threshold).collect();
        stats.pruned_per_layer.push(layer_mask.iter().filter(|&&m| m).count());
        mask.push(layer_mask);
    }
    (mask, stats)
}

/// PESF hooks for a single-pass pruned prefill: the mask is derived inside
/// each MoE layer (between routing and expert dispatch), so PESF costs one
/// counting pass and no extra forward (Appendix A.1).
pub fn pesf_hooks(n_layers: usize, cfg: PesfConfig) -> Hooks {
    Hooks {
        pesf_alpha: Some(cfg.alpha),
        pesf_pruned: Some(std::cell::RefCell::new(vec![0usize; n_layers])),
        ..Default::default()
    }
}

/// Run a PESF-pruned prefill (single pass). Returns (logits, stats).
pub fn pesf_prefill(
    model: &Model,
    tokens: &[u32],
    cfg: PesfConfig,
) -> (crate::tensor::Mat, PesfStats) {
    let mcfg = model.cfg();
    let hooks = pesf_hooks(mcfg.n_layers, cfg);
    let logits = model.forward_with_hooks(tokens, &hooks);
    let stats = PesfStats {
        pruned_per_layer: hooks.pesf_pruned.unwrap().into_inner(),
        n_experts: mcfg.n_experts,
    };
    (logits, stats)
}

/// Derive the PESF mask from router logits only (cheap pre-pass used by the
/// serving engine: one GEMM per layer on the *embedded* tokens rather than a
/// full forward; see DESIGN.md §Perf for the tradeoff).
///
/// `lens[li]` is the number of tokens recorded for layer `li` — Eq. 6's `l`
/// is per layer, exactly as [`pesf_mask`] computes it from a
/// [`SelectionRecord`]; a single global length silently disagrees with the
/// record-based mask whenever layers hold different token counts.
pub fn pesf_mask_from_counts(
    counts: &[Vec<u64>],
    lens: &[usize],
    n_experts: usize,
    top_k: usize,
    cfg: PesfConfig,
) -> (Vec<Vec<bool>>, PesfStats) {
    assert_eq!(counts.len(), lens.len(), "one token count per layer");
    let mut mask = Vec::with_capacity(counts.len());
    let mut stats = PesfStats { pruned_per_layer: Vec::new(), n_experts };
    for (layer_counts, &l) in counts.iter().zip(lens) {
        // Eq. 6's N is this layer's routed width — the counts row's own
        // length. Under expert merging layers can be narrower than the
        // config's n_experts; for unmerged layers the two are equal.
        let n = layer_counts.len().max(1);
        let threshold = (l * top_k) as f32 / n as f32 * cfg.alpha;
        let layer_mask: Vec<bool> = layer_counts
            .iter()
            .map(|&c| cfg.alpha > 0.0 && (c as f32) < threshold)
            .collect();
        stats.pruned_per_layer.push(layer_mask.iter().filter(|&&m| m).count());
        mask.push(layer_mask);
    }
    (mask, stats)
}

/// Online PESF state for one decoding sequence: the rolling
/// selection-frequency window that re-derives the `layer × expert` mask
/// every [`PesfConfig::refresh_every`] generated tokens (Eq. 6 with `l` =
/// window length). Built from the prefill's [`SelectionRecord`]; the
/// initial mask equals the mask the PESF prefill itself applied (same
/// per-layer counts, same per-layer `l`).
#[derive(Clone, Debug)]
pub struct PesfDecodeState {
    cfg: PesfConfig,
    /// Routed-expert width per layer ([`crate::model::LayerWeights::n_routed`]);
    /// uniform `n_experts` for unmerged models, narrower on merged layers.
    widths: Vec<usize>,
    top_k: usize,
    /// Most recent `cfg.window` tokens: each entry is one token's selected
    /// experts per layer (`entry[layer]`), prompt tail first.
    window: VecDeque<Vec<Vec<u16>>>,
    /// Running per-layer selection counts over `window`.
    counts: Vec<Vec<u64>>,
    /// Generated tokens observed since the last mask refresh.
    since_refresh: usize,
    mask: SeqExpertMask,
    prune_rate: f32,
}

impl PesfDecodeState {
    /// Seed the state from a prefill's routing record: initial mask from
    /// the *full* prompt (exactly what [`pesf_hooks`] pruned with), window
    /// from the prompt's last `cfg.window` tokens.
    pub fn from_prefill(
        record: &SelectionRecord,
        n_experts: usize,
        top_k: usize,
        cfg: PesfConfig,
    ) -> Self {
        Self::from_prefill_widths(record, &vec![n_experts; record.layers.len()], top_k, cfg)
    }

    /// Like [`Self::from_prefill`] but with a per-layer routed-expert
    /// width: under expert merging (`prune::merge`) a layer's routing —
    /// and therefore its PESF mask — is over the *merged* ids, so the
    /// engine passes `layers.map(n_routed)` instead of a uniform
    /// `cfg.n_experts`.
    pub fn from_prefill_widths(
        record: &SelectionRecord,
        widths: &[usize],
        top_k: usize,
        cfg: PesfConfig,
    ) -> Self {
        let n_layers = record.layers.len();
        assert_eq!(widths.len(), n_layers, "one routed width per layer");
        let counts: Vec<Vec<u64>> =
            (0..n_layers).map(|li| record.counts(li, widths[li])).collect();
        let lens: Vec<usize> = (0..n_layers).map(|li| record.n_tokens(li)).collect();
        let n_stat = widths.iter().copied().max().unwrap_or(0);
        let (mask, stats) = pesf_mask_from_counts(&counts, &lens, n_stat, top_k, cfg);
        let l = lens.iter().copied().min().unwrap_or(0);
        let start = l.saturating_sub(cfg.window.max(1));
        let mut window: VecDeque<Vec<Vec<u16>>> = VecDeque::with_capacity(l - start);
        for t in start..l {
            window.push_back(record.token_experts(t));
        }
        let mut wcounts: Vec<Vec<u64>> = widths.iter().map(|&n| vec![0u64; n]).collect();
        for tok in &window {
            for (li, experts) in tok.iter().enumerate() {
                for &e in experts {
                    wcounts[li][e as usize] += 1;
                }
            }
        }
        PesfDecodeState {
            cfg,
            widths: widths.to_vec(),
            top_k,
            window,
            counts: wcounts,
            since_refresh: 0,
            mask: Arc::new(mask),
            prune_rate: stats.prune_rate(),
        }
    }

    /// The mask currently in effect (cheap Arc clone; the engine hands it
    /// to `Hooks::seq_expert_masks` every decode step).
    pub fn mask(&self) -> SeqExpertMask {
        self.mask.clone()
    }

    /// Fraction of experts the current mask prunes (mean over layers).
    pub fn prune_rate(&self) -> f32 {
        self.prune_rate
    }

    /// Feed one generated token's routing (from the decode step's
    /// [`SelectionRecord`], layer-major as [`SelectionRecord::token_experts`]
    /// returns) into the window; refresh the mask when the cadence is due.
    pub fn observe(&mut self, token: Vec<Vec<u16>>) {
        for (li, experts) in token.iter().enumerate() {
            for &e in experts {
                self.counts[li][e as usize] += 1;
            }
        }
        self.window.push_back(token);
        while self.window.len() > self.cfg.window.max(1) {
            let Some(old) = self.window.pop_front() else { break };
            for (li, experts) in old.iter().enumerate() {
                for &e in experts {
                    self.counts[li][e as usize] -= 1;
                }
            }
        }
        self.since_refresh += 1;
        if self.cfg.refresh_every > 0 && self.since_refresh >= self.cfg.refresh_every {
            self.refresh();
            self.since_refresh = 0;
        }
    }

    /// Re-derive the mask from the window counts (Eq. 6, `l` = window len).
    fn refresh(&mut self) {
        let lens = vec![self.window.len(); self.counts.len()];
        let n_stat = self.widths.iter().copied().max().unwrap_or(0);
        let (mask, stats) =
            pesf_mask_from_counts(&self.counts, &lens, n_stat, self.top_k, self.cfg);
        self.mask = Arc::new(mask);
        self.prune_rate = stats.prune_rate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::hooks::TokenSelection;
    use crate::model::{ModelConfig, Weights};

    fn record_with_counts(counts: &[u64], top_k: usize) -> SelectionRecord {
        // Build a record whose per-expert counts equal `counts` by emitting
        // single-expert "tokens" padded to top_k with a dummy partner that we
        // count too; easier: emit tokens with exactly one expert each and
        // top_k=1 semantics. For top_k>1 tests we construct manually.
        let mut r = SelectionRecord::with_layers(1);
        for (e, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                r.layers[0].push(TokenSelection { experts: vec![e as u16], scores: vec![1.0] });
            }
        }
        let _ = top_k;
        r
    }

    #[test]
    fn eq6_threshold_exact() {
        // N=4, K=1, l=8 -> balanced count = 2. alpha=0.5 -> threshold 1.0:
        // prune experts with c < 1 (i.e. c == 0).
        let rec = record_with_counts(&[4, 2, 2, 0], 1);
        let (mask, stats) = pesf_mask(&rec, 4, 1, PesfConfig { alpha: 0.5, ..Default::default() });
        assert_eq!(mask[0], vec![false, false, false, true]);
        assert_eq!(stats.pruned_per_layer[0], 1);
        // alpha=1.0 -> threshold 2.0: prune c < 2 (only expert 3).
        let (mask, _) = pesf_mask(&rec, 4, 1, PesfConfig { alpha: 1.0, ..Default::default() });
        assert_eq!(mask[0], vec![false, false, false, true]);
        // skewed: c=[6,1,1,0], alpha=1.0 -> prune c<2: experts 1,2,3.
        let rec2 = record_with_counts(&[6, 1, 1, 0], 1);
        let (mask2, st2) = pesf_mask(&rec2, 4, 1, PesfConfig { alpha: 1.0, ..Default::default() });
        assert_eq!(mask2[0], vec![false, true, true, true]);
        assert!((st2.prune_rate() - 0.75).abs() < 1e-6);
    }

    #[test]
    fn alpha_zero_prunes_nothing() {
        let rec = record_with_counts(&[5, 0, 0, 0], 1);
        let (mask, stats) = pesf_mask(&rec, 4, 1, PesfConfig { alpha: 0.0, ..Default::default() });
        assert!(mask[0].iter().all(|&m| !m));
        assert_eq!(stats.prune_rate(), 0.0);
    }

    /// Property: pruning rate is monotone non-decreasing in alpha.
    #[test]
    fn prop_prune_rate_monotone_in_alpha() {
        let mut rng = crate::tensor::Pcg64::seeded(81);
        for _ in 0..10 {
            let n = 8;
            let counts: Vec<u64> = (0..n).map(|_| rng.below(20)).collect();
            let rec = record_with_counts(&counts, 1);
            let mut last = -1.0f32;
            for a in [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
                let (_, st) = pesf_mask(&rec, n, 1, PesfConfig { alpha: a, ..Default::default() });
                let rate = st.prune_rate();
                assert!(rate >= last, "alpha={a}: {rate} < {last} counts={counts:?}");
                last = rate;
            }
        }
    }

    #[test]
    fn pesf_prefill_end_to_end() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 8,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        let model = Model::new(Weights::init(&cfg, 17));
        let tokens: Vec<u32> = (0..32).map(|i| (i * 7) % 32).collect();
        let (logits, stats) = pesf_prefill(&model, &tokens, PesfConfig::aggressive());
        assert_eq!(logits.rows, 32);
        assert!(logits.data.iter().all(|x| x.is_finite()));
        // Some pruning should happen at alpha=0.7 on a random router.
        assert!(stats.prune_rate() >= 0.0);
        // alpha=0 reproduces the dense output exactly.
        let (l0, st0) = pesf_prefill(&model, &tokens, PesfConfig { alpha: 0.0, ..Default::default() });
        assert_eq!(st0.prune_rate(), 0.0);
        let dense = model.forward(&tokens);
        for (a, b) in l0.data.iter().zip(&dense.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn counts_variant_matches_record_variant() {
        let rec = record_with_counts(&[6, 1, 1, 0], 1);
        let counts = vec![rec.counts(0, 4)];
        let (m1, _) = pesf_mask(&rec, 4, 1, PesfConfig { alpha: 0.8, ..Default::default() });
        let (m2, _) =
            pesf_mask_from_counts(&counts, &[rec.n_tokens(0)], 4, 1, PesfConfig { alpha: 0.8, ..Default::default() });
        assert_eq!(m1, m2);
    }

    #[test]
    fn counts_variant_matches_record_variant_on_unequal_layer_lengths() {
        // Two layers with different token counts: layer 0 has 8 tokens,
        // layer 1 has 2. A single global `l` (the old signature) produced
        // the wrong threshold for one of them; per-layer lengths must
        // reproduce pesf_mask exactly.
        let mut rec = SelectionRecord::with_layers(2);
        for (e, c) in [(0u16, 6u64), (1, 1), (2, 1), (3, 0)] {
            for _ in 0..c {
                rec.layers[0].push(TokenSelection { experts: vec![e], scores: vec![1.0] });
            }
        }
        for e in [0u16, 1] {
            rec.layers[1].push(TokenSelection { experts: vec![e], scores: vec![1.0] });
        }
        assert_ne!(rec.n_tokens(0), rec.n_tokens(1));
        let counts = vec![rec.counts(0, 4), rec.counts(1, 4)];
        let lens = vec![rec.n_tokens(0), rec.n_tokens(1)];
        for alpha in [0.3, 0.8, 1.0] {
            let (m1, s1) = pesf_mask(&rec, 4, 1, PesfConfig { alpha, ..Default::default() });
            let (m2, s2) = pesf_mask_from_counts(&counts, &lens, 4, 1, PesfConfig { alpha, ..Default::default() });
            assert_eq!(m1, m2, "alpha={alpha}");
            assert_eq!(s1.pruned_per_layer, s2.pruned_per_layer, "alpha={alpha}");
        }
        // Pin the disagreement the bug caused: layer 1's threshold with a
        // global l=8 would prune both its experts (c=1 < 0.8*2); with the
        // correct l=2 threshold (0.4) neither is pruned.
        let (m, _) = pesf_mask_from_counts(&counts, &lens, 4, 1, PesfConfig { alpha: 0.8, ..Default::default() });
        assert_eq!(m[1], vec![false, false, true, true]);
    }

    /// A record whose every token selects `expert` (top_k = 1).
    fn uniform_record(expert: u16, l: usize) -> SelectionRecord {
        let mut r = SelectionRecord::with_layers(1);
        for _ in 0..l {
            r.layers[0].push(TokenSelection { experts: vec![expert], scores: vec![1.0] });
        }
        r
    }

    #[test]
    fn decode_state_initial_mask_matches_prompt_mask() {
        let rec = record_with_counts(&[6, 1, 1, 0], 1);
        let cfg = PesfConfig { alpha: 0.8, refresh_every: 4, window: 8 };
        let st = PesfDecodeState::from_prefill(&rec, 4, 1, cfg);
        let (want, wstats) = pesf_mask(&rec, 4, 1, cfg);
        assert_eq!(*st.mask(), want);
        assert!((st.prune_rate() - wstats.prune_rate()).abs() < 1e-6);
    }

    /// Per-layer widths: a merged layer (width 2) thresholds over N=2, not
    /// the config's N=4, and the mask rows have the layer's own width.
    #[test]
    fn decode_state_prefill_widths_threshold_per_layer() {
        let mut rec = SelectionRecord::with_layers(2);
        // Layer 0 (unmerged, 4 experts): counts [3,1,0,0] over 4 tokens.
        for e in [0u16, 0, 0, 1] {
            rec.layers[0].push(TokenSelection { experts: vec![e], scores: vec![1.0] });
        }
        // Layer 1 (merged, 2 experts): counts [3,1] over the same tokens.
        for e in [0u16, 0, 0, 1] {
            rec.layers[1].push(TokenSelection { experts: vec![e], scores: vec![1.0] });
        }
        let cfg = PesfConfig { alpha: 1.0, refresh_every: 0, window: 8 };
        let st = PesfDecodeState::from_prefill_widths(&rec, &[4, 2], 1, cfg);
        let mask = st.mask();
        assert_eq!(mask.len(), 2);
        assert_eq!(mask[0].len(), 4);
        assert_eq!(mask[1].len(), 2);
        // Layer 0: threshold = 4*1/4 = 1 -> prune c<1 (experts 2,3).
        assert_eq!(mask[0], vec![false, false, true, true]);
        // Layer 1: threshold = 4*1/2 = 2 -> prune c<2 (merged expert 1).
        // With the old uniform-N divisor (N=4) the threshold would be 1
        // and nothing in layer 1 would be pruned.
        assert_eq!(mask[1], vec![false, true]);
        // Uniform widths delegate: identical to from_prefill.
        let a = PesfDecodeState::from_prefill_widths(&rec, &[4, 4], 1, cfg);
        let b = PesfDecodeState::from_prefill(&rec, 4, 1, cfg);
        assert_eq!(*a.mask(), *b.mask());
    }

    #[test]
    fn decode_state_refreshes_only_at_cadence_and_tracks_drift() {
        // Prompt: every token routes to expert 0 -> experts 1..3 pruned.
        let cfg = PesfConfig { alpha: 1.0, refresh_every: 4, window: 4 };
        let st0 = PesfDecodeState::from_prefill(&uniform_record(0, 8), 4, 1, cfg);
        assert_eq!(*st0.mask(), vec![vec![false, true, true, true]]);
        // Decode drifts entirely to expert 2. Before `refresh_every`
        // observations the mask must stay frozen at prompt statistics...
        let mut st = st0.clone();
        for i in 0..3 {
            st.observe(vec![vec![2]]);
            assert_eq!(*st.mask(), *st0.mask(), "mask refreshed early at token {i}");
        }
        // ...and the 4th observation refreshes it. By then the window
        // (len 4) has slid entirely onto decode tokens: counts are
        // [0, 0, 4, 0], threshold = 4*1/4*1.0 = 1, so expert 2 is revived
        // and the prompt-hot expert 0 is now pruned along with 1 and 3.
        st.observe(vec![vec![2]]);
        assert_eq!(*st.mask(), vec![vec![true, true, false, true]]);
        assert!((st.prune_rate() - 0.75).abs() < 1e-6);
    }

    #[test]
    fn decode_state_refresh_zero_freezes_mask() {
        let cfg = PesfConfig { alpha: 1.0, refresh_every: 0, window: 4 };
        let mut st = PesfDecodeState::from_prefill(&uniform_record(0, 8), 4, 1, cfg);
        let frozen = st.mask();
        for _ in 0..12 {
            st.observe(vec![vec![2]]);
        }
        assert_eq!(*st.mask(), *frozen, "refresh_every=0 must freeze the prompt mask");
    }

    #[test]
    fn decode_state_alpha_zero_mask_stays_open() {
        let cfg = PesfConfig { alpha: 0.0, refresh_every: 1, window: 2 };
        let mut st = PesfDecodeState::from_prefill(&uniform_record(0, 6), 4, 1, cfg);
        for _ in 0..5 {
            st.observe(vec![vec![3]]);
            assert!(st.mask().iter().all(|l| l.iter().all(|&m| !m)));
            assert_eq!(st.prune_rate(), 0.0);
        }
    }
}
