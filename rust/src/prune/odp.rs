//! ODP — dynamic pruning with critical-token protection (the MC-MoE /
//! Huang et al. 2024a baseline, reproduced per the paper's Appendix A.8).
//!
//! ODP extends EES: the same median-ratio skip rule, plus a
//! *significance-aware token protection* mechanism that identifies critical
//! tokens and refuses to prune their experts even when the ratio condition
//! holds. Token significance here is the L2 norm of the token's MoE-layer
//! input (the activation magnitude heuristic MC-MoE derives its protection
//! from); tokens above the calibrated `protect_quantile` norm are protected.

use super::ees::{apply_ees, median};
use crate::model::hooks::{Hooks, SelectionFilter, TokenSelection};
use crate::model::Model;

/// Calibrated ODP pruner.
#[derive(Clone, Copy, Debug)]
pub struct OdpPruner {
    /// EES median score-ratio threshold.
    pub ratio_threshold: f32,
    /// Tokens with MoE-input norm above this are protected.
    pub norm_threshold: f32,
}

impl OdpPruner {
    /// Calibrate both thresholds on a calibration set. `protect_quantile`
    /// is the fraction of tokens NOT protected (e.g. 0.8 protects the top
    /// 20% most significant tokens).
    pub fn calibrate(model: &Model, calib: &[Vec<u32>], protect_quantile: f32) -> Self {
        let n_layers = model.cfg().n_layers;
        let mut ratios: Vec<f32> = Vec::new();
        let mut norms: Vec<f32> = Vec::new();
        for seq in calib {
            let hooks = Hooks {
                record_selections: Some(std::cell::RefCell::new(
                    crate::model::hooks::SelectionRecord::with_layers(n_layers),
                )),
                capture_moe_inputs: Some(std::cell::RefCell::new(vec![None; n_layers])),
                ..Default::default()
            };
            model.forward_with_hooks(seq, &hooks);
            // Both cells were installed on the hooks literal just above.
            debug_assert!(
                hooks.record_selections.is_some() && hooks.capture_moe_inputs.is_some(),
                "hooks installed above"
            );
            let Some(rec_cell) = hooks.record_selections else { continue };
            let rec = rec_cell.into_inner();
            for layer in &rec.layers {
                for sel in layer {
                    if sel.scores.len() < 2 || sel.scores[0] <= 0.0 {
                        continue;
                    }
                    let Some(&last) = sel.scores.last() else { continue };
                    ratios.push(last / sel.scores[0]);
                }
            }
            let Some(cap_cell) = hooks.capture_moe_inputs else { continue };
            let caps = cap_cell.into_inner();
            for cap in caps.into_iter().flatten() {
                for t in 0..cap.rows {
                    let n = cap.row(t).iter().map(|x| x * x).sum::<f32>().sqrt();
                    norms.push(n);
                }
            }
        }
        let ratio_threshold = median(&mut ratios);
        norms.sort_by(|a, b| a.total_cmp(b));
        let idx = ((protect_quantile * norms.len() as f32) as usize).min(norms.len().saturating_sub(1));
        let norm_threshold = if norms.is_empty() { f32::INFINITY } else { norms[idx] };
        OdpPruner { ratio_threshold, norm_threshold }
    }

    /// Per-token selection filter: EES skip unless the token is critical.
    pub fn filter(&self) -> SelectionFilter {
        let rt = self.ratio_threshold;
        let nt = self.norm_threshold;
        Box::new(move |_layer, _token, x: &[f32], sel: &mut TokenSelection| {
            let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            if norm > nt {
                return; // critical token: protected
            }
            apply_ees(sel, rt);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};

    #[test]
    fn protection_blocks_pruning() {
        let pruner = OdpPruner { ratio_threshold: 0.9, norm_threshold: 1.0 };
        let f = pruner.filter();
        // Low-norm token: pruned (ratio 0.2/0.8 = 0.25 < 0.9).
        let mut sel = TokenSelection { experts: vec![0, 1], scores: vec![0.8, 0.2] };
        f(0, 0, &[0.1, 0.1], &mut sel);
        assert_eq!(sel.experts.len(), 1);
        // High-norm token: protected.
        let mut sel = TokenSelection { experts: vec![0, 1], scores: vec![0.8, 0.2] };
        f(0, 0, &[5.0, 5.0], &mut sel);
        assert_eq!(sel.experts.len(), 2);
    }

    #[test]
    fn calibration_produces_sane_thresholds() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 6,
            top_k: 2,
            n_shared: 0,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        };
        let model = Model::new(Weights::init(&cfg, 29));
        let calib: Vec<Vec<u32>> = vec![(0..20).map(|i| (3 * i) % 32).collect()];
        let p = OdpPruner::calibrate(&model, &calib, 0.8);
        assert!(p.ratio_threshold > 0.0 && p.ratio_threshold <= 1.0);
        assert!(p.norm_threshold.is_finite() && p.norm_threshold > 0.0);
        // ODP prunes strictly less than plain EES at the same threshold.
        let ees_filter = crate::prune::ees::EesPruner { threshold: p.ratio_threshold }.filter();
        let odp_filter = p.filter();
        let mut rng = crate::tensor::Pcg64::seeded(91);
        let mut ees_dropped = 0;
        let mut odp_dropped = 0;
        for _ in 0..200 {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
            let s0 = 0.5 + rng.next_f32() * 0.4;
            let s1 = s0 * rng.next_f32();
            let mk = || TokenSelection { experts: vec![0, 1], scores: vec![s0, s1] };
            let mut a = mk();
            ees_filter(0, 0, &x, &mut a);
            let mut b = mk();
            odp_filter(0, 0, &x, &mut b);
            ees_dropped += (a.experts.len() == 1) as usize;
            odp_dropped += (b.experts.len() == 1) as usize;
            assert!(b.experts.len() >= a.experts.len());
        }
        assert!(odp_dropped <= ees_dropped);
    }
}
