//! Dynamic expert pruning (paper §5) and the baselines of Table 3.
//!
//! * [`pesf`] — the paper's contribution: per-sequence frequency pruning
//!   (Eq. 6) applied during prefill, and extended online into batched
//!   decode via a rolling selection-frequency window
//!   ([`pesf::PesfDecodeState`]).
//! * [`ees`] — Efficient Experts Skipping (Lu et al., 2024): per-token,
//!   drop the least-contributing selected expert when its score ratio to
//!   the top expert falls under a calibrated median threshold.
//! * [`odp`] — Online Dynamic Pruning (Huang et al., 2024a): EES plus a
//!   significance-aware critical-token protection mechanism.
//! * [`merge`] — static expert *merging* (the third compression axis):
//!   cluster pairwise-similar experts, collapse each cluster into a
//!   frequency-weighted base plus low-rank per-member deltas, and remap
//!   the router onto the reduced expert set.

pub mod ees;
pub mod merge;
pub mod odp;
pub mod pesf;

pub use ees::{calibrate_ees_threshold, EesPruner};
pub use merge::{
    merge_experts, synthesize_mergeable_pairs, uniform_frequencies, MergeConfig, MergeReport,
};
pub use odp::OdpPruner;
pub use pesf::{pesf_mask, PesfConfig, PesfDecodeState, PesfStats};
