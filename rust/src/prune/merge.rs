//! Expert merging — the third compression axis, alongside QESC (bytes per
//! expert) and PESF (experts per task): permanently reduce the *expert
//! count* by clustering pairwise-similar experts and collapsing each
//! cluster into one base expert plus optional per-member low-rank deltas.
//!
//! MC# (arXiv 2510.10962) and the chuk-mlx exemplar (SNIPPETS.md §2–3)
//! observe that many checkpoints carry experts that are >70%
//! pairwise-similar in weight space — merging them loses little quality
//! while cutting expert bytes and routing width at once. The transform
//! here:
//!
//! 1. **Cluster** greedily in expert-id order: an expert joins the first
//!    existing cluster whose *representative* (first member) it matches at
//!    cosine ≥ threshold over the concatenated dense w1‖w2‖w3
//!    ([`crate::model::ExpertWeights::concat_dense`]); otherwise it opens
//!    a new cluster. Deterministic, order-stable, O(n²) in experts — this
//!    runs at compression time, never while serving.
//! 2. **Merge** each multi-member cluster into a frequency-weighted
//!    average of its members (Eq. 3/4-style selection frequencies as the
//!    weights; uniform when the cluster saw no traffic), and factor each
//!    member's residual into a rank-limited [`ExpertDelta`] via the
//!    deterministic truncated SVD ([`crate::tensor::linalg`]).
//! 3. **Remap** the router: install a [`RouterRemap`] so the forward pass
//!    reduces old-id logits to merged-id logits (max or sum) before
//!    softmax/top-k — `model/forward.rs::moe_layer_merged`.
//!
//! Contract: `threshold >= 1.0` merges nothing and installs nothing — the
//! model is byte-identical to its input and the forward pass never leaves
//! the unmerged code path. Singleton clusters keep their original
//! [`WeightMat`] (packed stays packed, no dequant round-trip) and carry no
//! delta, so a merge that only forms singletons is also exact.

use crate::model::weights::{ExpertDelta, ExpertWeights, RemapReduce, RouterRemap, Weights};
use crate::tensor::linalg::svd_truncated;
use crate::tensor::{ops, Mat, Pcg64};
use std::sync::Arc;

/// Parameters of the merge transform.
#[derive(Clone, Copy, Debug)]
pub struct MergeConfig {
    /// Cosine-similarity threshold for joining a cluster; `>= 1.0` merges
    /// nothing (the bit-identity sentinel).
    pub threshold: f32,
    /// Max rank of each absorbed member's per-projection residual delta;
    /// 0 drops residuals entirely (pure averaging, lossy).
    pub delta_rank: usize,
    /// How cluster members' router logits combine into the merged logit.
    pub reduce: RemapReduce,
}

impl Default for MergeConfig {
    fn default() -> Self {
        MergeConfig { threshold: 1.0, delta_rank: 4, reduce: RemapReduce::Max }
    }
}

impl MergeConfig {
    /// Config at a given threshold with the default rank/reduce.
    pub fn at_threshold(threshold: f32) -> Self {
        MergeConfig { threshold, ..Default::default() }
    }
}

/// Per-layer outcome of [`merge_experts`].
#[derive(Clone, Debug)]
pub struct MergeLayerReport {
    pub layer: usize,
    pub experts_before: usize,
    pub experts_after: usize,
    /// Old expert ids per cluster, in merged-id order (singletons
    /// included). Empty when the layer was left unmerged.
    pub clusters: Vec<Vec<usize>>,
}

/// Whole-model outcome of [`merge_experts`].
#[derive(Clone, Debug)]
pub struct MergeReport {
    pub per_layer: Vec<MergeLayerReport>,
    pub experts_before: usize,
    pub experts_after: usize,
    /// Routed-expert bytes (bases + deltas) before/after the transform.
    pub bytes_before: usize,
    pub bytes_after: usize,
}

impl MergeReport {
    /// True if any layer actually installed a remap.
    pub fn merged_any(&self) -> bool {
        self.per_layer.iter().any(|l| l.experts_after < l.experts_before)
    }
}

/// Uniform per-layer selection frequencies — the merge weighting to use
/// when no calibration traffic is available (every member contributes
/// equally to its cluster base).
pub fn uniform_frequencies(n_layers: usize, n_experts: usize) -> Vec<Vec<f32>> {
    vec![vec![1.0; n_experts]; n_layers]
}

/// Merge each layer's routed experts in place per `cfg`, installing the
/// router remap, cluster bases and per-member low-rank deltas. `freq` is
/// one selection-frequency row per layer (width = that layer's expert
/// count; see [`uniform_frequencies`]); it weights the cluster average so
/// the merged base leans toward the members the router actually uses.
///
/// Layers where every cluster is a singleton (including every layer when
/// `threshold >= 1.0`) are left untouched — no remap, no new tensors, and
/// the forward pass stays on the unmerged code path.
pub fn merge_experts(w: &mut Weights, freq: &[Vec<f32>], cfg: &MergeConfig) -> MergeReport {
    assert_eq!(freq.len(), w.layers.len(), "one frequency row per layer");
    let bytes_before = w.routed_expert_bytes();
    let mut experts_before = 0usize;
    let mut experts_after = 0usize;
    let mut per_layer = Vec::with_capacity(w.layers.len());
    for li in 0..w.layers.len() {
        let layer = &mut w.layers[li];
        assert!(layer.remap().is_none(), "layer {li} is already merged");
        let n = layer.experts().len();
        assert_eq!(freq[li].len(), n, "layer {li}: frequency width != expert count");
        experts_before += n;
        let identity = |experts_after: &mut usize| {
            *experts_after += n;
            MergeLayerReport {
                layer: li,
                experts_before: n,
                experts_after: n,
                clusters: Vec::new(),
            }
        };
        if cfg.threshold >= 1.0 || n == 0 {
            per_layer.push(identity(&mut experts_after));
            continue;
        }
        // Greedy clustering against each cluster's representative.
        let flats: Vec<Vec<f32>> = layer.experts().iter().map(|e| e.concat_dense()).collect();
        let mut clusters: Vec<Vec<usize>> = Vec::new();
        for e in 0..n {
            let mut placed = false;
            for c in clusters.iter_mut() {
                if ops::cosine(&flats[e], &flats[c[0]]) >= cfg.threshold {
                    c.push(e);
                    placed = true;
                    break;
                }
            }
            if !placed {
                clusters.push(vec![e]);
            }
        }
        if clusters.len() == n {
            per_layer.push(identity(&mut experts_after));
            continue;
        }
        let mut map = vec![0u16; n];
        for (m, c) in clusters.iter().enumerate() {
            for &o in c {
                map[o] = m as u16;
            }
        }
        let mut bases: Vec<Arc<ExpertWeights>> = Vec::with_capacity(clusters.len());
        let mut deltas: Vec<Option<ExpertDelta>> = (0..n).map(|_| None).collect();
        for c in &clusters {
            if c.len() == 1 {
                // Singleton: keep the original storage form (packed stays
                // packed — no dequant round-trip), no delta. Exact.
                bases.push(layer.expert_arc(c[0]));
                continue;
            }
            let (base, member_deltas) = merge_cluster(layer.experts(), c, &freq[li], cfg);
            for (&o, d) in c.iter().zip(member_deltas) {
                deltas[o] = d;
            }
            bases.push(Arc::new(base));
        }
        experts_after += clusters.len();
        let remap = RouterRemap { map, n_merged: clusters.len(), reduce: cfg.reduce };
        layer.install_merge(remap, bases, deltas);
        per_layer.push(MergeLayerReport {
            layer: li,
            experts_before: n,
            experts_after: clusters.len(),
            clusters: clusters.clone(),
        });
    }
    MergeReport {
        per_layer,
        experts_before,
        experts_after,
        bytes_before,
        bytes_after: w.routed_expert_bytes(),
    }
}

/// Merge one multi-member cluster: frequency-weighted average base (dense
/// f32) plus each member's rank-limited residual delta (`None` when the
/// residual is numerically negligible or `delta_rank == 0`).
fn merge_cluster(
    experts: &[Arc<ExpertWeights>],
    members: &[usize],
    freq: &[f32],
    cfg: &MergeConfig,
) -> (ExpertWeights, Vec<Option<ExpertDelta>>) {
    let dense: Vec<(Mat, Mat, Mat)> = members
        .iter()
        .map(|&o| {
            let e = &experts[o];
            (e.w1.to_dense(), e.w2.to_dense(), e.w3.to_dense())
        })
        .collect();
    // Frequency weights, uniform when the cluster's mass is zero.
    let mut ws: Vec<f32> = members.iter().map(|&o| freq[o].max(0.0)).collect();
    if ws.iter().sum::<f32>() <= 0.0 {
        ws.iter_mut().for_each(|x| *x = 1.0);
    }
    let total: f32 = ws.iter().sum();
    let avg = |pick: fn(&(Mat, Mat, Mat)) -> &Mat| -> Mat {
        let first = pick(&dense[0]);
        let mut acc = Mat::zeros(first.rows, first.cols);
        for (mem, &wt) in dense.iter().zip(&ws) {
            let frac = wt / total;
            for (a, &v) in acc.data.iter_mut().zip(&pick(mem).data) {
                *a += v * frac;
            }
        }
        acc
    };
    let (b1, b2, b3) = (avg(|d| &d.0), avg(|d| &d.1), avg(|d| &d.2));
    let deltas = dense
        .iter()
        .map(|(m1, m2, m3)| {
            if cfg.delta_rank == 0 {
                return None;
            }
            let r1 = sub(m1, &b1);
            let r2 = sub(m2, &b2);
            let r3 = sub(m3, &b3);
            // Skip a delta whose residual is noise relative to the base —
            // e.g. a member that IS the (weighted) average.
            let resid = r1.fro_norm() + r2.fro_norm() + r3.fro_norm();
            let scale = b1.fro_norm() + b2.fro_norm() + b3.fro_norm();
            if resid <= 1e-7 * (scale + 1.0) {
                return None;
            }
            let (u1, v1) = svd_truncated(&r1, cfg.delta_rank);
            let (u2, v2) = svd_truncated(&r2, cfg.delta_rank);
            let (u3, v3) = svd_truncated(&r3, cfg.delta_rank);
            Some(ExpertDelta { u1, v1, u2, v2, u3, v3 })
        })
        .collect();
    let base =
        ExpertWeights { w1: b1.into(), w2: b2.into(), w3: b3.into() };
    (base, deltas)
}

fn sub(a: &Mat, b: &Mat) -> Mat {
    debug_assert!(a.rows == b.rows && a.cols == b.cols, "residual shape mismatch");
    let mut out = a.clone();
    for (x, &y) in out.data.iter_mut().zip(&b.data) {
        *x -= y;
    }
    out
}

/// Test/bench workload synthesizer: overwrite expert `2i+1` of every
/// layer with expert `2i` plus a small seeded perturbation, so pairwise
/// cosine within each pair is ≈ `1/sqrt(1 + noise²)` while cross-pair
/// cosine stays near zero (random-init experts are near-orthogonal, and
/// without this nothing would merge at any realistic threshold). The
/// perturbation keeps residuals nonzero, so merge deltas exist and the
/// delta-tiering path is actually exercised.
pub fn synthesize_mergeable_pairs(w: &mut Weights, noise: f32, seed: u64) {
    let mut rng = Pcg64::new(seed, 7);
    for li in 0..w.layers.len() {
        let n = w.layers[li].experts().len();
        let mut e = 0;
        while e + 1 < n {
            let src = {
                let s = &w.layers[li].experts()[e];
                (s.w1.to_dense(), s.w2.to_dense(), s.w3.to_dense())
            };
            let mut perturb = |m: &Mat| {
                // Noise sigma relative to the matrix's RMS entry, so
                // `noise` directly controls the pairwise cosine.
                let rms = m.fro_norm() / (m.data.len().max(1) as f32).sqrt();
                let nz = Mat::randn(m.rows, m.cols, noise * rms.max(1e-6), &mut rng);
                let mut out = m.clone();
                for (a, &b) in out.data.iter_mut().zip(&nz.data) {
                    *a += b;
                }
                crate::model::weights::WeightMat::Dense(out)
            };
            *w.layers[li].expert_mut(e + 1) = ExpertWeights {
                w1: perturb(&src.0),
                w2: perturb(&src.1),
                w3: perturb(&src.2),
            };
            e += 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            n_shared: 1,
            n_heads: 2,
            vocab: 32,
            max_seq: 64,
        }
    }

    #[test]
    fn threshold_one_merges_nothing() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 31);
        synthesize_mergeable_pairs(&mut w, 0.01, 1);
        let before = w.clone();
        let rep = merge_experts(
            &mut w,
            &uniform_frequencies(cfg.n_layers, cfg.n_experts),
            &MergeConfig::at_threshold(1.0),
        );
        assert!(!rep.merged_any());
        assert_eq!(rep.experts_before, rep.experts_after);
        assert_eq!(rep.bytes_before, rep.bytes_after);
        for (l, lb) in w.layers.iter().zip(&before.layers) {
            assert!(l.remap().is_none());
            assert_eq!(l.experts().len(), lb.experts().len());
            for (a, b) in l.experts().iter().zip(lb.experts()) {
                assert_eq!(a.w1, b.w1);
                assert_eq!(a.w2, b.w2);
                assert_eq!(a.w3, b.w3);
            }
        }
    }

    #[test]
    fn synthesized_pairs_cluster_at_090() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 32);
        synthesize_mergeable_pairs(&mut w, 0.01, 2);
        let rep = merge_experts(
            &mut w,
            &uniform_frequencies(cfg.n_layers, cfg.n_experts),
            &MergeConfig::at_threshold(0.9),
        );
        assert!(rep.merged_any());
        assert_eq!(rep.experts_before, cfg.n_layers * cfg.n_experts);
        assert_eq!(rep.experts_after, cfg.n_layers * cfg.n_experts / 2);
        assert!(rep.bytes_after < rep.bytes_before);
        for l in &w.layers {
            let rm = l.remap().expect("remap installed");
            assert_eq!(rm.n_merged, cfg.n_experts / 2);
            assert_eq!(rm.map, vec![0, 0, 1, 1]);
            assert_eq!(l.n_routed(), cfg.n_experts / 2);
            // Perturbed members differ from the average, so both cluster
            // members carry a delta.
            assert!(l.deltas().iter().all(|d| d.is_some()));
        }
    }

    /// The frequency-weighted average is exactly Σ f_i·W_i / Σ f_i, and a
    /// member's base + full-rank delta reconstructs the member.
    #[test]
    fn weighted_average_and_delta_reconstruction() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 33);
        synthesize_mergeable_pairs(&mut w, 0.05, 3);
        let orig: Vec<Mat> =
            w.layers[0].experts().iter().map(|e| e.w1.to_dense()).collect();
        // Uneven frequencies: expert 0 carries 3x the weight of expert 1.
        let mut freq = uniform_frequencies(cfg.n_layers, cfg.n_experts);
        freq[0][0] = 3.0;
        freq[0][1] = 1.0;
        let rank = cfg.d_model.min(cfg.d_ff); // full rank: delta is exact
        let mc = MergeConfig { threshold: 0.9, delta_rank: rank, reduce: RemapReduce::Max };
        merge_experts(&mut w, &freq, &mc);
        let base = w.layers[0].experts()[0].w1.to_dense();
        for (i, (&a, &b)) in orig[0].data.iter().zip(&orig[1].data).enumerate() {
            let want = (3.0 * a + b) / 4.0;
            assert!(
                (base.data[i] - want).abs() <= 1e-5,
                "base[{i}] = {} want {want}",
                base.data[i]
            );
        }
        // Reconstruct member 1: base + u1·v1 ≈ original w1.
        let d = w.layers[0].delta_arc(1).expect("delta for absorbed member");
        let mut recon = base.clone();
        for r in 0..recon.rows {
            for c in 0..recon.cols {
                let mut corr = 0f32;
                for t in 0..d.u1.cols {
                    corr += d.u1.at(r, t) * d.v1.at(t, c);
                }
                *recon.at_mut(r, c) += corr;
            }
        }
        let err = recon.mse(&orig[1]).sqrt();
        let scale = orig[1].fro_norm() / (orig[1].data.len() as f32).sqrt();
        assert!(err <= 1e-4 * scale.max(1.0), "reconstruction rmse {err}");
    }

    #[test]
    fn zero_frequency_cluster_falls_back_to_uniform() {
        let cfg = tiny_cfg();
        let mut w = Weights::init(&cfg, 34);
        synthesize_mergeable_pairs(&mut w, 0.01, 4);
        let orig: Vec<Mat> =
            w.layers[0].experts().iter().map(|e| e.w1.to_dense()).collect();
        let freq = vec![vec![0.0; cfg.n_experts]; cfg.n_layers];
        merge_experts(&mut w, &freq, &MergeConfig::at_threshold(0.9));
        let base = w.layers[0].experts()[0].w1.to_dense();
        for (i, (&a, &b)) in orig[0].data.iter().zip(&orig[1].data).enumerate() {
            let want = (a + b) / 2.0;
            assert!((base.data[i] - want).abs() <= 1e-5, "base[{i}]");
        }
    }
}
