// Fixture: rule `float-hash-order`. HashMap/HashSet iteration order is
// nondeterministic; accumulating floats in that order breaks the pinned
// operation DAG between runs. Ordered (sorted-key) reductions and
// integer counters stay clean.

use std::collections::HashMap;

pub struct Acc {
    weights: HashMap<usize, f32>,
}

impl Acc {
    pub fn unordered_total(&self) -> f32 {
        let mut total = 0.0f32;
        for (_k, v) in &self.weights {
            total += *v; // LINT:float-hash-order
        }
        total
    }

    pub fn unordered_sum_chain(&self) -> f32 {
        self.weights.values().copied().sum::<f32>() // LINT:float-hash-order
    }

    pub fn count_is_fine(&self) -> usize {
        let mut n = 0usize;
        for _ in &self.weights {
            n += 1;
        }
        n
    }

    pub fn sorted_total_is_fine(&self) -> f32 {
        let mut keys: Vec<usize> = self.weights.keys().copied().collect();
        keys.sort_unstable();
        let mut total = 0.0f32;
        for k in keys {
            total += self.weights[&k];
        }
        total
    }

    pub fn allowed(&self) -> f32 {
        let mut total = 0.0f32;
        // xtask-allow: float-hash-order — fixture exercises the escape hatch
        for (_k, v) in &self.weights { total += *v; }
        total
    }
}
