// Fixture: rule `no-fma-transitive`. Replayed by the self-tests at
// rust/src/tensor/matmul.rs (a kernel contract file — every fn here is a
// seed) and at rust/src/calib/fixture.rs (outside the contract region —
// no seeds, so the same source lints clean). The inline `no-fma` allow
// silences the token rule but must NOT launder FMA past the transitive
// rule.

pub fn matmul_entry(a: f32, b: f32, c: f32) -> f32 {
    helper(a, b, c)
}

fn helper(a: f32, b: f32, c: f32) -> f32 {
    // xtask-allow: no-fma — fixture: the allow covers the token rule only
    a.mul_add(b, c) // LINT:no-fma-transitive
}
