// Fixture: rule `env-read-site`. EAC_MOE_* configuration is read once
// through util/env.rs; scattered reads reintroduce the PR 3 mid-run
// reconfiguration bug.

pub fn bad() -> Option<String> {
    std::env::var("EAC_MOE_THREADS").ok() // LINT:env-read-site
}

pub fn bad_split() -> Option<String> {
    std::env::var( // LINT:env-read-site
        "EAC_MOE_NO_SIMD",
    )
    .ok()
}

pub fn other_vars_are_fine() -> Option<String> {
    std::env::var("HOME").ok()
}

pub fn allowed() -> Option<String> {
    // xtask-allow: env-read-site — fixture exercises the escape hatch
    std::env::var("EAC_MOE_FIXTURE").ok()
}
