// Fixture: rule `env-read-site`. EAC_MOE_* configuration is read once
// through util/env.rs; scattered reads reintroduce the PR 3 mid-run
// reconfiguration bug. `var_os` counts as a read, and the `vars` /
// `vars_os` iterators enumerate every EAC_MOE_* variable implicitly.

pub fn bad() -> Option<String> {
    std::env::var("EAC_MOE_THREADS").ok() // LINT:env-read-site
}

pub fn bad_os() -> Option<std::ffi::OsString> {
    std::env::var_os("EAC_MOE_THREADS") // LINT:env-read-site
}

pub fn bad_split() -> Option<String> {
    std::env::var( // LINT:env-read-site
        "EAC_MOE_NO_SIMD",
    )
    .ok()
}

pub fn bad_enumerate() -> usize {
    std::env::vars().count() // LINT:env-read-site
}

pub fn bad_enumerate_os() -> usize {
    std::env::vars_os().count() // LINT:env-read-site
}

pub fn other_vars_are_fine() -> Option<String> {
    std::env::var("HOME").ok()
}

pub fn other_var_os_is_fine() -> Option<std::ffi::OsString> {
    std::env::var_os("HOME")
}

pub fn allowed() -> Option<String> {
    // xtask-allow: env-read-site — fixture exercises the escape hatch
    std::env::var("EAC_MOE_FIXTURE").ok()
}
