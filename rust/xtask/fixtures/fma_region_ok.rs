// Fixture: `no-fma` allow-region, linted by the self-tests at the rel
// path of tensor/simd.rs (the only file allowed to open one).

pub fn pinned_dag_region(a: f32, b: f32, c: f32) -> f32 {
    // xtask-allow-region: no-fma
    a.mul_add(b, c)
    // xtask-end-region: no-fma
}

pub fn outside_region_stays_clean(a: f32, b: f32) -> f32 {
    a * b + 1.0
}
