// Fixture: rule `no-raw-thread`. Production code must ride the scoped
// worker pool; raw std::thread escapes the thread budget.

pub fn bad_spawn() {
    std::thread::spawn(|| {}); // LINT:no-raw-thread
}

pub fn bad_builder() {
    let _ = std::thread::Builder::new(); // LINT:no-raw-thread
}

pub fn bad_scope() {
    std::thread::scope(|_| {}); // LINT:no-raw-thread
}

pub fn allowed_scope() {
    // xtask-allow: no-raw-thread — fixture exercises the escape hatch
    std::thread::scope(|_| {});
}

#[cfg(test)]
mod tests {
    #[test]
    fn threads_in_tests_are_fine() {
        std::thread::spawn(|| {}).join().unwrap();
    }
}
