//! Lexer torture sheet: every construct here must produce zero findings
//! (the self-tests lint it at a serve/ rel path so all rules are in
//! scope). Rule tokens appear only inside comments, strings, raw strings,
//! and char literals — places the scanner must blank.

pub fn strings_hide_tokens() -> Vec<String> {
    vec![
        "unsafe { never scanned }".to_string(),
        "a.mul_add(b, c)".to_string(),
        "std::thread::spawn".to_string(),
        ".unwrap() .expect( panic! unreachable!".to_string(),
        r#"env::var("EAC_MOE_X")"#.to_string(),
        "escaped \" quote stays inside the string".to_string(),
        "two trailing backslashes \\\\".to_string(),
    ]
}

/* block comment: unsafe, mul_add, thread::spawn, env::var("EAC_MOE_Y")
   /* nested block */ still comment: .unwrap() panic! */
pub fn lifetimes_and_chars<'env>(x: &'env [char]) -> (char, Option<&'env char>) {
    let quote = '"';
    let tick = '\'';
    let backslash = '\\';
    let newline = '\n';
    let brace = '{';
    let _ = (quote, tick, backslash, newline, brace);
    ('q', x.first())
}

pub fn byte_literals() -> (&'static [u8], u8, &'static [u8]) {
    let magic = b"EACM";
    let nul = b'\0';
    let raw = br#"bytes "quoted" here"#;
    (magic, nul, raw)
}

pub fn multiline_raw() -> &'static str {
    r#"
    unsafe { panic!("EAC_MOE_FAKE") } env::var mul_add thread::spawn .unwrap()
    "#
}

pub fn locks_are_exempt(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
