// Fixture: rule `unsafe-safety-comment`. Lines tagged LINT:<rule> in a
// trailing comment are the findings xtask's self-tests expect.

pub fn bad(out: &mut [f32]) {
    unsafe { // LINT:unsafe-safety-comment
        std::ptr::write(out.as_mut_ptr(), 1.0);
    }
}

pub fn good(out: &mut [f32]) {
    // SAFETY: the pointer comes from a live mutable slice.
    unsafe {
        std::ptr::write(out.as_mut_ptr(), 2.0);
    }
}

// SAFETY: contract — caller passes a pointer to at least one writable f32.
pub unsafe fn good_fn(p: *mut f32) {
    *p = 0.0;
}

/// Doc-style annotation also counts.
///
/// # Safety
/// Caller guarantees `p` is valid for writes.
pub unsafe fn good_doc_fn(p: *mut f32) {
    *p = 3.0;
}

pub fn escape_hatch(out: &mut [f32]) {
    // xtask-allow: unsafe-safety-comment — fixture exercises the escape hatch
    unsafe { std::ptr::write(out.as_mut_ptr(), 4.0) }
}
