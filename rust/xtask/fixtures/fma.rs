// Fixture: rule `no-fma`. Fused multiply-add rounds once where the pinned
// kernel DAG rounds twice, so any of these tokens breaks bit-identity.

pub fn bad(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c) // LINT:no-fma
}

// Comments may name fused multiply-add (mul_add) freely; only code counts.
pub fn ok(a: f32, b: f32, c: f32) -> f32 {
    let s = "mul_add in a string is fine";
    let _ = s;
    // xtask-allow: no-fma — fixture exercises the escape hatch
    a.mul_add(b, c)
}

pub fn region_outside_simd() -> f32 {
    // xtask-allow-region: no-fma LINT:xtask-marker
    1.0f32.mul_add(2.0, 3.0) // LINT:no-fma
    // xtask-end-region: no-fma
}
