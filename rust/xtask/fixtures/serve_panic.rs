// Fixture: rule `serve-no-panic`. Linted by the self-tests at a
// rust/src/serve/ rel path (in scope) and a rust/src/quant/ rel path
// (out of scope, expecting zero findings).

use std::sync::Mutex;

pub fn bad_unwrap(v: Option<u32>) -> u32 {
    v.unwrap() // LINT:serve-no-panic
}

pub fn bad_expect(v: Option<u32>) -> u32 {
    v.expect("boom") // LINT:serve-no-panic
}

pub fn bad_panic() {
    panic!("down"); // LINT:serve-no-panic
}

pub fn bad_unreachable(x: u8) -> u8 {
    match x {
        0 => 1,
        _ => unreachable!(), // LINT:serve-no-panic
    }
}

pub fn poisoned_lock_is_exempt(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

pub fn chained_lock_is_exempt(m: &Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len()
}

pub fn allowed(v: Option<u32>) -> u32 {
    // xtask-allow: serve-no-panic — invariant: caller checked is_some()
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1u32).unwrap(), 1);
    }
}
