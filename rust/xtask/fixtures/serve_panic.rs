// Fixture: transitive `serve-no-panic` / `serve-unguarded-index`. The
// graph analysis seeds at Engine::serve, decode_step_batch, and the pub
// ExpertStore surface, then follows call edges; private fns nothing on
// the serve path calls stay exempt — reachability, not path prefix,
// decides.

use std::sync::Mutex;

pub struct Engine;
pub struct ExpertStore;

impl Engine {
    pub fn serve(&self, m: &Mutex<u32>) -> usize {
        let base = allowed_unwrap(Some(locked(m)));
        dispatch(base as usize)
    }
}

impl ExpertStore {
    pub fn fetch(&self, xs: &[f32]) -> f32 {
        assert!(xs.len() > 1, "fetch needs at least two activations");
        xs[0] + xs[1]
    }
}

pub fn decode_step_batch(xs: &[f32]) -> f32 {
    deep_helper(xs)
}

fn dispatch(n: usize) -> usize {
    if n > 3 {
        boom(n)
    } else {
        n
    }
}

fn boom(n: usize) -> usize {
    panic!("mid-batch failure: {n}"); // LINT:serve-no-panic
}

fn deep_helper(xs: &[f32]) -> f32 {
    let head = xs.first().copied();
    let head = head.unwrap(); // LINT:serve-no-panic
    head + raw_index(xs)
}

fn raw_index(xs: &[f32]) -> f32 {
    xs[2] * 2.0 // LINT:serve-unguarded-index
}

fn locked(m: &Mutex<u32>) -> u32 {
    // Poisoned-lock unwraps propagate a worker panic — exempt.
    *m.lock().unwrap()
}

fn allowed_unwrap(v: Option<u32>) -> u32 {
    // xtask-allow: serve-no-panic — invariant: serve() always passes Some
    v.unwrap()
}

fn dead_code(xs: &[f32]) -> f32 {
    // Panic and unguarded index, but nothing on the serve path calls
    // this fn — no findings here.
    panic!("never reached: {}", xs[3]);
}
