//! Minimal Rust-aware source scanner for the invariant linter.
//!
//! For every line of a source file it produces three views:
//!
//! - `code`: the line with comments removed and the *contents* of string /
//!   char literals blanked (the delimiters remain, so `"..."` scans as
//!   `""`). Rule tokens are matched against this view only, which is what
//!   makes the rules reliable: `unsafe` in a doc comment, `mul_add` in an
//!   error-message string, or a quoted `env::var("EAC_MOE_X")` example can
//!   never trip a rule.
//! - `comment`: the concatenated comment text of the line (without the
//!   `//` / `/* */` markers). Escape-hatch markers (`xtask-allow: <rule>`)
//!   and `SAFETY:` annotations are read from this view only, so quoting a
//!   marker inside a string cannot disable a rule.
//! - `raw`: the original line, used only where a rule needs literal string
//!   contents (the `EAC_MOE_` prefix of an env read).
//!
//! This is deliberately not a full lexer — just enough of one: nested
//! block comments, escaped strings, raw strings (`r"…"`, `r#"…"#`, byte
//! variants), char literals vs. lifetimes (`'a'` vs `'env`), multi-line
//! literals. The `fixtures/clean.rs` self-test is the torture sheet.

/// One scanned source line.
pub struct Line {
    pub raw: String,
    pub code: String,
    pub comment: String,
}

/// A scanned file: lines plus a per-line "is this test code?" mask.
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<Line>,
    pub is_test: Vec<bool>,
}

pub fn scan_source(rel: &str, text: &str) -> SourceFile {
    let lines = lex(text);
    let is_test = mark_test_regions(&lines, rel);
    SourceFile { rel: rel.to_string(), lines, is_test }
}

enum Mode {
    Code,
    LineComment,
    /// Nesting depth (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// Number of `#` marks that close this raw string.
    RawStr(u32),
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `true` if `ch[j..]` starts with `hashes` copies of `#` (the tail of a
/// raw-string terminator whose `"` the caller already matched).
fn ends_raw(ch: &[char], j: usize, hashes: u32) -> bool {
    let h = hashes as usize;
    j + h <= ch.len() && ch[j..j + h].iter().all(|&c| c == '#')
}

/// If `ch[i..]` opens a raw/byte string or byte-char literal (`r"`,
/// `r#"`, `br"`, `b"`, `b'`), return (chars consumed through the opening
/// delimiter, mode to enter).
fn raw_or_byte_open(ch: &[char], i: usize) -> Option<(usize, Mode)> {
    let mut j = i;
    if ch[j] == 'b' {
        match ch.get(j + 1) {
            Some('"') => return Some((2, Mode::Str)),
            Some('\'') => return Some((2, Mode::CharLit)),
            Some('r') => j += 1,
            _ => return None,
        }
    }
    if ch[j] != 'r' {
        return None;
    }
    let mut hashes = 0u32;
    let mut k = j + 1;
    while ch.get(k) == Some(&'#') {
        hashes += 1;
        k += 1;
    }
    if ch.get(k) == Some(&'"') {
        Some((k + 1 - i, Mode::RawStr(hashes)))
    } else {
        None
    }
}

fn lex(text: &str) -> Vec<Line> {
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut lines: Vec<Line> = Vec::new();
    let mut raw = String::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < n {
        let c = ch[i];
        if c == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            lines.push(Line {
                raw: std::mem::take(&mut raw),
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        raw.push(c);
        match mode {
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '*' && ch.get(i + 1) == Some(&'/') {
                    raw.push('/');
                    i += 2;
                    if depth == 1 {
                        mode = Mode::Code;
                        code.push(' ');
                    } else {
                        mode = Mode::BlockComment(depth - 1);
                    }
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    raw.push('*');
                    comment.push(' ');
                    i += 2;
                    mode = Mode::BlockComment(depth + 1);
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str | Mode::CharLit => {
                let closer = if matches!(mode, Mode::Str) { '"' } else { '\'' };
                if c == '\\' {
                    // Consume the escape pair (keeps \" and \' from
                    // closing the literal). A backslash-newline
                    // continuation leaves the newline for the line loop.
                    if let Some(&e) = ch.get(i + 1) {
                        if e != '\n' {
                            raw.push(e);
                            i += 2;
                            continue;
                        }
                    }
                    i += 1;
                } else if c == closer {
                    code.push(closer);
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && ends_raw(&ch, i + 1, hashes) {
                    for _ in 0..hashes {
                        raw.push('#');
                    }
                    code.push('"');
                    i += 1 + hashes as usize;
                    mode = Mode::Code;
                } else {
                    i += 1;
                }
            }
            Mode::Code => {
                let next = ch.get(i + 1).copied();
                let prev_is_ident = code.chars().last().map(is_ident).unwrap_or(false);
                if c == '/' && next == Some('/') {
                    raw.push('/');
                    code.push(' ');
                    i += 2;
                    mode = Mode::LineComment;
                } else if c == '/' && next == Some('*') {
                    raw.push('*');
                    code.push(' ');
                    i += 2;
                    mode = Mode::BlockComment(1);
                } else if c == '"' {
                    code.push('"');
                    i += 1;
                    mode = Mode::Str;
                } else if !prev_is_ident && (c == 'r' || c == 'b') {
                    match raw_or_byte_open(&ch, i) {
                        Some((consumed, m)) => {
                            for k in 1..consumed {
                                raw.push(ch[i + k]);
                            }
                            code.push(if matches!(m, Mode::CharLit) { '\'' } else { '"' });
                            i += consumed;
                            mode = m;
                        }
                        None => {
                            code.push(c);
                            i += 1;
                        }
                    }
                } else if c == '\'' {
                    match next {
                        // Escaped char literal: '\n', '\'', '\\', '\u{…}'.
                        Some('\\') => {
                            code.push('\'');
                            i += 1;
                            mode = Mode::CharLit;
                        }
                        // Plain one-char literal 'x' (consume it whole so
                        // a quote or brace inside never reaches Code mode).
                        Some(x) if x != '\'' && ch.get(i + 2) == Some(&'\'') => {
                            raw.push(x);
                            raw.push('\'');
                            code.push('\'');
                            code.push('\'');
                            i += 3;
                        }
                        // Otherwise a lifetime / loop label tick.
                        _ => {
                            code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    // Blank non-ASCII so byte-offset searches over `code`
                    // can never land mid-codepoint.
                    code.push(if c.is_ascii() { c } else { '_' });
                    i += 1;
                }
            }
        }
    }
    if !raw.is_empty() || !code.is_empty() || !comment.is_empty() {
        lines.push(Line { raw, code, comment });
    }
    lines
}

/// Mark lines inside `#[cfg(test)]` items (and whole files under
/// `rust/tests/`) as test code. Tracking is brace-based: the attribute
/// arms a pending flag, the next `{` opens the region, and the matching
/// `}` closes it. `mod tests;` (out-of-line test modules) is not handled
/// — this repo keeps test modules inline.
fn mark_test_regions(lines: &[Line], rel: &str) -> Vec<bool> {
    if rel.starts_with("rust/tests/") {
        return vec![true; lines.len()];
    }
    let mut out = vec![false; lines.len()];
    let mut depth = 0usize;
    let mut pending = false;
    let mut region: Option<usize> = None;
    for (idx, line) in lines.iter().enumerate() {
        if region.is_none() && line.code.contains("#[cfg(test)") {
            pending = true;
        }
        if pending || region.is_some() {
            out[idx] = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending && region.is_none() {
                        region = Some(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if region == Some(depth) {
                        region = None;
                    }
                    depth = depth.saturating_sub(1);
                }
                _ => {}
            }
        }
        if region.is_some() {
            out[idx] = true;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        lex(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let t = "let s = \"unsafe { }\"; // trailing unsafe\nlet c = 'x';";
        let code = code_of(t);
        assert!(!code[0].contains("unsafe"));
        assert!(code[0].contains("let s = \"\";"));
        assert_eq!(code[1], "let c = '';");
        let lines = lex(t);
        assert!(lines[0].comment.contains("trailing unsafe"));
    }

    #[test]
    fn raw_strings_span_lines() {
        let t = "let s = r#\"line1\nunsafe mul_add\n\"#; let x = 1;";
        let code = code_of(t);
        assert_eq!(code[0], "let s = r\"");
        assert_eq!(code[1], "");
        assert_eq!(code[2], "\"; let x = 1;");
    }

    #[test]
    fn char_literal_with_quote_does_not_open_string() {
        let t = "let q = '\"';\nlet tick = '\\'';\nlet bs = '\\\\';\nlet lt: &'static str = \"ok\";";
        let code = code_of(t);
        assert_eq!(code[0], "let q = '';");
        assert_eq!(code[1], "let tick = '';");
        assert_eq!(code[2], "let bs = '';");
        assert!(code[3].contains("&'static str"));
        assert!(code[3].ends_with("\"\";"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let t = "/* outer /* inner */ still comment */ let x = 1;";
        let code = code_of(t);
        assert!(code[0].contains("let x = 1;"));
        assert!(!code[0].contains("inner"));
    }

    #[test]
    fn test_regions_are_marked() {
        let t = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}";
        let sf = scan_source("rust/src/x.rs", t);
        assert_eq!(sf.is_test, vec![false, true, true, true, true, false]);
        let tf = scan_source("rust/tests/x.rs", "fn a() {}");
        assert!(tf.is_test[0]);
    }
}
