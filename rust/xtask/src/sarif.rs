//! SARIF 2.1.0 output for the linter, so findings surface as GitHub
//! code-scanning annotations.
//!
//! Hand-rolled JSON (the crate is std-only by design): a minimal but
//! schema-valid document — `version`, `$schema`, one run with the tool
//! driver's rule table, and one `result` per finding with `ruleId`,
//! `ruleIndex`, `level`, `message.text`, and a physical location
//! (repo-relative URI + 1-based `startLine`). CI validates the emitted
//! file against the official SARIF 2.1.0 JSON schema.

use crate::rules::{Finding, META_RULE, RULES};

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a full SARIF document for the findings.
pub fn render(findings: &[Finding]) -> String {
    // Rule table: the declared rules plus the marker meta-rule; ruleIndex
    // in each result points into this array.
    let mut rule_ids: Vec<(&str, &str)> = RULES.to_vec();
    rule_ids.push((META_RULE, "xtask-allow/region marker misuse"));

    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(
        "  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\",\n  \"version\": \"2.1.0\",\n",
    );
    out.push_str("  \"runs\": [\n    {\n      \"tool\": {\n        \"driver\": {\n");
    out.push_str("          \"name\": \"xtask-lint\",\n");
    out.push_str("          \"informationUri\": \"https://example.invalid/eac-moe/xtask\",\n");
    out.push_str("          \"version\": \"0.1.0\",\n");
    out.push_str("          \"rules\": [\n");
    for (i, (id, desc)) in rule_ids.iter().enumerate() {
        out.push_str(&format!(
            "            {{ \"id\": \"{}\", \"shortDescription\": {{ \"text\": \"{}\" }} }}{}\n",
            esc(id),
            esc(desc),
            if i + 1 < rule_ids.len() { "," } else { "" }
        ));
    }
    out.push_str("          ]\n        }\n      },\n");
    out.push_str("      \"results\": [\n");
    for (i, f) in findings.iter().enumerate() {
        let rule_index = rule_ids
            .iter()
            .position(|(id, _)| *id == f.rule)
            .map(|p| p as i64)
            .unwrap_or(-1);
        out.push_str("        {\n");
        out.push_str(&format!("          \"ruleId\": \"{}\",\n", esc(f.rule)));
        if rule_index >= 0 {
            out.push_str(&format!("          \"ruleIndex\": {rule_index},\n"));
        }
        out.push_str("          \"level\": \"error\",\n");
        out.push_str(&format!(
            "          \"message\": {{ \"text\": \"{}\" }},\n",
            esc(&f.msg)
        ));
        out.push_str("          \"locations\": [\n            {\n");
        out.push_str("              \"physicalLocation\": {\n");
        out.push_str(&format!(
            "                \"artifactLocation\": {{ \"uri\": \"{}\" }},\n",
            esc(&f.rel)
        ));
        out.push_str(&format!(
            "                \"region\": {{ \"startLine\": {} }}\n",
            f.line.max(1)
        ));
        out.push_str("              }\n            }\n          ]\n");
        out.push_str(&format!(
            "        }}{}\n",
            if i + 1 < findings.len() { "," } else { "" }
        ));
    }
    out.push_str("      ]\n    }\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![
            Finding {
                rel: "rust/src/serve/engine.rs".into(),
                line: 12,
                rule: "serve-no-panic",
                msg: "`panic!` with \"quotes\" and a\nnewline".into(),
            },
            Finding {
                rel: "rust/xtask/layering.toml".into(),
                line: 1,
                rule: "module-layering",
                msg: "module `a` has no entry".into(),
            },
        ]
    }

    #[test]
    fn renders_required_fields() {
        let doc = render(&sample());
        for needle in [
            "\"version\": \"2.1.0\"",
            "sarif-2.1.0.json",
            "\"name\": \"xtask-lint\"",
            "\"ruleId\": \"serve-no-panic\"",
            "\"startLine\": 12",
            "rust/src/serve/engine.rs",
        ] {
            assert!(doc.contains(needle), "missing {needle} in:\n{doc}");
        }
    }

    #[test]
    fn escapes_message_text() {
        let doc = render(&sample());
        assert!(doc.contains("\\\"quotes\\\""));
        assert!(doc.contains("a\\nnewline"));
        assert!(!doc.contains("a\nnewline"), "raw newline leaked into a JSON string");
    }

    #[test]
    fn empty_findings_still_valid_shape() {
        let doc = render(&[]);
        assert!(doc.contains("\"results\": [\n      ]"));
        // Every declared rule appears in the driver table.
        for (id, _) in RULES {
            assert!(doc.contains(&format!("\"id\": \"{id}\"")), "rule {id} missing");
        }
    }

    /// A structural brace/bracket/quote balance check — not a JSON parser,
    /// but enough to catch an unbalanced emitter. CI validates the real
    /// document against the official schema.
    #[test]
    fn braces_balance() {
        let doc = render(&sample());
        let mut depth = 0i32;
        let mut in_str = false;
        let mut prev_escape = false;
        for c in doc.chars() {
            if in_str {
                if prev_escape {
                    prev_escape = false;
                } else if c == '\\' {
                    prev_escape = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' | '[' => depth += 1,
                '}' | ']' => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0);
        assert!(!in_str);
    }
}
